"""Adaptive decode-burst length (``--burst-len auto``).

Decode bursts trade host round trips for mid-burst waste: a row (or beam
group) that finishes at step ``s`` of a ``K``-step burst computes ``K - s``
masked steps before the host can refill its slot at the burst edge.  The
right ``K`` therefore depends on two machine-local quantities the engine
can only measure at run time:

* ``t_sync`` — the fixed cost of one burst dispatch + device→host drain
  (what larger bursts amortize), and
* ``t_step`` — the marginal cost of one fused grid step (what mid-burst
  EOS waste is denominated in).

:class:`AdaptiveBurst` estimates both from per-burst wall times and moves
the step cap between bursts: shrink when the waste of the *last* burst
cost more than one sync, grow when it cost far less.  The cap only ever
takes power-of-two values **and the compiled ring-buffer width stays
pinned at the maximum bucket** — the engine's burst programs take the
real step cap as a device scalar, so adapting ``K`` never triggers a new
XLA compile (the ROADMAP PR 2 follow-up's requirement).
"""

from __future__ import annotations

from repro.data.sorting import next_pow2


class AdaptiveBurst:
    """Online controller for the serve loop's burst step cap.

    Usage: read :attr:`k` before each burst, call :meth:`observe` with the
    burst's measurements after its drain.  :attr:`max_burst` is the fixed
    compiled bucket (ring-buffer width); :attr:`k` is the device-scalar
    cap, always a power of two in ``[1, max_burst]``.
    """

    #: fraction of a burst's wall time used to seed ``_t_sync`` — the
    #: first measured burst cannot separate step cost from sync overhead
    #: (its own per-step time still *contains* the overhead), so the sync
    #: estimate starts as a conservative wall-time fraction and the EMA
    #: refines it once later bursts ground ``_t_step``.
    SYNC_SEED_FRAC = 0.1

    def __init__(self, start: int = 8, max_burst: int = 64,
                 grow_margin: float = 4.0, ema: float = 0.3):
        if max_burst < 1:
            raise ValueError(f"max_burst must be ≥ 1, got {max_burst}")
        self.max_burst = next_pow2(max_burst)
        self.k = max(1, min(next_pow2(start), self.max_burst))
        self.grow_margin = float(grow_margin)
        self.ema = float(ema)
        self._t_step: float | None = None      # min observed s/step
        self._t_sync: float | None = None      # EMA of fixed per-burst cost
        self._observed = 0
        self.shrinks = 0
        self.grows = 0

    @property
    def t_sync_s(self) -> float:
        return self._t_sync or 0.0

    @property
    def t_step_s(self) -> float:
        return self._t_step or 0.0

    def observe(self, wall_s: float, steps: int, wasted_row_steps: int,
                rows: int) -> int:
        """Feed one burst's measurements; returns the next step cap.

        ``wall_s``: dispatch→drain wall time of the burst;
        ``steps``: grid steps the burst actually took;
        ``wasted_row_steps``: Σ over occupied rows of steps computed after
        the row finished (the ``decode_steps`` vs ``busy_slot_steps`` gap
        attributable to mid-burst EOS);
        ``rows``: total grid rows (waste is normalised to whole-grid
        steps, since the fused program computes every row every step).
        """
        if steps <= 0 or rows <= 0 or wall_s <= 0.0:
            return self.k
        self._observed += 1
        if self._observed == 1:
            return self.k            # burn-in: first burst includes compile
        per_step = wall_s / steps
        if self._observed == 2:
            # burn-in, part two: the first *measured* burst's per-step
            # time still carries the full per-burst sync overhead, so
            # deriving ``overhead = wall − steps·t_step`` from it would
            # compute ≈0 and seed ``_t_sync`` near zero — every mid-burst
            # EOS would then look more expensive than a sync and shrink
            # ``k`` spuriously.  Seed both estimates conservatively and
            # start adapting only once a second, distinct observation can
            # ground them.
            self._t_step = per_step
            self._t_sync = self.SYNC_SEED_FRAC * wall_s
            return self.k
        self._t_step = per_step if self._t_step is None \
            else min(self._t_step, per_step)
        overhead = max(wall_s - steps * self._t_step, 0.0)
        self._t_sync = overhead if self._t_sync is None \
            else (1.0 - self.ema) * self._t_sync + self.ema * overhead
        waste_s = (wasted_row_steps / rows) * self._t_step
        if wasted_row_steps == 0 and self.k < self.max_burst:
            # no row finished mid-burst: a longer burst strictly saves syncs
            self.k *= 2
            self.grows += 1
        elif waste_s > self.t_sync_s and self.k > 1:
            # the waste cost more than the sync it saved: halve the burst
            self.k //= 2
            self.shrinks += 1
        elif waste_s * self.grow_margin < self.t_sync_s and \
                self.k < self.max_burst:
            self.k *= 2
            self.grows += 1
        return self.k
