"""Preempt-by-page-spill: host-side spill store + victim selection.

Under overcommit the scheduler admits more concurrent rows than the page
pool could back worst-case; the proof that this cannot deadlock is this
module: any running request can be *preempted* — its KV pages (INT8
payload + scales), cross-attention K/V, cursors and decode tokens are
copied to host, its pages returned to the pool, and the request re-enters
the wait queue.  On re-admission the engine restores the payload through
the existing paged splice (``kv_cache.insert_rows_paged``) and decoding
continues bit-identically to an uninterrupted serve — the identity the
chaos harness (``serving/chaos.py`` + ``tests/test_preemption.py``)
asserts across the whole greedy/beam × FP/INT8 × fused/unfused matrix.

Everything here is host-side bookkeeping (numpy + dicts); the device
gathers/scatters live in the engine's jitted ``_spill_fn``/``_resume_fn``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SpilledRequest:
    """One preempted request's complete decode state, on host.

    Arrays keep the *logical* (linearized) row view — ``(L, W, cap, …)``
    with ``cap = max_pages × page_size`` — so restore is exactly the
    unfused-admission splice: build a contiguous side batch, scatter it
    into freshly allocated pages.  INT8 payload and float32 scales are
    captured verbatim (no re-quantization round trip), which is what
    makes resume bit-identical.
    """

    req_id: int
    n_rows: int                        # 1 (greedy) or the group width
    # self-attention KV, linearized logical rows (junk past each cursor —
    # masked on device exactly like any partially filled cache row)
    k: np.ndarray                      # (L, W, cap, HKV, dh)
    v: np.ndarray
    k_scale: Optional[np.ndarray]      # (L, W, cap, HKV) when quantized
    v_scale: Optional[np.ndarray]
    lengths: np.ndarray                # (W,) decode cursors
    tokens_row: np.ndarray             # (W,) last token fed to each row
    # cross-attention KV + source lengths (whatever splice installed —
    # fresh encode, prefix-cache chain, or an earlier restore)
    cross_k: np.ndarray                # (L, W, S_enc, HKV, dh)
    cross_v: np.ndarray
    src_lengths: np.ndarray            # (W,)
    # allocator accounting: pages' worth of KV this spill represents
    n_pages: int
    # beam serving: host-side search state (None for greedy)
    beam: Optional[dict] = None        # scores, finished, parked, history,
                                       # budget_left

    @property
    def n_bytes(self) -> int:
        total = 0
        for a in (self.k, self.v, self.k_scale, self.v_scale,
                  self.cross_k, self.cross_v, self.lengths,
                  self.tokens_row, self.src_lengths):
            if a is not None:
                total += a.nbytes
        return int(total)


class SpillStore:
    """Host spill store: req_id → :class:`SpilledRequest`, with the
    counters ``ServeResult.metrics`` surfaces.  A serve must end with the
    store empty (every spill restored) — the leak check next to the
    allocator's ``spilled == 0``."""

    def __init__(self) -> None:
        self._store: Dict[int, SpilledRequest] = {}
        self.spill_events = 0
        self.restore_events = 0
        self.spilled_bytes = 0         # cumulative, for metrics

    def put(self, spill: SpilledRequest) -> None:
        if spill.req_id in self._store:
            raise ValueError(f"request {spill.req_id} is already spilled")
        self._store[spill.req_id] = spill
        self.spill_events += 1
        self.spilled_bytes += spill.n_bytes

    def pop(self, req_id: int) -> SpilledRequest:
        if req_id not in self._store:
            raise ValueError(f"request {req_id} has no spill to restore")
        self.restore_events += 1
        return self._store.pop(req_id)

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, req_id: int) -> bool:
        return req_id in self._store


def pick_victims(candidates: Sequence, *, pages_needed: int,
                 key_fn, pages_held_fn,
                 exclude: Iterable = (),
                 min_key: Optional[float] = None) -> Tuple[List, bool]:
    """Choose running requests to preempt until ``pages_needed`` pages
    would come free.

    Least-urgent-first (largest ``key_fn`` value — latest deadline /
    lowest priority — evicted first), ties broken toward the youngest
    admission so older work keeps its progress.  ``exclude`` protects
    rows that must survive this round (the row being grown, this round's
    fresh admissions).  ``min_key``: only requests *strictly less urgent*
    than this key may be evicted — the anti-thrash guard for
    admission-driven preemption (a request never evicts an equally or
    more urgent one, so two equal-urgency requests cannot ping-pong).

    Returns ``(victims, covered)``: ``covered`` says whether evicting the
    listed victims frees at least ``pages_needed`` pages.  The contract
    is uniform across ``min_key`` modes — earlier revisions returned an
    *insufficient* victim list in the ``min_key=None`` case, so a caller
    that preempted without re-checking paid the spill + re-encode cost of
    every victim and still came up short.  Callers decide: mandatory
    growth may evict partial coverage (or fail loudly), admission-driven
    preemption must not evict at all unless the head request actually
    fits afterwards.
    """
    if pages_needed <= 0:
        return [], True
    excluded = {id(r) for r in exclude}
    pool = [r for r in candidates if id(r) not in excluded]
    if min_key is not None:
        pool = [r for r in pool if key_fn(r) > min_key]
    pool.sort(key=lambda r: (-key_fn(r),
                             -(r.admitted_step if r.admitted_step
                               is not None else 0)))
    victims: List = []
    freed = 0
    for r in pool:
        if freed >= pages_needed:
            break
        victims.append(r)
        freed += pages_held_fn(r)
    return victims, freed >= pages_needed
