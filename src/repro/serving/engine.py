"""Serving engine: prefill + auto-regressive decode (greedy, beam, continuous).

This is the paper's workload: batched NMT inference with a decoder
while-loop.  Beam search reorders the KV cache every step through
``kv_cache.gather_beams`` — the GatherNd the paper quantized (§5.3); with an
INT8 cache the reorder moves 4× fewer bytes.

Beyond the paper's static batches, :meth:`ServingEngine.serve` implements
**continuous batching**: a fixed pool of ``n_slots`` decode rows runs one
shared decode step; when a sequence finishes, its KV-cache slot is refilled
by prefilling the next waiting request (``kv_cache.insert_at_slots``) while
the other slots keep decoding.  Admission order and pacing come from
``scheduler.ContinuousScheduler``; prefill side-batches are padded to
power-of-two widths so the whole serve compiles O(log slots) programs.
Greedy decode through ``serve`` is token-identical to per-request
:meth:`generate` — every per-row computation is batch-independent.

``serve(beam=B)`` extends continuous batching to **beam search**: a request
occupies a *group* of ``B`` contiguous rows, the scheduler admits/releases
whole groups, and the decode burst runs the beam-search body (top-k +
device-side cache reorder — the paper's §5.3 GatherNd) with per-group
budget/finished masks so groups at different lifecycle stages share one
grid.  Finished groups are drained and refilled at burst edges; output is
token-identical to per-request :meth:`generate_beam` for every
``burst_len``, with FP or INT8 KV cache.

**Decode bursts.**  The per-token serving loop used to dispatch one jitted
step per token and synchronize with the host every step (``np.asarray`` of
the argmax) — framework dispatch, not math, dominated small per-step work
(the paper §5.5; Quinn & Ballesteros arXiv:1804.05038 for CPU NMT).  All
three decode paths now run **bursts of up to ``burst_len`` steps entirely
on device** inside one jitted ``lax.while_loop``: argmax, EOS masking,
per-row budget countdown, and a ``(rows, K)`` token ring buffer live in the
loop carry, and the host is touched only at burst boundaries, where the
scheduler drains tokens, releases finished slots and refills them.  A burst
exits early once every row is finished, so ``burst_len=1`` exactly
reproduces the per-step loop (token-identical for every ``burst_len``);
rows that finish mid-burst keep computing but are masked to EOS — the
utilization cost ``benchmarks/bench_decode_burst.py`` quantifies against
the saved host round trips.  Burst lengths are bucketed to powers of two
(``data.sorting.next_pow2``): the compiled ring-buffer width is the bucket,
the *actual* step cap is a device scalar, so sweeping ``burst_len`` costs
O(log K) compiles.

**Fused admission.**  Decode bursts left one host dispatch per admission
round: refilling freed slots ran a separate jitted prefill and drained its
first token before the next burst could start.  With
``fused_admission=True`` (the default) an admission round is folded *into*
the burst program: the padded admitted sources ride along as device
inputs, and the program encodes them, splices their cross-K/V into the
grid rows (``encdec.splice_prefill``), resets the spliced rows' KV
cursors, seeds BOS tokens, and then runs the decode ``while_loop`` — the
spliced rows' first step *is* the BOS prefill step, so a serve round is
exactly one dispatch and one device→host sync whether or not it admitted.
Beam groups additionally encode each admitted source **once** and
broadcast the memory/cross-KV across the group's ``beam`` rows (the old
side-batch prefill tiled the source ``beam`` times — ``beam×`` encoder
FLOPs for identical rows); the group's first-step top-k falls out of the
shared beam step by seeding row 0 with score 0 and rows ``1..B-1`` with
``-1e30``, which reproduces ``generate_beam``'s beam-0 top-k exactly.
Output is token-identical to the unfused path (and therefore to
per-request ``generate``/``generate_beam``) for every ``burst_len``, FP
and INT8 cache; ``ServeResult.prefill_dispatches`` stays 0 and
``encoder_tokens`` drops ``beam×`` for beam serving.

``burst_len="auto"`` puts the step cap under the
``burst_control.AdaptiveBurst`` controller: the compiled ring width stays
pinned at the max power-of-two bucket while the device-scalar cap
shrinks/grows between bursts as measured mid-burst EOS waste crosses the
measured per-sync cost — adapting never triggers a new compile.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.data.sorting import next_pow2
from repro.data.synthetic import EOS, pad_batch
from repro.distributed.fault import StepWatchdog
from repro.distributed.sharding import named_shardings
from repro.models import kv_cache as kvc
from repro.serving.sharding import decode_state_shardings, mesh_axis_sizes, \
    tp_degree
from repro.serving.burst_control import AdaptiveBurst
from repro.serving.chaos import ChaosSchedule
from repro.serving.preemption import SpilledRequest, SpillStore, pick_victims
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ContinuousScheduler, Request, \
    pad_rows_pow2

# new-group beam-score seed: row 0 scores 0, rows 1..B-1 score so low that
# the shared beam step's group top-k can only draw candidates from row 0 —
# which reproduces generate_beam's first-step "top-k over beam-0 logits"
# without a special-cased first step (see _make_fused_beam_serve_burst)
BEAM_SEED_NEG = np.float32(-1e30)

# compiled ring-buffer bucket for burst_len="auto": the AdaptiveBurst cap
# moves as a device scalar inside [1, AUTO_MAX_BURST] — one compile total
AUTO_MAX_BURST = 64


def _spec_accept(d: jax.Array, v: jax.Array, remaining: jax.Array,
                 eos: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Speculative-decoding acceptance: longest agreeing prefix + bonus.

    ``d``: (B, s) drafted tokens; ``v``: (B, s+1) verifier greedy tokens
    over the same positions (``v[:, j]`` is what sequential decode would
    emit after accepting ``j`` drafts); ``remaining``: (B,) per-row token
    budgets (0 ⇔ inactive row).

    Returns ``(stop, hit_eos, accepted)``: ``stop`` (B,) is how many of
    ``v[:, :stop]`` this macro-step emits — the longest prefix where the
    draft agrees with the verifier, plus the verifier's first correction
    token, clamped by the first verifier EOS (emitted, then the row stops,
    exactly like the sequential loop) and by the budget; ``hit_eos``
    marks rows whose emitted window ends in EOS; ``accepted`` counts the
    emitted tokens that came from the draft (the acceptance-rate
    numerator).  Rows with ``remaining == 0`` emit nothing.
    """
    s = d.shape[1]
    active = remaining > 0
    agree = jnp.cumprod((d == v[:, :s]).astype(jnp.int32), axis=1)
    a = jnp.sum(agree, axis=1)                  # longest agreeing prefix
    cand = a + 1                                # + verifier's correction
    idx = jnp.arange(s + 1, dtype=jnp.int32)[None, :]
    eos_first = jnp.min(jnp.where(v == eos, idx, s + 1), axis=1)
    stop = jnp.minimum(jnp.minimum(cand, eos_first + 1), remaining)
    stop = jnp.where(active, stop, 0)
    hit_eos = active & (eos_first + 1 <= jnp.minimum(cand, remaining))
    accepted = jnp.minimum(a, stop)
    return stop, hit_eos, accepted


@dataclasses.dataclass
class GenerationResult:
    tokens: List[np.ndarray]          # per-sequence generated ids (no EOS)
    steps: int
    prefill_s: float
    decode_s: float
    host_syncs: int = 0               # device→host round trips (prefill + bursts)
    speculative_k: int = 0            # draft window (0 = plain decode)
    draft_tokens: int = 0             # tokens proposed by the draft model
    accepted_tokens: int = 0          # drafted tokens the verifier kept

    @property
    def acceptance_rate(self) -> float:
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def n_tokens(self) -> int:
        return int(sum(len(t) for t in self.tokens))

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.total_s, 1e-9)

    @property
    def decode_steps_per_s(self) -> float:
        # steps counts grid columns; the first column is emitted by prefill,
        # outside the decode_s window, so it is discounted here
        return max(self.steps - 1, 0) / max(self.decode_s, 1e-9)


@dataclasses.dataclass
class ServeResult:
    """Outcome of one continuous-batching serve.

    With ``beam > 1`` every request occupied a group of ``beam`` decode
    rows: ``n_slots`` still counts *rows*, ``busy_slot_steps`` counts all
    rows of a busy group (so ``utilization`` stays an occupied-row
    fraction of the computed grid), and each ``Request.tokens`` holds the
    group's *winning* hypothesis (``Request.score`` its length-penalized
    log-prob).
    """

    requests: List[Request]           # submission order, lifecycle filled in
    n_slots: int
    decode_steps: int
    busy_slot_steps: int              # Σ over steps of occupied rows
    prefill_rounds: int               # admission rounds (fused or not)
    wall_s: float
    host_syncs: int = 0               # device→host round trips (prefill + bursts)
    burst_len: int = 1                # final step cap (adapts when auto_burst)
    beam: int = 1                     # rows per request group (1 = greedy)
    prefill_dispatches: int = 0       # host-dispatched prefill programs
    #                                   (0 ⇔ admissions rode the burst program)
    encoder_tokens: int = 0           # encoder row-tokens computed for
    #                                   admissions (beam× lower when fused)
    fused_admission: bool = True
    auto_burst: bool = False          # burst_len ran under AdaptiveBurst
    paged: bool = False               # KV cache was paged (block tables)
    page_size: int = 0
    pages_in_use: int = 0             # allocator pages still held at the end
    page_hwm: int = 0                 # peak concurrent pages over the serve
    reorder_bytes: int = 0            # total bytes beam reorders moved
    #                                   (slab gathers unpaged; block-table
    #                                   permutation + partial-page copy paged)
    # cross-request prefix cache (per-serve deltas; the cache itself —
    # tree, chains, pool — persists on the engine across serves)
    prefix_cache: bool = False
    prefix_hits: int = 0              # admissions that skipped the encoder
    prefix_misses: int = 0
    prefix_inserts: int = 0           # misses that cached their encode
    prefix_evictions: int = 0
    prefix_hit_pages: int = 0         # chain pages hits read instead of wrote
    prefix_pages_allocated: int = 0   # chain pages reserved by this serve
    prefix_chains: int = 0            # chains resident at serve end
    # overload machinery (preempt-by-page-spill / deadline admission /
    # chunked prefill — all zero on a serve that never hit pressure)
    overcommit: float = 1.0           # reserve cap ÷ physical pool size
    preemptions: int = 0              # evictions (chaos-forced + pressure)
    spill_events: int = 0             # KV page sets copied to host
    restore_events: int = 0           # spills re-spliced on re-admission
    spilled_bytes: int = 0            # cumulative host bytes spilled
    straggler_rounds: int = 0         # watchdog-flagged burst rounds
    chunked_admissions: int = 0       # requests whose prefill was staged
    chunk_rounds: int = 0             # staged encoder dispatches
    peak_running: int = 0             # max concurrent running requests
    rejected: int = 0                 # requests shed (deadline unmeetable)
    deadline_misses: int = 0          # shed + finished past their deadline
    free_lwm: int = 0                 # page free-list low-water mark
    fragmentation: float = 0.0        # final free-list scatter in [0, 1]
    # self-speculative decoding (draft with draft_quant, verify with the
    # engine quant context — greedy output stays bit-identical to the
    # non-speculative path by construction)
    speculative_k: int = 0            # draft window (0 = speculation off)
    draft_tokens: int = 0             # tokens proposed by the draft passes
    accepted_tokens: int = 0          # drafted tokens the verifier kept
    # multi-chip serving: tensor-parallel burst (mesh on the engine) and/or
    # data-parallel replicas (ReplicaRouter sets ``replicas`` post-merge)
    mesh_shape: Tuple[int, ...] = ()  # mesh axis sizes, () = unsharded
    tp_degree: int = 1                # "model"-axis width the burst ran at
    replicas: int = 1                 # engine replicas behind the router
    collective_bytes_per_step: int = 0  # predicted per-device wire bytes
    #                                     per decode step (ring all-reduce)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verifier accepted (0 when
        speculation was off — no drafts were proposed)."""
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def n_groups(self) -> int:
        """Request groups the decode grid holds (== n_slots for greedy)."""
        return self.n_slots // self.beam

    @property
    def n_tokens(self) -> int:
        return int(sum(len(r.tokens) for r in self.requests))

    @property
    def utilization(self) -> float:
        """Occupied-row fraction of the decode grid actually computed.

        Beam-group aware: a busy group accounts for all ``beam`` of its
        rows.  ``n_slots`` is the *computed* grid — rows a non-dividing
        ``beam`` would strand are trimmed before the serve (``n_slots``
        here is already the trimmed row count), so the starvation cost of
        a coarse beam shows up as fewer group servers (and in
        ``simulate_continuous(..., beam=B)``'s ``idle_rows``), not as a
        deflated utilization.
        """
        return self.busy_slot_steps / max(self.n_slots * self.decode_steps, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_steps_per_s(self) -> float:
        return self.decode_steps / max(self.wall_s, 1e-9)

    def tokens_for(self, req_id: int) -> np.ndarray:
        """Generated ids for one request — the winning hypothesis when the
        serve ran with ``beam > 1`` (one row per request otherwise)."""
        for r in self.requests:
            if r.req_id == req_id:
                return np.asarray(r.tokens, np.int32)
        raise KeyError(req_id)

    def metrics(self) -> Dict[str, float]:
        first = [r.first_token_latency_s for r in self.requests
                 if r.first_token_latency_s is not None]
        total = [r.total_latency_s for r in self.requests
                 if r.total_latency_s is not None]
        return {
            "n_requests": float(len(self.requests)),
            "n_tokens": float(self.n_tokens),
            "beam": float(self.beam),
            "n_groups": float(self.n_groups),
            "wall_s": self.wall_s,
            "tokens_per_s": self.tokens_per_s,
            "utilization": self.utilization,
            "decode_steps": float(self.decode_steps),
            "decode_steps_per_s": self.decode_steps_per_s,
            "host_syncs": float(self.host_syncs),
            "burst_len": float(self.burst_len),
            "prefill_rounds": float(self.prefill_rounds),
            "prefill_dispatches": float(self.prefill_dispatches),
            "encoder_tokens": float(self.encoder_tokens),
            "paged": float(self.paged),
            "pages_in_use": float(self.pages_in_use),
            "page_hwm": float(self.page_hwm),
            "reorder_bytes": float(self.reorder_bytes),
            "prefix_cache": float(self.prefix_cache),
            "prefix_hits": float(self.prefix_hits),
            "prefix_misses": float(self.prefix_misses),
            "prefix_inserts": float(self.prefix_inserts),
            "prefix_evictions": float(self.prefix_evictions),
            "prefix_hit_pages": float(self.prefix_hit_pages),
            "prefix_pages_allocated": float(self.prefix_pages_allocated),
            "prefix_chains": float(self.prefix_chains),
            "prefix_hit_rate": (self.prefix_hits /
                                max(self.prefix_hits + self.prefix_misses, 1)),
            "overcommit": float(self.overcommit),
            "preemptions": float(self.preemptions),
            "spill_events": float(self.spill_events),
            "restore_events": float(self.restore_events),
            "spilled_bytes": float(self.spilled_bytes),
            "straggler_rounds": float(self.straggler_rounds),
            "chunked_admissions": float(self.chunked_admissions),
            "chunk_rounds": float(self.chunk_rounds),
            "peak_running": float(self.peak_running),
            "rejected": float(self.rejected),
            "deadline_misses": float(self.deadline_misses),
            "free_lwm": float(self.free_lwm),
            "fragmentation": float(self.fragmentation),
            "speculative_k": float(self.speculative_k),
            "draft_tokens": float(self.draft_tokens),
            "accepted_tokens": float(self.accepted_tokens),
            "acceptance_rate": self.acceptance_rate,
            "tp_degree": float(self.tp_degree),
            "replicas": float(self.replicas),
            "collective_bytes_per_step":
                float(self.collective_bytes_per_step),
            "first_token_latency_mean_s": float(np.mean(first)) if first else 0.0,
            "first_token_latency_p95_s":
                float(np.percentile(first, 95)) if first else 0.0,
            "total_latency_mean_s": float(np.mean(total)) if total else 0.0,
            "total_latency_p95_s":
                float(np.percentile(total, 95)) if total else 0.0,
        }


class ServingEngine:
    def __init__(self, model, params, *, quant: QuantContext = FP_CONTEXT,
                 max_len: int = 256, eos_id: int = EOS,
                 donate_state: bool = True,
                 burst_len: Union[int, str] = 8,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 admission_enc_bucket: str = "max",
                 prefix_cache: bool = False,
                 prefix_pages: int = 256,
                 prefix_page_size: Optional[int] = None,
                 draft_quant: Optional[QuantContext] = None,
                 mesh=None):
        self.model = model
        # tensor-parallel serving: with a ("data","model") mesh the burst
        # programs compile as ONE SPMD program — GSPMD places the per-layer
        # all-reduces inside the lax.while_loop, so a serve round stays one
        # dispatch + one host sync.  We only *place* the inputs: weights by
        # the training sharding rules (fsdp off — serving replicates
        # non-tensor dims), the decode state by serving.sharding (K/V pools
        # split on heads, host-facing buffers replicated).
        self.mesh = mesh
        self.tp = tp_degree(mesh)
        if mesh is not None:
            params = jax.device_put(
                params, named_shardings(params, mesh, tensor="model",
                                        fsdp=None,
                                        kv_heads=model.cfg.n_kv_heads))
        self.params = params
        self.quant = quant
        # speculative decoding draft context: the k cheap draft steps run
        # with these weights/activations (e.g. INT8 while ``quant`` is FP —
        # the paper's <0.5% quality gap is exactly the regime where such
        # drafts are accepted almost always).  None → draft with ``quant``
        # itself (degenerate self-speculation, acceptance 1.0).  The KV
        # cache layout always follows ``quant`` — the verifier owns every
        # cache entry past the accepted cursor, which is what makes greedy
        # output bit-identical to the non-speculative ``quant`` path.
        self.draft_quant = quant if draft_quant is None else draft_quant
        self.max_len = max_len
        self.eos_id = eos_id
        if burst_len != "auto":
            burst_len = int(burst_len)
            if burst_len < 1:
                raise ValueError(f"burst_len must be ≥ 1, got {burst_len}")
        self.burst_len = burst_len
        self._donate_state = donate_state
        # paged KV cache (serve() paths): fixed-size pages + block tables;
        # max_len must be a page multiple so the paged logical view has
        # exactly the contiguous shape (bit-identical numerics).
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.n_pages = n_pages
        if self.paged and max_len % self.page_size:
            raise ValueError(f"paged cache needs max_len % page_size == 0, "
                             f"got {max_len} % {self.page_size}")
        if admission_enc_bucket not in ("max", "exact"):
            raise ValueError("admission_enc_bucket must be 'max' or "
                             f"'exact', got {admission_enc_bucket!r}")
        self.admission_enc_bucket = admission_enc_bucket
        self._enc_bucket_hwm = 0
        # cross-request prefix cache: persists ACROSS serve() calls on this
        # engine (the pool's page granularity makes its device shape
        # independent of any one serve's enc_len or grid size).  Built
        # lazily so engines that never enable it pay nothing.
        self.prefix_cache_default = bool(prefix_cache)
        self.prefix_pages = int(prefix_pages)
        self.prefix_page_size = int(prefix_page_size or page_size)
        self._prefix_cache_obj: Optional[PrefixCache] = None
        self._prefix_pool: Optional[Tuple[jax.Array, jax.Array]] = None
        self._pool_insert_jit: Optional[Callable] = None
        self._hit_splice_jits: Dict[int, Callable] = {}

        self._prefill = jax.jit(
            lambda p, b, s: model.prefill(p, b, s, quant=quant))
        # continuous-batching row splice: scatter a prefilled side-batch into
        # the long-lived decode state.  Donates the old state/token buffers —
        # the caller always rebinds to the returned ones.
        self._insert = jax.jit(self._insert_rows, donate_argnums=(0, 2))
        # paged variant (unfused admission): the side batch prefills into a
        # plain contiguous cache, then its rows are page-chunked into the
        # destination rows' reservations and the block tables installed
        self._insert_paged = jax.jit(self._insert_rows_paged,
                                     donate_argnums=(0, 2))
        # burst programs, keyed by compiled ring-buffer width (greedy) or
        # (width, beam) — power-of-two bucketed, so O(log K) entries.  The
        # fused-admission variants additionally respecialize (inside
        # jax.jit's own shape cache) per pow2 admission width × enc_len.
        self._burst_jits: Dict[int, Callable] = {}
        self._beam_burst_jits: Dict[Tuple[int, int], Callable] = {}
        self._beam_serve_jits: Dict[Tuple[int, int], Callable] = {}
        self._fused_burst_jits: Dict[int, Callable] = {}
        self._fused_beam_serve_jits: Dict[Tuple[int, int], Callable] = {}
        # speculative burst programs, keyed (ring width, speculative_k)
        self._spec_burst_jits: Dict[Tuple[int, int], Callable] = {}
        self._spec_fused_burst_jits: Dict[Tuple[int, int], Callable] = {}
        # overload machinery: preempt-by-page-spill gathers/scatters,
        # overcommit page growth, and chunked-prefill staged encodes —
        # keyed by row count (1 greedy, group width beam) / encoder layer
        self._spill_jits: Dict[int, Callable] = {}
        self._resume_jits: Dict[int, Callable] = {}
        self._grow_jits: Dict[int, Callable] = {}
        self._chunk_splice_jits: Dict[int, Callable] = {}
        self._stage_begin_jit: Optional[Callable] = None
        self._stage_finish_jit: Optional[Callable] = None
        self._stage_layer_jits: Dict[int, Callable] = {}

    # ------------------------------------------------------------------ util
    def _init_state(self, batch_size: int):
        return self._shard_state(self.model.init_decode_state(
            batch_size, self.max_len, quantized=self.quant.quantize_kv))

    def _shard_state(self, state):
        """Place a fresh decode state on the engine mesh: K/V pools (self,
        cross, prefix) split on the heads axis, block tables / cursors /
        token buffers replicated.  No-op without a mesh."""
        if self.mesh is None:
            return state
        cfg = self.model.cfg
        return jax.device_put(state, decode_state_shardings(
            state, self.mesh, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd))

    def _mesh_result_fields(self, rows: int) -> Dict[str, Any]:
        """ServeResult kwargs describing the mesh the serve ran on."""
        if self.mesh is None:
            return {}
        from repro.launch.roofline import decode_collective_bytes
        cfg = self.model.cfg
        return dict(
            mesh_shape=mesh_axis_sizes(self.mesh),
            tp_degree=self.tp,
            collective_bytes_per_step=decode_collective_bytes(
                n_layers=cfg.n_layers, d_model=cfg.d_model, rows=rows,
                tp=self.tp, act_bytes=cfg.activation_dtype.itemsize,
                vocab=cfg.vocab))

    def _resolve_burst(self, burst_len: Optional[Union[int, str]]
                       ) -> Union[int, str]:
        """Resolve a call-site burst length: an int cap, or the sentinel
        ``"auto"`` (serve puts the cap under :class:`AdaptiveBurst`)."""
        k = self.burst_len if burst_len is None else burst_len
        if isinstance(k, str):
            if k == "auto":
                return "auto"
            raise ValueError(
                f"burst_len must be an int ≥ 1 or 'auto', got {k!r}")
        k = int(k)
        if k < 1:
            raise ValueError(f"burst_len must be ≥ 1, got {k}")
        return k

    def _check_overload_args(self, overcommit: float,
                             prefill_chunk: Optional[int],
                             chaos: Optional[ChaosSchedule],
                             fused_admission: bool) -> None:
        if overcommit < 1.0:
            raise ValueError(f"overcommit must be >= 1.0, got {overcommit}")
        if overcommit > 1.0 and not self.paged:
            raise ValueError("overcommit needs the paged KV cache "
                             "(preempt-by-page-spill backs it)")
        if chaos is not None and not self.paged:
            raise ValueError("chaos preemption needs the paged KV cache "
                             "(spill/restore move pages)")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, "
                                 f"got {prefill_chunk}")
            if not fused_admission:
                raise ValueError("prefill_chunk requires fused_admission "
                                 "(staged encodes ride the fused rounds)")

    def _burst_controller(self, K: Union[int, str]
                          ) -> Optional[AdaptiveBurst]:
        """An :class:`AdaptiveBurst` when ``K == "auto"``, else None."""
        if K != "auto":
            return None
        start = self.burst_len if isinstance(self.burst_len, int) else 8
        return AdaptiveBurst(start=start, max_burst=AUTO_MAX_BURST)

    def compiled_variants(self) -> Optional[int]:
        """Compiled burst-program variants held by this engine — the outer
        pow2-bucketed builders times jax.jit's inner shape cache (fused
        admission respecializes per admission width × enc_len).  The
        ``admission_enc_bucket`` regression in ``bench_continuous.py``
        asserts this stops growing with the source-length mix.

        Returns None when the jax version exposes no per-function cache
        introspection (``_cache_size``), so callers skip the comparison
        instead of asserting on degenerate equal counts.
        """
        n = 0
        for d in (self._burst_jits, self._beam_burst_jits,
                  self._beam_serve_jits, self._fused_burst_jits,
                  self._fused_beam_serve_jits):
            for fn in d.values():
                size = getattr(fn, "_cache_size", None)
                if not callable(size):
                    return None
                n += size()
        return n

    def _enc_bucket(self, reqs: Sequence[Request], m: int) -> int:
        """Admission ``enc_len`` bucket for one serve.

        ``admission_enc_bucket="exact"`` keeps the historical behaviour —
        the serve's max source length rounded to ``pad_to_multiple`` — so
        every distinct length mix compiles its own burst-program variant
        (the cross-K/V state buffers and fused admission inputs are all
        ``enc_len``-shaped).  ``"max"`` (default) pads to a power-of-two
        bucket held monotone across serves on this engine: a sweep over
        many source-length mixes converges onto ONE variant per (ring
        bucket × admission width) once the largest bucket has been seen.
        Padding is masked (hard ``where`` on ``src_lengths``), so tokens
        are identical either way.
        """
        enc_len = max(r.n_src_tokens for r in reqs)
        enc_len = ((enc_len + m - 1) // m) * m
        if self.admission_enc_bucket == "exact":
            return enc_len
        self._enc_bucket_hwm = max(self._enc_bucket_hwm, next_pow2(enc_len))
        return self._enc_bucket_hwm

    # ------------------------------------------------------------- paged util
    @property
    def _max_pages(self) -> int:
        return self.max_len // self.page_size

    def _make_allocator(self, n_rows: int,
                        overcommit: float = 1.0) -> kvc.PageAllocator:
        """Fresh page pool for one serve: ``n_pages`` from the constructor,
        or contiguous-equivalent capacity (every grid row could hold
        ``max_len`` tokens) when unset.  ``overcommit`` scales the
        *virtual* reservation cap past the physical pool (preemption by
        page spill covers the gap)."""
        n_pages = self.n_pages or n_rows * self._max_pages
        return kvc.PageAllocator(n_pages, self.page_size,
                                 overcommit_limit=overcommit)

    def _initial_pages(self, req: Request, rows: int, hint: int) -> int:
        """Pages physically allocated at (re-)admission under overcommit:
        enough to hold what the request already decoded (its spill cursor
        when resuming) plus one max-length burst — growth covers the rest,
        round by round."""
        have = 0
        if req.spill is not None:
            have = int(np.max(req.spill.lengths))
        cap_tok = min(req.max_new_tokens, self.max_len)
        return rows * kvc.pages_per_row(min(have + hint, cap_tok),
                                        self.page_size)

    def _pages_per_request(self, req: Request, rows: int) -> int:
        """Worst-case reservation: the request's full decode budget, per
        *live* row (parked rows of a narrow beam reserve nothing)."""
        return rows * kvc.pages_per_row(
            min(req.max_new_tokens, self.max_len), self.page_size)

    def _page_rows(self, reqs: Sequence[Request], rows_per_req: int,
                   n_req_rows: int, sentinel: int,
                   widths: Optional[Sequence[int]] = None) -> np.ndarray:
        """Shape admitted requests' page reservations as device input:
        (n_req_rows × rows_per_req, maxP) int32, sentinel-padded — padding
        requests, parked rows, and each row's tail past its reservation
        all read as sentinel (writes there drop)."""
        maxP = self._max_pages
        out = np.full((n_req_rows * rows_per_req, maxP), sentinel, np.int32)
        for i, r in enumerate(reqs):
            live = widths[i] if widths is not None else rows_per_req
            flat = np.asarray(r.pages, np.int32)
            if flat.size == 0:
                continue
            ppr = flat.size // live
            per_row = flat.reshape(live, ppr)
            out[i * rows_per_req:i * rows_per_req + live, :ppr] = per_row
        return out

    # ------------------------------------------------- preempt-by-page-spill
    def _spill_fn(self, n_rows: int) -> Callable:
        """Jitted spill gather: linearize ``n_rows`` paged cache rows into
        logical ``(L, W, cap, …)`` views (INT8 payload + scales verbatim —
        no requantization round trip) plus cursors, current tokens, and
        cross-K/V.  One dispatch + one host sync per preemption; junk past
        each cursor rides along and is masked on restore exactly like any
        partially filled row.  NOT donating: the live state survives."""
        fn = self._spill_jits.get(n_rows)
        if fn is None:
            def spill(state, tokens, rows):
                cache = state["cache"]
                P = cache.n_pages
                cap = cache.max_pages * cache.page_size
                tb = jnp.clip(cache.block_tables[rows], 0, P - 1)

                def lin(pool):
                    if pool is None:
                        return None
                    got = pool[:, tb]          # (L, W, maxP, ps, …)
                    return got.reshape((pool.shape[0], n_rows, cap)
                                       + pool.shape[3:])

                return (lin(cache.k), lin(cache.v), lin(cache.k_scale),
                        lin(cache.v_scale), cache.lengths[rows],
                        tokens[rows], state["cross_k"][:, rows],
                        state["cross_v"][:, rows],
                        state["src_lengths"][rows])

            fn = jax.jit(spill)
            self._spill_jits[n_rows] = fn
        return fn

    def _resume_fn(self, n_rows: int) -> Callable:
        """Jitted resume scatter: the spilled logical rows become a host-
        built contiguous side batch and re-enter through the SAME paged
        splice admission uses (``kv_cache.insert_rows_paged``), plus the
        cross-K/V / source-length / current-token scatters — so a resumed
        request is indistinguishable from one that was never preempted."""
        fn = self._resume_jits.get(n_rows)
        if fn is None:
            def resume(state, tokens, slots, pages, k, v, ks, vs, lengths,
                       row_tokens, ck, cv, slens):
                sub = kvc.KVCache(k=k, v=v, k_scale=ks, v_scale=vs,
                                  lengths=lengths)
                out = dict(state)
                out["cache"] = kvc.insert_rows_paged(state["cache"], sub,
                                                     slots, pages)
                out["cross_k"] = state["cross_k"].at[:, slots].set(
                    ck.astype(state["cross_k"].dtype), mode="drop")
                out["cross_v"] = state["cross_v"].at[:, slots].set(
                    cv.astype(state["cross_v"].dtype), mode="drop")
                out["src_lengths"] = state["src_lengths"].at[slots].set(
                    slens, mode="drop")
                tokens = tokens.at[slots].set(row_tokens, mode="drop")
                return out, tokens

            donate = (0, 1) if self._donate_state else ()
            fn = jax.jit(resume, donate_argnums=donate)
            self._resume_jits[n_rows] = fn
        return fn

    def _grow_fn(self, n_rows: int) -> Callable:
        """Jitted page growth: install freshly allocated page ids into
        ``n_rows`` rows' block tables.  ``upd`` is (n_rows, maxP) int32
        with -1 = keep; new slots are written to BOTH ``block_tables`` and
        ``own_pages`` — a grown slot is owned by construction, which is
        the copy-on-write invariant every beam reorder relies on."""
        fn = self._grow_jits.get(n_rows)
        if fn is None:
            def grow(state, rows, upd):
                cache = state["cache"]
                new_t = jnp.where(upd >= 0, upd, cache.block_tables[rows])
                new_o = jnp.where(upd >= 0, upd, cache.own_pages[rows])
                out = dict(state)
                out["cache"] = dataclasses.replace(
                    cache,
                    block_tables=cache.block_tables.at[rows].set(
                        new_t, mode="drop"),
                    own_pages=cache.own_pages.at[rows].set(
                        new_o, mode="drop"))
                return out

            donate = (0,) if self._donate_state else ()
            fn = jax.jit(grow, donate_argnums=donate)
            self._grow_jits[n_rows] = fn
        return fn

    # ---------------------------------------------------- chunked prefill
    def _stage_fns(self) -> Tuple[Callable, Callable]:
        """Jitted begin/finish of a depth-staged encode (chunked prefill).
        The bidirectional encoder cannot chunk over source *tokens*, so a
        long source's encode is spread over *layers*: one width-1 encoder
        layer per serving round rides between decode bursts instead of one
        monolithic width-W encode stalling a whole round."""
        if self._stage_begin_jit is None:
            model, quant = self.model, self.quant
            self._stage_begin_jit = jax.jit(
                lambda p, src, lens: model.encode_staged_begin(
                    p, {"src_tokens": src, "src_lengths": lens}))
            self._stage_finish_jit = jax.jit(
                lambda p, x, lens: model.encode_staged_finish(
                    p, x, src_lengths=lens, quant=quant))
        return self._stage_begin_jit, self._stage_finish_jit

    def _stage_layer_fn(self, layer_idx: int) -> Callable:
        fn = self._stage_layer_jits.get(layer_idx)
        if fn is None:
            model, quant = self.model, self.quant
            fn = jax.jit(lambda p, x, lens: model.encode_staged_layer(
                p, x, layer_idx, src_lengths=lens, quant=quant))
            self._stage_layer_jits[layer_idx] = fn
        return fn

    def _chunk_splice_fn(self, group: int) -> Callable:
        """Jitted completion of a staged encode: splice the finished
        cross-K/V into the request's grid rows and seed BOS — exactly the
        fused-admission splice, one round later than a monolithic encode
        would have landed it."""
        fn = self._chunk_splice_jits.get(group)
        if fn is None:
            model = self.model

            def csplice(state, tokens, ck, cv, slens, base_rows, extra):
                state = model.splice_prefill(state, ck, cv, slens,
                                             base_rows, group=group,
                                             pages=extra.get("pages"))
                rows = kvc.group_rows(jnp.asarray(base_rows, jnp.int32),
                                      group)
                tokens = tokens.at[rows].set(0, mode="drop")       # BOS
                return state, tokens

            donate = (0, 1) if self._donate_state else ()
            fn = jax.jit(csplice, donate_argnums=donate)
            self._chunk_splice_jits[group] = fn
        return fn

    # ------------------------------------------------------------ prefix cache
    def _ensure_prefix_cache(self) -> PrefixCache:
        """The engine-lifetime prefix cache + its device-side chain pool.

        The pool is a pair of ``(L, prefix_pages, ps, HKV, dh)`` arrays in
        the *activation* dtype — NOT the decode cache's (possibly int8)
        dtype: a chain must read back bit-identical to a fresh
        ``encode_cross_kv``, and the quantize→dequantize round trip of the
        INT8 decode pool would break the token-identity gate.  During a
        serve the arrays ride inside the decode state (so fused bursts
        scatter/gather them in-program and donation recycles their
        buffers); between serves the engine re-binds them here.
        """
        if self._prefix_cache_obj is None:
            self._prefix_cache_obj = PrefixCache(
                kvc.PageAllocator(self.prefix_pages, self.prefix_page_size))
            cfg = self.model.cfg
            shape = (cfg.n_layers, self.prefix_pages, self.prefix_page_size,
                     cfg.n_kv_heads, cfg.hd)
            self._prefix_pool = (jnp.zeros(shape, cfg.activation_dtype),
                                 jnp.zeros(shape, cfg.activation_dtype))
        return self._prefix_cache_obj

    def _resolve_prefix_cache(self, prefix_cache: Optional[bool]
                              ) -> Optional[PrefixCache]:
        use = (self.prefix_cache_default if prefix_cache is None
               else bool(prefix_cache))
        return self._ensure_prefix_cache() if use else None

    def _prefix_result_fields(self, pc: Optional[PrefixCache],
                              stats0) -> Dict[str, Any]:
        """ServeResult kwargs: per-serve deltas of the persistent stats."""
        if pc is None:
            return {}
        s = pc.stats
        return dict(prefix_cache=True,
                    prefix_hits=s.hits - stats0.hits,
                    prefix_misses=s.misses - stats0.misses,
                    prefix_inserts=s.inserts - stats0.inserts,
                    prefix_evictions=s.evictions - stats0.evictions,
                    prefix_hit_pages=s.hit_pages - stats0.hit_pages,
                    prefix_pages_allocated=(s.pages_allocated
                                            - stats0.pages_allocated),
                    prefix_chains=pc.n_chains)

    @staticmethod
    def _overload_result_fields(overcommit, preempt_count, store, watchdog,
                                sched, reqs, allocator, peak_running,
                                chunked_admissions, chunk_rounds
                                ) -> Dict[str, Any]:
        """ServeResult kwargs for the overload machinery counters."""
        misses = len(sched.rejected) + sum(
            1 for r in reqs
            if (r.status == "finished" and r.deadline_s is not None
                and r.finish_s is not None and r.finish_s > r.deadline_s))
        return dict(
            overcommit=overcommit,
            preemptions=preempt_count,
            spill_events=store.spill_events,
            restore_events=store.restore_events,
            spilled_bytes=store.spilled_bytes,
            straggler_rounds=len(watchdog.straggler_steps),
            chunked_admissions=chunked_admissions,
            chunk_rounds=chunk_rounds,
            peak_running=peak_running,
            rejected=len(sched.rejected),
            deadline_misses=misses,
            free_lwm=allocator.free_lwm if allocator else 0,
            fragmentation=allocator.fragmentation if allocator else 0.0)

    def _pool_insert_fn(self) -> Callable:
        """Jitted unfused-path pool insert: scatter a prefilled side
        batch's cross-K/V into reserved chain pages (fused admission does
        the same scatter inside the burst program)."""
        if self._pool_insert_jit is None:
            def fn(state, ck, cv, pages):
                out = dict(state)
                out["prefix_k"] = kvc.insert_chain_pages(
                    state["prefix_k"], ck, pages)
                out["prefix_v"] = kvc.insert_chain_pages(
                    state["prefix_v"], cv, pages)
                return out
            donate = (0,) if self._donate_state else ()
            self._pool_insert_jit = jax.jit(fn, donate_argnums=donate)
        return self._pool_insert_jit

    def _hit_splice_fn(self, group: int) -> Callable:
        """Jitted unfused-path hit splice: gather cached chains from the
        prefix pool and splice them into the admitted rows — no encoder.
        The rows' first token is deferred to the next burst (BOS seed),
        exactly the fused-admission seeding, so token *streams* stay
        identical (per-request content is pacing-independent)."""
        fn = self._hit_splice_jits.get(group)
        if fn is None:
            model = self.model

            def splice(state, tokens, hit_pages, hit_lens, hit_rows, extra):
                enc_len = state["cross_k"].shape[2]
                hk = kvc.gather_chain_pages(state["prefix_k"], hit_pages,
                                            enc_len)
                hv = kvc.gather_chain_pages(state["prefix_v"], hit_pages,
                                            enc_len)
                state = model.splice_prefill(
                    state, hk, hv, hit_lens, hit_rows, group=group,
                    pages=extra.get("dec_pages"))
                rows = kvc.group_rows(jnp.asarray(hit_rows, jnp.int32),
                                      group)
                tokens = tokens.at[rows].set(0, mode="drop")       # BOS
                return state, tokens

            donate = (0, 1) if self._donate_state else ()
            fn = jax.jit(splice, donate_argnums=donate)
            self._hit_splice_jits[group] = fn
        return fn

    @staticmethod
    def _beam_gather_state(state: Dict[str, Any], idx: jax.Array):
        """Reorder every batch-major leaf of the decode state (paper §5.3).

        Paged cache: the reorder degenerates to a block-table permutation
        plus one partial-page copy (``kv_cache.gather_beams_paged``) — and
        the cross-K/V / source-length leaves are *skipped entirely*: beam
        reorders only ever permute rows within a group, and a group's rows
        share one broadcast encoder memory, so that gather is an identity
        by construction.  The cache payload slab stops moving.
        """
        cache = state.get("cache")
        if isinstance(cache, kvc.PagedKVCache):
            out = dict(state)
            out["cache"] = kvc.gather_beams_paged(cache, idx)
            return out

        def gather(leaf):
            return jnp.take(leaf, idx, axis=0)

        out = {}
        for k, v in state.items():
            if k == "cache" and isinstance(v, kvc.KVCache):
                out[k] = kvc.gather_beams(v, idx)
            elif v is None:
                out[k] = None
            elif k in ("cross_k", "cross_v"):
                # layer-major (L, B, S, H, dh): the batch axis is 1
                out[k] = jnp.take(v, idx, axis=1)
            elif k in ("prefix_k", "prefix_v"):
                # chain page pools have no batch axis — beam reorders
                # permute rows, and chains are read-only row-agnostic data
                out[k] = v
            else:
                out[k] = jax.tree_util.tree_map(gather, v)
        return out

    @staticmethod
    def _winner(grid: np.ndarray, scores: np.ndarray, alpha: float,
                eos_id: int) -> Tuple[np.ndarray, float]:
        """Pick one beam group's length-penalized best hypothesis.

        ``grid``: (beam, T) host-side token history in final beam order;
        ``scores``: (beam,) final log-probs.  Returns ``(tokens, score)``
        with ``tokens`` truncated before EOS.  Shared by
        :meth:`generate_beam` and the continuous beam serve's group drain
        — one implementation, so the two paths cannot drift apart.
        """
        hit = grid == eos_id
        lengths = np.where(hit.any(axis=1), np.argmax(hit, axis=1),
                           grid.shape[1])
        pen = ((5.0 + lengths) / 6.0) ** alpha
        final = scores / pen
        best = int(final.argmax())
        return grid[best, :lengths[best]], float(final[best])

    @staticmethod
    def _insert_rows(state: Dict[str, Any], sub: Dict[str, Any],
                     tokens: jax.Array, sub_tokens: jax.Array,
                     slots: jax.Array):
        """Splice a prefilled side-batch into the running decode state.

        ``slots``: (B_sub,) destination rows; entries ≥ n_slots are padding
        and dropped by jax scatter semantics (admission groups are padded to
        a power-of-two width for compile stability).
        """
        out = dict(state)
        out["cache"] = kvc.insert_at_slots(state["cache"], sub["cache"],
                                           slots)
        out["cross_k"] = state["cross_k"].at[:, slots].set(sub["cross_k"])
        out["cross_v"] = state["cross_v"].at[:, slots].set(sub["cross_v"])
        out["src_lengths"] = state["src_lengths"].at[slots].set(
            sub["src_lengths"])
        tokens = tokens.at[slots].set(sub_tokens)
        return out, tokens

    @staticmethod
    def _insert_rows_paged(state: Dict[str, Any], sub: Dict[str, Any],
                           tokens: jax.Array, sub_tokens: jax.Array,
                           slots: jax.Array, pages: jax.Array):
        """Paged ``_insert_rows``: same splice contract, but the main cache
        is a page pool — the contiguous side-batch rows are chunked into
        the destination rows' page reservations (``pages``, sentinel-
        padded) and the block tables installed alongside."""
        out = dict(state)
        out["cache"] = kvc.insert_rows_paged(state["cache"], sub["cache"],
                                             slots, pages)
        out["cross_k"] = state["cross_k"].at[:, slots].set(sub["cross_k"])
        out["cross_v"] = state["cross_v"].at[:, slots].set(sub["cross_v"])
        out["src_lengths"] = state["src_lengths"].at[slots].set(
            sub["src_lengths"])
        tokens = tokens.at[slots].set(sub_tokens)
        return out, tokens

    # ------------------------------------------------------- prefill splice
    def _prefill_padded(self, src_rows: np.ndarray, len_rows: np.ndarray):
        """Prefill a side batch padded to a power-of-two width.

        Padding rows replay row 0 — their results are discarded because
        ``_splice_rows`` gives them out-of-range destinations — so prefill
        compiles one program per pow2 width, not per admission-group size
        (``scheduler.pad_rows_pow2``, the contract shared with the fused
        path's ``plan_admission``).  Returns ``(logits, sub_state, width)``.
        """
        src_rows, len_rows, width = pad_rows_pow2(src_rows, len_rows)
        sub = self.model.init_decode_state(
            width, self.max_len, quantized=self.quant.quantize_kv)
        logits, sub = self._prefill(
            self.params,
            {"src_tokens": jnp.asarray(src_rows),
             "src_lengths": jnp.asarray(len_rows)},
            sub)
        return logits, sub, width

    def _splice_rows(self, state, tokens, sub, sub_tokens, rows: np.ndarray,
                     width: int, pages: Optional[np.ndarray] = None):
        """Splice the first ``len(rows)`` rows of a prefilled side batch
        into the running decode state at ``rows``; the side batch's
        padding rows get an out-of-range sentinel destination (the total
        row count) and are dropped by jax scatter semantics.
        ``sub_tokens`` is already ``width``-long (padding-row entries are
        discarded with their rows), keeping every device shape a function
        of the pow2 bucket, never of the admission-group size.
        ``pages`` (paged cache): (width, maxP) per-row page reservations,
        sentinel rows for the padding."""
        slots = np.full((width,), tokens.shape[0], np.int32)  # OOB sentinel
        slots[:len(rows)] = rows
        if pages is not None:
            return self._insert_paged(state, sub, tokens, sub_tokens,
                                      jnp.asarray(slots), jnp.asarray(pages))
        return self._insert(state, sub, tokens, sub_tokens,
                            jnp.asarray(slots))

    def _admission_prologue(self, params, state, tokens, live, adm_src,
                            adm_lens, adm_rows, extra, group: int = 1):
        """Fused-admission prologue shared by the greedy and beam burst
        programs, so the token-identity-critical free→encode→splice
        sequence exists exactly once:

        1. reset dead rows (cursor only unpaged; cursor + sentinel tables
           paged — their pages may be reassigned by this very splice);
        2. if the round has encode rows (``adm_src`` non-empty — a static
           shape, so empty rounds compile the branch away): encode them,
           optionally scatter the fresh cross-K/V into reserved prefix
           chains (``extra["ins_pages"]``), splice into the grid (paged
           reservations from ``extra["pages"]``), and seed BOS;
        3. if the round has prefix *hits* (``extra["hit_rows"]``): gather
           their chains from the prefix pool and splice those rows with no
           encoder work at all — the refcount bump already happened on the
           host.  The insert scatter in (2) is ordered before this gather,
           so a source admitted twice in one round reads the pages its
           sibling wrote moments earlier in the same program.

        ``extra`` is a dict pytree: key *presence* is static (each
        combination traces its own specialization, a small bounded set),
        which is how zero-width encode/hit rounds cost nothing.
        """
        model, quant = self.model, self.quant
        state = dict(state)
        if self.paged:
            state["cache"] = kvc.free_inactive_paged(state["cache"], live)
        else:
            state["cache"] = kvc.free_inactive(state["cache"], live)
        enc_len = adm_src.shape[1]
        if adm_src.shape[0]:
            ck, cv, slens = model.encode_cross_kv(
                params, {"src_tokens": adm_src, "src_lengths": adm_lens},
                quant=quant)
            if "ins_pages" in extra:
                state["prefix_k"] = kvc.insert_chain_pages(
                    state["prefix_k"], ck, extra["ins_pages"])
                state["prefix_v"] = kvc.insert_chain_pages(
                    state["prefix_v"], cv, extra["ins_pages"])
            state = model.splice_prefill(state, ck, cv, slens, adm_rows,
                                         group=group,
                                         pages=extra.get("pages"))
            rows = kvc.group_rows(jnp.asarray(adm_rows, jnp.int32), group)
            tokens = tokens.at[rows].set(0, mode="drop")           # BOS
        if "hit_rows" in extra:
            hk = kvc.gather_chain_pages(state["prefix_k"],
                                        extra["hit_pages"], enc_len)
            hv = kvc.gather_chain_pages(state["prefix_v"],
                                        extra["hit_pages"], enc_len)
            state = model.splice_prefill(state, hk, hv, extra["hit_lens"],
                                         extra["hit_rows"], group=group,
                                         pages=extra.get("hit_dec_pages"))
            rows = kvc.group_rows(
                jnp.asarray(extra["hit_rows"], jnp.int32), group)
            tokens = tokens.at[rows].set(0, mode="drop")           # BOS
        return state, tokens

    # ---------------------------------------------------------------- bursts
    def _greedy_burst_fn(self, width: int) -> Callable:
        fn = self._burst_jits.get(width)
        if fn is None:
            fn = self._make_greedy_burst(width)
            self._burst_jits[width] = fn
        return fn

    def _greedy_while(self, width: int) -> Callable:
        """The greedy burst ``while_loop`` body, shared (un-jitted) by the
        plain and fused-admission burst programs so the token-identity-
        critical math exists exactly once.

        Carry: step counter, current tokens, per-row ``remaining`` budgets,
        decode state (KV cache updated in place each step), and a
        ``(rows, width)`` token ring buffer.  A row is *active* while
        ``remaining > 0``; emitting EOS or exhausting the budget zeroes it.
        Inactive rows keep stepping (the grid is one fused program) but
        their outputs are masked to EOS and their cache writes land past
        their cursor (dropped by ``kv_cache.append_token`` scatter
        semantics).  The loop exits early once no row is active, so
        ``steps_cap=1`` reproduces the per-step path exactly.
        """
        model, quant, eos = self.model, self.quant, self.eos_id

        def burst(params, tokens, remaining, steps_cap, state):
            buf0 = jnp.full((tokens.shape[0], width), eos, jnp.int32)

            def cond(carry):
                step, _, remaining, _, _ = carry
                return (step < steps_cap) & jnp.any(remaining > 0)

            def body(carry):
                step, tokens, remaining, state, buf = carry
                logits, state = model.decode_step(params, tokens, state,
                                                  quant=quant)
                active = remaining > 0
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, eos)
                buf = buf.at[:, step].set(nxt)
                remaining = jnp.where(active & (nxt != eos), remaining - 1,
                                      jnp.zeros_like(remaining))
                return (step + 1, nxt, remaining, state, buf)

            carry = (jnp.int32(0), tokens,
                     jnp.asarray(remaining, jnp.int32), state, buf0)
            step, tokens, remaining, state, buf = jax.lax.while_loop(
                cond, body, carry)
            return tokens, remaining, state, buf, step

        return burst

    def _make_greedy_burst(self, width: int) -> Callable:
        """Jitted ``while_loop`` running up to ``steps_cap ≤ width`` greedy
        decode steps on device (see :meth:`_greedy_while`)."""
        donate = (1, 4) if self._donate_state else ()
        return jax.jit(self._greedy_while(width), donate_argnums=donate)

    def _fused_greedy_burst_fn(self, width: int) -> Callable:
        fn = self._fused_burst_jits.get(width)
        if fn is None:
            fn = self._make_fused_greedy_burst(width)
            self._fused_burst_jits[width] = fn
        return fn

    def _make_fused_greedy_burst(self, width: int) -> Callable:
        """Greedy burst with the admission round folded into the program.

        Prologue, before the shared :meth:`_greedy_while` loop:

        1. encode the padded admitted sources **inside the program**
           (``encdec.encode_cross_kv``) — no separate prefill dispatch;
        2. reset the cursors of dead rows (``remaining == 0``: finished or
           never occupied), replacing the host-dispatched ``free_slots``
           call the unfused path paid between bursts;
        3. splice the encoded cross-K/V into the admitted rows and zero
           their cursors (``encdec.splice_prefill``) — the self-attention
           cache rows need no copy, length masking hides every stale
           position exactly;
        4. seed the admitted rows' current token with BOS.

        The loop's first iteration then runs the BOS decode step for the
        admitted rows — the exact computation the unfused path ran as a
        separate prefill — while mid-flight rows take their next ordinary
        step in the same fused grid.  ``adm_rows`` entries ≥ n_slots are
        padding (dropped by scatter semantics), so the program specializes
        only on the pow2 admission width, never the admitted count.
        """
        prologue = self._admission_prologue
        loop = self._greedy_while(width)

        def burst(params, tokens, remaining, steps_cap, state,
                  adm_src, adm_lens, adm_rows, extra):
            state, tokens = prologue(params, state, tokens, remaining > 0,
                                     adm_src, adm_lens, adm_rows, extra)
            return loop(params, tokens, remaining, steps_cap, state)

        donate = (1, 4) if self._donate_state else ()
        return jax.jit(burst, donate_argnums=donate)

    # ------------------------------------------------- speculative decoding
    def _spec_greedy_burst_fn(self, width: int, spec_k: int) -> Callable:
        fn = self._spec_burst_jits.get((width, spec_k))
        if fn is None:
            donate = (1, 4) if self._donate_state else ()
            fn = jax.jit(self._spec_greedy_while(width, spec_k),
                         donate_argnums=donate)
            self._spec_burst_jits[(width, spec_k)] = fn
        return fn

    def _spec_fused_greedy_burst_fn(self, width: int, spec_k: int) -> Callable:
        fn = self._spec_fused_burst_jits.get((width, spec_k))
        if fn is None:
            prologue = self._admission_prologue
            loop = self._spec_greedy_while(width, spec_k)

            def burst(params, tokens, remaining, steps_cap, state,
                      adm_src, adm_lens, adm_rows, extra):
                state, tokens = prologue(params, state, tokens,
                                         remaining > 0, adm_src, adm_lens,
                                         adm_rows, extra)
                return loop(params, tokens, remaining, steps_cap, state)

            donate = (1, 4) if self._donate_state else ()
            fn = jax.jit(burst, donate_argnums=donate)
            self._spec_fused_burst_jits[(width, spec_k)] = fn
        return fn

    def _spec_greedy_while(self, width: int, spec_k: int) -> Callable:
        """Self-speculative greedy burst: every ``while_loop`` iteration
        (one *macro-step*) runs ``spec_k`` sequential draft steps with the
        ``draft_quant`` context, then ONE batched multi-position verify
        pass with the engine ``quant`` context, and emits the longest
        draft prefix the verifier agrees with plus the verifier's own
        correction token (:func:`_spec_accept`) — all on device, so host
        syncs per serve round stay exactly one, same as the plain burst.

        The drafts' KV writes are scratch: the verify pass re-appends
        positions ``[n0, n0 + spec_k]`` from the *pre-draft* cache state
        with verifier-quality values, and the accepted cursor
        ``n0 + stop`` is installed with :func:`kv_cache.with_lengths` —
        rejected positions become junk past the cursor, which the cache
        contract already tolerates (reads are length-masked, later writes
        overwrite).  Accepted positions therefore hold the verifier's KV
        of exactly the tokens sequential decode would have fed, which is
        why greedy output is bit-identical to the non-speculative path.

        Ring-buffer layout: ``width`` macro-steps × up to ``spec_k + 1``
        tokens each, written at per-row ``emitted`` cursors (rows emit
        different counts per macro-step, so the host drain reads
        ``emitted[row]`` entries, not a column count).  The per-row
        ``emitted``/``drafted``/``accepted`` counters and ``act_steps``
        (macro-steps the row was live — the busy/wasted accounting unit
        under speculation) ride back as 4 extra ring columns.
        """
        model, eos = self.model, self.eos_id
        quant, draft_quant = self.quant, self.draft_quant
        s = spec_k
        width_cols = width * (s + 1)

        def burst(params, tokens, remaining, steps_cap, state):
            B = tokens.shape[0]
            buf0 = jnp.full((B, width_cols), eos, jnp.int32)
            zeros = jnp.zeros((B,), jnp.int32)
            b_idx = jnp.arange(B)

            def cond(carry):
                step, _, remaining = carry[0], carry[1], carry[2]
                return (step < steps_cap) & jnp.any(remaining > 0)

            def body(carry):
                (step, tokens, remaining, state, buf,
                 emitted, drafted, accepted, act_steps) = carry
                n0 = state["cache"].lengths
                active = remaining > 0
                # ---- draft: s sequential cheap steps (static unroll)
                dst, cur, drafts = state, tokens, []
                for _ in range(s):
                    lg, dst = model.decode_step(params, cur, dst,
                                                quant=draft_quant)
                    cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                    drafts.append(cur)
                d = jnp.stack(drafts, axis=1)              # (B, s)
                # ---- verify: one batched pass over (t0, d_1 … d_s)
                # against the PRE-draft cache (cursors n0) — its appends
                # overwrite every draft-scratch position
                seq = jnp.concatenate([tokens[:, None], d], axis=1)
                vlogits, vstate = model.decode_step_multi(params, seq,
                                                          state, quant=quant)
                v = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)  # (B,s+1)
                stop, hit_eos, acc = _spec_accept(d, v, remaining, eos)
                # ---- roll back rejected positions: cursor-only
                vstate = dict(vstate)
                vstate["cache"] = kvc.with_lengths(vstate["cache"],
                                                   n0 + stop)
                # ---- emit v[:, :stop] at per-row ring cursors
                for j in range(s + 1):
                    col = jnp.where(active & (j < stop), emitted + j,
                                    width_cols)          # OOB → drop
                    buf = buf.at[b_idx, col].set(v[:, j], mode="drop")
                remaining = jnp.where(hit_eos, 0, remaining - stop)
                nxt = jnp.where(active,
                                v[b_idx, jnp.maximum(stop - 1, 0)], eos)
                return (step + 1, nxt, remaining, vstate, buf,
                        emitted + stop, drafted + jnp.where(active, s, 0),
                        accepted + acc,
                        act_steps + active.astype(jnp.int32))

            carry = (jnp.int32(0), tokens, jnp.asarray(remaining, jnp.int32),
                     state, buf0, zeros, zeros, zeros, zeros)
            (step, tokens, remaining, state, buf,
             emitted, drafted, accepted, act_steps) = jax.lax.while_loop(
                cond, body, carry)
            # pack the per-row counters as 4 extra ring columns so the
            # burst returns the same 5-tuple as the plain greedy burst and
            # the host drain still costs exactly ONE device→host transfer
            packed = jnp.concatenate(
                [buf, emitted[:, None], drafted[:, None],
                 accepted[:, None], act_steps[:, None]], axis=1)
            return tokens, remaining, state, packed, step

        return burst

    def _beam_burst_fn(self, width: int, beam: int) -> Callable:
        fn = self._beam_burst_jits.get((width, beam))
        if fn is None:
            fn = self._make_beam_burst(width, beam)
            self._beam_burst_jits[(width, beam)] = fn
        return fn

    def _make_beam_step(self, beam: int) -> Callable:
        """One beam-search decode step — log-softmax, finished-beam EOS
        masking, per-group top-k, score update, and the **cache reorder**
        (the paper's §5.3 GatherNd) — shared by both beam burst builders
        so the token-identity-critical math exists exactly once.

        ``act_r`` is a per-row activity mask: rows of inactive groups
        gather themselves (identity permutation) and keep their tokens /
        scores / finished / permutation-composition / ring-buffer entries
        frozen while their decode state advances with garbage (nothing
        reads it).  An all-True mask reproduces the unmasked
        ``generate_beam`` step exactly.

        ``parked`` is a per-row mask for **mixed beam widths**: a request
        with ``beam_req < beam`` occupies only the first ``beam_req`` rows
        of its group; the tail rows are *parked* — pinned to EOS /
        ``BEAM_SEED_NEG`` / finished, self-gathering — so their candidates
        score ``-1e30 + 0`` and can never enter the group's top-k ahead of
        a real hypothesis, while the top-k's first ``beam_req`` slots (it
        returns descending) are exactly ``top_k(real candidates,
        beam_req)``: the step *is* a ``beam_req``-wide beam step.  An
        all-False mask reproduces the uniform-width step exactly.
        """
        model, quant, eos = self.model, self.quant, self.eos_id
        gather_state = self._beam_gather_state

        def step_fn(params, tokens, scores, finished, comp, state, buf,
                    step, act_r, parked):
            R = tokens.shape[0]
            G = R // beam
            logits, state = model.decode_step(params, tokens, state,
                                              quant=quant)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            V = lp.shape[-1]
            # finished beams only extend with EOS at no cost
            eos_only = jnp.full_like(lp, -1e30).at[:, eos].set(0.0)
            lp = jnp.where(finished[:, None], eos_only, lp)
            cand = (scores[:, None] + lp).reshape(G, beam * V)
            scores_new, flat_idx = jax.lax.top_k(cand, beam)
            src_beam = flat_idx // V
            tok_new = (flat_idx % V).reshape(R).astype(jnp.int32)
            tok_new = jnp.where(parked, eos, tok_new)
            gidx = (src_beam + jnp.arange(G)[:, None] * beam).reshape(R)
            gidx = jnp.where(act_r & ~parked, gidx,
                             jnp.arange(R, dtype=jnp.int32))
            state = gather_state(state, gidx)
            tokens = jnp.where(act_r, tok_new, tokens)
            scores = jnp.where(act_r, scores_new.reshape(R), scores)
            scores = jnp.where(parked, BEAM_SEED_NEG, scores)
            finished = jnp.take(finished, gidx, axis=0) | \
                (act_r & (tokens == eos)) | parked
            comp = jnp.take(comp, gidx, axis=0)
            buf = jnp.take(buf, gidx, axis=0)
            buf = buf.at[:, step].set(jnp.where(act_r, tokens, eos))
            return tokens, scores, finished, comp, state, buf

        return step_fn

    def _make_beam_burst(self, width: int, beam: int) -> Callable:
        """Beam-search burst: top-k, score update, **cache reorder** (the
        paper's §5.3 GatherNd) all inside the scanned body.

        Besides the token ring buffer it carries ``comp`` — the composition
        of this burst's beam-reorder permutations — so the host can apply
        one gather to the token history per *burst* instead of one per
        step.  Ring-buffer rows are reordered alongside the state, so at
        burst exit the buffer is already in final beam order.
        """
        eos = self.eos_id
        step_fn = self._make_beam_step(beam)

        def burst(params, tokens, scores, finished, steps_cap, state):
            BB = tokens.shape[0]
            buf0 = jnp.full((BB, width), eos, jnp.int32)
            comp0 = jnp.arange(BB, dtype=jnp.int32)
            all_rows = jnp.ones((BB,), bool)
            none_parked = jnp.zeros((BB,), bool)

            def cond(carry):
                step, _, _, finished, _, _, _ = carry
                return (step < steps_cap) & ~jnp.all(finished)

            def body(carry):
                step, tokens, scores, finished, comp, state, buf = carry
                tokens, scores, finished, comp, state, buf = step_fn(
                    params, tokens, scores, finished, comp, state, buf,
                    step, all_rows, none_parked)
                return (step + 1, tokens, scores, finished, comp, state, buf)

            carry = (jnp.int32(0), tokens, scores, finished, comp0, state,
                     buf0)
            (step, tokens, scores, finished, comp, state, buf) = \
                jax.lax.while_loop(cond, body, carry)
            return tokens, scores, finished, comp, state, buf, step

        donate = (1, 5) if self._donate_state else ()
        return jax.jit(burst, donate_argnums=donate)

    def _beam_serve_burst_fn(self, width: int, beam: int) -> Callable:
        fn = self._beam_serve_jits.get((width, beam))
        if fn is None:
            fn = self._make_beam_serve_burst(width, beam)
            self._beam_serve_jits[(width, beam)] = fn
        return fn

    def _beam_serve_while(self, width: int, beam: int) -> Callable:
        """Continuous-batching beam burst loop (un-jitted, shared by the
        plain and fused-admission burst programs):
        ``_make_beam_burst``'s body with
        **per-group** lifecycle masks, so requests at different stages of
        their budgets share one decode grid.

        The grid is ``G = rows // beam`` independent beam groups.  Each
        group carries its own ``remaining`` step budget; a group is
        *active* while ``remaining > 0`` and not all of its rows have
        finished.  Inactive groups (budget exhausted, fully finished, or
        unoccupied rows) keep stepping — the grid is one fused program —
        but their tokens / scores / finished / permutation-composition /
        ring-buffer rows are frozen by the per-row mask (see
        ``_make_beam_step``), so at the burst edge the host drains each
        group exactly as ``generate_beam`` would have left it at its own
        early exit.  Groups only *deactivate* mid-burst (admission happens
        at burst edges), so every group active at step ``s`` has taken
        exactly ``s`` steps and the global ring column doubles as the
        per-group one; per-group steps taken are recovered on the host as
        ``remaining_in - remaining_out``.
        """
        eos = self.eos_id
        step_fn = self._make_beam_step(beam)

        def burst(params, tokens, scores, finished, remaining, steps_cap,
                  state, parked):
            R = tokens.shape[0]
            G = R // beam
            buf0 = jnp.full((R, width), eos, jnp.int32)
            ident = jnp.arange(R, dtype=jnp.int32)

            def active_groups(finished, remaining):
                alive = ~jnp.all(finished.reshape(G, beam), axis=1)
                return (remaining > 0) & alive                    # (G,)

            def cond(carry):
                step, _, _, finished, remaining, _, _, _ = carry
                return (step < steps_cap) & \
                    jnp.any(active_groups(finished, remaining))

            def body(carry):
                (step, tokens, scores, finished, remaining, comp, state,
                 buf) = carry
                act_g = active_groups(finished, remaining)        # (G,)
                act_r = jnp.repeat(act_g, beam)                   # (R,)
                tokens, scores, finished, comp, state, buf = step_fn(
                    params, tokens, scores, finished, comp, state, buf,
                    step, act_r, parked)
                remaining = remaining - act_g.astype(remaining.dtype)
                return (step + 1, tokens, scores, finished, remaining, comp,
                        state, buf)

            carry = (jnp.int32(0), tokens, scores.astype(jnp.float32),
                     finished, jnp.asarray(remaining, jnp.int32), ident,
                     state, buf0)
            (step, tokens, scores, finished, remaining, comp, state, buf) = \
                jax.lax.while_loop(cond, body, carry)
            return tokens, scores, finished, remaining, comp, state, buf, step

        return burst

    def _make_beam_serve_burst(self, width: int, beam: int) -> Callable:
        donate = (1, 6) if self._donate_state else ()
        return jax.jit(self._beam_serve_while(width, beam),
                       donate_argnums=donate)

    def _fused_beam_serve_burst_fn(self, width: int, beam: int) -> Callable:
        fn = self._fused_beam_serve_jits.get((width, beam))
        if fn is None:
            fn = self._make_fused_beam_serve_burst(width, beam)
            self._fused_beam_serve_jits[(width, beam)] = fn
        return fn

    def _make_fused_beam_serve_burst(self, width: int, beam: int) -> Callable:
        """Beam-group burst with the admission round folded in —
        **encode-once** prefill.

        The prologue encodes each admitted source exactly once
        (``adm_src`` holds one row per admitted *request*, not per beam
        row) and ``encdec.splice_prefill(group=beam)`` broadcasts the
        memory/cross-KV across the group's ``beam`` rows — the unfused
        side-batch tiled the source ``beam`` times through the encoder for
        bit-identical rows, a ``beam×`` FLOP tax.  Dead rows' cursors are
        reset in-program (replacing the host-dispatched ``free_groups``),
        admitted rows get BOS tokens, and the shared group-masked loop
        runs.  The host seeds the admitted groups' scores as
        ``[0, -1e30, …]`` and ``finished = False`` (uploaded with the
        per-burst score/finished round-trip it already pays), which makes
        the shared beam step's first iteration reproduce
        ``generate_beam``'s first step exactly: every candidate outside
        row 0 carries score ``-1e30 + logprob`` and can never enter the
        top-k, and flat top-k tie-breaking prefers row 0's candidates —
        so the group's first tokens are the top-``beam`` tokens of the
        beam-0 logits, at the beam-0 log-probs.
        """
        prologue = self._admission_prologue
        loop = self._beam_serve_while(width, beam)

        def burst(params, tokens, scores, finished, remaining, steps_cap,
                  state, parked, adm_src, adm_lens, adm_bases, extra):
            live = jnp.repeat(remaining > 0, beam)                 # (R,)
            state, tokens = prologue(params, state, tokens, live, adm_src,
                                     adm_lens, adm_bases, extra, group=beam)
            return loop(params, tokens, scores, finished, remaining,
                        steps_cap, state, parked)

        donate = (1, 6) if self._donate_state else ()
        return jax.jit(burst, donate_argnums=donate)

    # ---------------------------------------------------------------- greedy
    def generate(self, batch: Dict[str, np.ndarray], *,
                 max_new_tokens: int = 64,
                 burst_len: Optional[int] = None,
                 speculative_k: Optional[int] = None) -> GenerationResult:
        K = self._resolve_burst(burst_len)
        if K == "auto":
            K = 8      # adaptation targets serve(); static batches use a mid cap
        spec = int(speculative_k or 0)
        if spec < 0:
            raise ValueError(f"speculative_k must be >= 0, got {spec}")
        if spec and not hasattr(self.model, "decode_step_multi"):
            raise ValueError(
                "speculative decoding needs a model with decode_step_multi "
                f"(multi-position verify); {type(self.model).__name__} "
                "does not provide one")
        width = next_pow2(K)
        burst = (self._spec_greedy_burst_fn(width, spec) if spec
                 else self._greedy_burst_fn(width))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        B = next(iter(batch.values())).shape[0]

        t0 = time.perf_counter()
        state = self._init_state(B)
        logits, state = self._prefill(self.params, batch, state)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        first = np.asarray(tokens)
        host_syncs = 1
        cols = [first]
        # speculative bursts emit ragged per-row counts, so the output is
        # accumulated as per-row segments instead of grid columns
        rows = [[int(first[b])] for b in range(B)]
        emit_col = width * (spec + 1)
        draft_total = 0
        accept_total = 0
        remaining_np = np.where(first == self.eos_id, 0,
                                max(max_new_tokens - 1, 0)).astype(np.int32)
        remaining = jnp.asarray(remaining_np)
        steps = 1
        cap = jnp.asarray(K, jnp.int32)
        while remaining_np.any():
            tokens, remaining, state, buf, s = burst(
                self.params, tokens, remaining, cap, state)
            buf_host = np.asarray(buf)             # one host sync per burst
            s = int(s)
            remaining_np = np.asarray(remaining)
            host_syncs += 1
            if spec:
                for b in range(B):
                    n = int(buf_host[b, emit_col])
                    rows[b].extend(int(x) for x in buf_host[b, :n])
                    draft_total += int(buf_host[b, emit_col + 1])
                    accept_total += int(buf_host[b, emit_col + 2])
            else:
                cols.extend(buf_host[:, i] for i in range(s))
            steps += s
        t2 = time.perf_counter()

        if spec:
            grid_rows = [np.asarray(r, np.int32) for r in rows]
        else:
            grid = np.stack(cols, axis=1)                       # (B, T)
            grid_rows = [grid[b] for b in range(B)]
        seqs = []
        for row in grid_rows:
            stop = np.argmax(row == self.eos_id) if (row == self.eos_id).any() \
                else len(row)
            seqs.append(row[:stop])
        return GenerationResult(tokens=seqs, steps=steps,
                                prefill_s=t1 - t0, decode_s=t2 - t1,
                                host_syncs=host_syncs,
                                speculative_k=spec,
                                draft_tokens=draft_total,
                                accepted_tokens=accept_total)

    # ------------------------------------------------------------ continuous
    def _as_requests(
        self, requests: Sequence[Any],
        max_new_tokens: Union[int, Sequence[int]],
    ) -> List[Request]:
        per_req = (list(max_new_tokens)
                   if isinstance(max_new_tokens, (list, tuple, np.ndarray))
                   else [int(max_new_tokens)] * len(requests))
        if len(per_req) != len(requests):
            raise ValueError("max_new_tokens sequence length "
                             f"{len(per_req)} != {len(requests)} requests")
        out = []
        for i, (r, m) in enumerate(zip(requests, per_req)):
            if isinstance(r, Request):
                out.append(r)
                continue
            src = r.src if hasattr(r, "src") else np.asarray(r, np.int32)
            out.append(Request(req_id=i, src=np.asarray(src, np.int32),
                               max_new_tokens=int(m)))
        ids = [r.req_id for r in out]
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate req_ids in serve() input (raw "
                             "requests are numbered by position; supplied "
                             "Request ids must not collide)")
        return out

    def serve(self, requests: Sequence[Any], *, n_slots: int = 8,
              max_new_tokens: Union[int, Sequence[int]] = 64,
              prefill_token_budget: Optional[int] = None,
              admit_min_free: int = 1,
              pad_to_multiple: int = 8,
              burst_len: Optional[Union[int, str]] = None,
              beam: Optional[Union[int, Sequence[int]]] = None,
              alpha: float = 0.6,
              fused_admission: bool = True,
              prefix_cache: Optional[bool] = None,
              overcommit: float = 1.0,
              prefill_chunk: Optional[int] = None,
              chaos: Optional[ChaosSchedule] = None,
              speculative_k: Optional[int] = None) -> ServeResult:
        """Continuous-batching decode over a request stream.

        ``requests`` may be ``Sentence``s, raw token arrays, or ``Request``
        objects (the latter carry their own ``max_new_tokens``); submission
        order is arrival order.  All ``n_slots`` rows share one jitted
        decode burst of up to ``burst_len`` steps (engine default if None);
        the host is touched only at burst boundaries, where finished rows
        are released (``kv_cache.free_slots``) and refilled from the
        waiting queue (``kv_cache.insert_at_slots``), so the decode grid
        stays saturated even when generation lengths are wildly skewed.
        Greedy decode is token-identical to per-request :meth:`generate`
        for every ``burst_len``; ``burst_len=1`` reproduces the per-step
        loop (slot refill and latency observation every token), larger
        bursts amortize host round trips at the cost of finished rows
        idling (masked to EOS) until the next burst edge.

        ``beam`` switches the grid to continuous **beam search**: each
        request occupies a group of ``beam`` contiguous rows (so the grid
        holds ``n_slots // beam`` groups), the burst runs the beam-search
        body — top-k, score update, on-device cache reorder (the paper's
        §5.3 GatherNd) — with per-group budget/finished masks, finished
        groups are drained and their ``beam`` rows refilled at burst
        edges, and each request's ``tokens`` is the winning hypothesis
        under the ``alpha`` length penalty.  Token-identical to
        per-request :meth:`generate_beam` for every ``burst_len``, FP and
        INT8 KV cache alike.  ``beam=None`` (default) is the greedy path;
        ``beam=1`` runs the beam machinery with single-row groups (same
        tokens as greedy, but with scores and the beam drain path).
        ``beam`` may also be a per-request sequence (mixed widths in one
        grid: narrower requests park their groups' tail rows and — on the
        paged cache — reserve pages only for the rows they actually run).

        ``admit_min_free`` is admission hysteresis: wait until that many
        slot groups are free before paying for a prefill round (larger
        values amortize prefill dispatches at a small utilization/latency
        cost; 1 = refill immediately).  The last stragglers are always
        admitted.

        ``fused_admission=True`` (default) folds each admission round into
        the burst program — a serve round is ONE jitted dispatch and one
        device→host sync, admitted or not, and ``prefill_dispatches``
        stays 0; ``False`` keeps the PR 3 behaviour (separate prefill
        dispatch + first-token drain per admission round) as the measured
        baseline.  Token streams are identical either way; with fusion the
        first token of an admitted request is *observed* one burst edge
        later (it is emitted by the burst's first step, not by a prefill
        drain), which is the latency grain the queueing model
        ``streams.simulate_continuous(fused_admission=...)`` mirrors.

        ``burst_len="auto"`` lets :class:`burst_control.AdaptiveBurst`
        move the step cap between bursts (pow2 values under one compiled
        ring-width bucket, so adapting never recompiles).

        ``prefix_cache`` (None = the engine constructor's setting) turns
        on cross-request prefix sharing: an admission whose source exactly
        matches a cached one skips the encoder and splices the cached
        cross-K/V chain (a host-side refcount bump instead of encode +
        store); misses cache their encode for the next requester.  The
        cache persists across serve() calls on this engine.  Token
        streams are identical to a cold-cache serve — hits change *where*
        the cross-K/V comes from, never its values.

        **Overload behaviour** (all default-off; tokens stay identical to
        an unloaded serve in every mode):

        * ``overcommit > 1.0`` (paged cache only) admits past worst-case
          page reservation — a request's full-budget reservation becomes
          *virtual* (capped at ``overcommit × n_pages``), only next-burst
          pages are allocated up front, rows grow page by page between
          bursts, and when growth or a more urgent admission comes up
          short a victim is **preempted by page spill**: its KV pages,
          cursors and tokens are copied to host
          (``serving/preemption.py``), its pages freed, and it resumes
          later through the normal paged splice, bit-identically.
        * ``Request.deadline_s`` / ``Request.priority`` order the wait
          queue EDF-first (with starvation aging) and pick preemption
          victims; a request whose deadline has already passed at an
          admission edge is **shed** (status "rejected" with a reason)
          instead of wasting encode work.
        * ``prefill_chunk`` (fused admission only) stages sources longer
          than the chunk over serving rounds — one width-1 encoder layer
          per round between decode bursts — so one long prefill cannot
          stall every running request's next token.
        * ``chaos`` injects deterministic seeded faults at round edges
          (``serving/chaos.py``): forced preemptions and synthetic slow
          rounds for the ``StepWatchdog``.  The test harness uses it to
          prove the preempt/resume identity.

        ``speculative_k`` (greedy only) turns on **self-speculative
        decoding**: every burst loop iteration drafts ``speculative_k``
        tokens through the cheap ``draft_quant`` path, verifies them with
        ONE batched multi-position pass through the engine's own ``quant``
        path, and emits the longest agreeing prefix plus the verifier's
        correction.  Output is bit-identical to ``speculative_k=None``
        (lossless verification — emitted tokens always come from the
        verifier); the win is wall-clock when the draft path is cheaper
        and acceptance is high.  ``ServeResult`` reports
        ``draft_tokens``/``accepted_tokens``/``acceptance_rate``.
        """
        if beam is not None:
            if speculative_k:
                raise ValueError("speculative decoding is greedy-only; "
                                 "beam and speculative_k cannot combine")
            return self._serve_beam(
                requests, n_slots=n_slots, beam=beam, alpha=alpha,
                max_new_tokens=max_new_tokens,
                prefill_token_budget=prefill_token_budget,
                admit_min_free=admit_min_free,
                pad_to_multiple=pad_to_multiple, burst_len=burst_len,
                fused_admission=fused_admission, prefix_cache=prefix_cache,
                overcommit=overcommit, prefill_chunk=prefill_chunk,
                chaos=chaos)
        self._check_overload_args(overcommit, prefill_chunk, chaos,
                                  fused_admission)
        spec = int(speculative_k or 0)
        if spec < 0:
            raise ValueError(f"speculative_k must be >= 0, got {spec}")
        if spec and not hasattr(self.model, "decode_step_multi"):
            raise ValueError(
                "speculative decoding needs a model with decode_step_multi "
                f"(multi-position verify); {type(self.model).__name__} "
                "does not provide one")
        spec_mult = spec + 1
        K = self._resolve_burst(burst_len)
        ctrl = self._burst_controller(K)
        reqs = self._as_requests(requests, max_new_tokens)
        if not reqs:
            return ServeResult(requests=[], n_slots=n_slots, decode_steps=0,
                               busy_slot_steps=0, prefill_rounds=0,
                               wall_s=0.0, host_syncs=0,
                               burst_len=ctrl.k if ctrl else K,
                               fused_admission=fused_admission,
                               auto_burst=ctrl is not None,
                               paged=self.paged, page_size=self.page_size,
                               speculative_k=spec,
                               **self._mesh_result_fields(n_slots))
        if max(r.max_new_tokens for r in reqs) > self.max_len:
            raise ValueError("a request's max_new_tokens exceeds the "
                             f"engine KV capacity {self.max_len}")
        width = next_pow2(ctrl.max_burst if ctrl else K)
        if spec:
            burst = self._spec_greedy_burst_fn(width, spec)
            fused_burst = (self._spec_fused_greedy_burst_fn(width, spec)
                           if fused_admission else None)
        else:
            burst = self._greedy_burst_fn(width)
            fused_burst = (self._fused_greedy_burst_fn(width)
                           if fused_admission else None)
        enc_len = self._enc_bucket(reqs, pad_to_multiple)
        pc = self._resolve_prefix_cache(prefix_cache)
        stats0 = pc.stats.snapshot() if pc else None

        allocator = None
        if self.paged:
            allocator = self._make_allocator(n_slots, overcommit)
            for r in reqs:
                need = self._pages_per_request(r, 1)
                if need > allocator.n_pages:
                    raise ValueError(
                        f"request {r.req_id} needs {need} pages but the "
                        f"pool holds {allocator.n_pages}")
        # overcommit: admission allocates only next-burst pages; the loop
        # grows rows and preempts-by-spill under pressure.  The hint is
        # the largest step cap a burst can take — under speculation every
        # macro-step may append up to spec+1 KV positions, so the page
        # reach scales by spec_mult or accepted writes would be dropped.
        burst_hint = (ctrl.max_burst if ctrl else K) * spec_mult
        initial_fn = None
        if allocator is not None and overcommit > 1.0:
            initial_fn = lambda r: self._initial_pages(r, 1, burst_hint)
        sched = ContinuousScheduler(
            n_slots, prefill_token_budget=prefill_token_budget,
            allocator=allocator,
            pages_per_request=(
                (lambda r: self._pages_per_request(r, 1))
                if allocator else None),
            prefix_cache=pc, initial_pages=initial_fn,
            prefill_chunk=prefill_chunk)
        sched.submit_many(reqs)

        quantized = self.quant.quantize_kv
        state = self.model.init_decode_state(
            n_slots, self.max_len, quantized=quantized, enc_len=enc_len,
            paged=self.paged, page_size=self.page_size,
            n_pages=allocator.n_pages if allocator else None)
        if pc is not None:
            state["prefix_k"], state["prefix_v"] = self._prefix_pool
        state = self._shard_state(state)
        tokens = jnp.zeros((n_slots,), jnp.int32)

        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0
        decode_steps = 0
        busy_slot_steps = 0
        prefill_rounds = 0
        host_syncs = 0
        prefill_dispatches = 0
        encoder_tokens = 0
        draft_tokens = 0
        accepted_tokens = 0
        # fixed caps upload the device scalar once; auto rebuilds per round
        cap_fixed = None if ctrl else jnp.asarray(K, jnp.int32)
        # ---- overload machinery (all inert on an unloaded serve)
        store = SpillStore()
        watchdog = StepWatchdog()
        staging: Dict[int, Dict[str, Any]] = {}   # slot → staged-encode state
        # with growth (overcommit) or preemption in play, freed pages can
        # be handed to OTHER rows between fused prologues — dead rows must
        # be sentineled eagerly, not lazily at the next admission burst
        eager_free = (overcommit > 1.0) or (chaos is not None)
        preempt_count = 0
        peak_running = 0
        chunked_admissions = 0
        chunk_rounds = 0
        round_idx = 0
        maxP = self._max_pages

        def preempt_req(req: Request) -> None:
            """Spill one running request's device state to host and evict
            it (a mid-stage chunked prefill holds no device state worth
            saving: drop the stage and restage from scratch on
            re-admission — deterministic, so tokens are unaffected)."""
            nonlocal state, host_syncs, preempt_count
            slot = req.slot
            if slot in staging:
                staging.pop(slot)
                sched.preempt(req, now())
            else:
                outs = self._spill_fn(1)(
                    state, tokens, jnp.asarray(np.asarray([slot], np.int32)))
                k, v, ks, vs, lens, toks, ck, cv, slens = [
                    None if o is None else np.asarray(o) for o in outs]
                host_syncs += 1
                req.spill = SpilledRequest(
                    req_id=req.req_id, n_rows=1, k=k, v=v, k_scale=ks,
                    v_scale=vs, lengths=lens, tokens_row=toks, cross_k=ck,
                    cross_v=cv, src_lengths=slens,
                    n_pages=len(req.pages or []))
                store.put(req.spill)
                sched.preempt(req, now())
            preempt_count += 1
            # sentinel the victim row NOW: its stale block table would
            # otherwise route the next burst's (masked but real) writes
            # into pages growth/resume may already have handed to others
            state = dict(state)
            state["cache"] = kvc.free_slots_paged(
                state["cache"], np.asarray([slot], np.int32))

        def grow_rows(k_cap: int) -> None:
            """Pre-burst page growth for overcommitted rows: every running
            row gets pages to cover its cursor + the next burst, evicting
            least-urgent victims when the pool is dry (mandatory — a row
            that cannot grow cannot take its next step)."""
            nonlocal state
            if initial_fn is None:
                return
            for slot, req in list(sched.slot_map.items()):
                if sched.slot_map.get(slot) is not req or slot in staging:
                    continue       # victim of an earlier growth this round
                cursor = len(req.tokens)
                cap_tok = min(req.max_new_tokens, self.max_len)
                need = kvc.pages_per_row(min(cursor + k_cap, cap_tok),
                                         self.page_size)
                extra = need - len(req.pages)
                if extra <= 0:
                    continue
                newp = allocator.alloc(extra)
                while newp is None:
                    victims, covered = pick_victims(
                        [r for r in sched.slot_map.values() if r is not req],
                        pages_needed=extra - allocator.n_free,
                        key_fn=sched.victim_key,
                        pages_held_fn=lambda r: len(r.pages or []))
                    if not victims or not covered:
                        # fail BEFORE spilling: preempting victims that
                        # cannot cover the need pays spill + re-encode for
                        # nothing and wedges anyway
                        raise RuntimeError(
                            "page growth wedged: no preemptable victim "
                            f"set covers request {req.req_id}'s need "
                            f"({extra} pages)")
                    for v in victims:
                        preempt_req(v)
                    newp = allocator.alloc(extra)
                have = len(req.pages)
                upd = np.full((1, maxP), -1, np.int32)
                upd[0, have:have + extra] = newp
                req.pages.extend(newp)
                state = self._grow_fn(1)(
                    state, jnp.asarray(np.asarray([slot], np.int32)),
                    jnp.asarray(upd))

        def preempt_for_admission() -> None:
            """Admission-driven preemption: free pages for the most urgent
            waiting request by evicting strictly-less-urgent running ones
            (``min_key`` — equal urgency never evicts, so requests cannot
            ping-pong)."""
            if initial_fn is None:
                return
            for _ in range(n_slots + len(reqs)):
                short = sched.admission_shortfall()
                if short is None:
                    return
                need = max(short["pages_short"], 1)
                victims, covered = pick_victims(
                    list(sched.slot_map.values()), pages_needed=need,
                    key_fn=sched.victim_key,
                    pages_held_fn=lambda r: len(r.pages or []),
                    min_key=short["head_key"])
                if not victims or not covered:
                    # insufficient coverage: spilling these victims would
                    # not let the head request in — keep them running
                    return
                for v in victims:
                    preempt_req(v)

        def restore_resumed(resumed: List[Request]) -> None:
            """Re-splice spilled payloads into freshly admitted rows —
            the resume half of preempt-by-page-spill."""
            nonlocal state, tokens
            for req in resumed:
                sp = req.spill
                pages = np.full((1, maxP), allocator.n_pages, np.int32)
                pages[0, :len(req.pages)] = req.pages
                state, tokens = self._resume_fn(1)(
                    state, tokens,
                    jnp.asarray(np.asarray([req.slot], np.int32)),
                    jnp.asarray(pages),
                    jnp.asarray(sp.k), jnp.asarray(sp.v),
                    None if sp.k_scale is None else jnp.asarray(sp.k_scale),
                    None if sp.v_scale is None else jnp.asarray(sp.v_scale),
                    jnp.asarray(sp.lengths), jnp.asarray(sp.tokens_row),
                    jnp.asarray(sp.cross_k), jnp.asarray(sp.cross_v),
                    jnp.asarray(sp.src_lengths))
                store.pop(req.req_id)
                allocator.unspill(sp.n_pages)
                req.spill = None

        def advance_staging() -> None:
            """Run ONE encoder layer for every staged (chunked) prefill;
            finished stages splice their cross-K/V and seed BOS, so the
            request starts decoding next round."""
            nonlocal state, tokens, chunk_rounds
            n_enc = self.model.cfg.n_enc_layers
            for slot, st in list(staging.items()):
                req = st["req"]
                if st["x"] is None:
                    src = np.zeros((1, enc_len), np.int32)
                    src[0, :req.n_src_tokens] = req.src
                    st["lens"] = jnp.asarray(
                        np.asarray([req.n_src_tokens], np.int32))
                    begin, _ = self._stage_fns()
                    st["x"] = begin(self.params, jnp.asarray(src),
                                    st["lens"])
                st["x"] = self._stage_layer_fn(st["li"])(
                    self.params, st["x"], st["lens"])
                st["li"] += 1
                chunk_rounds += 1
                if st["li"] >= n_enc:
                    _, finish = self._stage_fns()
                    ck, cv, slens = finish(self.params, st["x"], st["lens"])
                    extra = {}
                    if allocator:
                        extra["pages"] = jnp.asarray(self._page_rows(
                            [req], 1, 1, allocator.n_pages))
                    state, tokens = self._chunk_splice_fn(1)(
                        state, tokens, ck, cv, slens,
                        jnp.asarray(np.asarray([req.slot], np.int32)),
                        extra)
                    staging.pop(slot)

        def prefill_into_slots(admitted, state, tokens):
            """Prefill newly admitted requests and splice them in."""
            g = len(admitted)
            src_pad, lens = pad_batch([r.src for r in admitted],
                                      length=enc_len)
            logits, sub, width = self._prefill_padded(src_pad, lens)
            # argmax at the padded width: device shapes depend only on the
            # pow2 bucket; the admission-group size g appears host-side
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if pc is not None and any(r.prefix_role == "insert"
                                      for r in admitted):
                ins = sched.chain_pages_matrix(admitted, width, enc_len)
                state = self._pool_insert_fn()(
                    state, sub["cross_k"], sub["cross_v"], jnp.asarray(ins))
            pages = (self._page_rows(admitted, 1, width, allocator.n_pages)
                     if allocator else None)
            state, tokens = self._splice_rows(
                state, tokens, sub, first,
                np.asarray([r.slot for r in admitted], np.int32), width,
                pages=pages)
            first_host = np.asarray(first)[:g]
            t = now()
            for r, tok in zip(admitted, first_host):
                r.first_token_s = t
                tok = int(tok)
                if r.max_new_tokens <= 0 or tok == self.eos_id:
                    sched.release(r, t, step=decode_steps)
                    # zero budget / empty translation
                else:
                    r.tokens.append(tok)
                    if r.max_new_tokens <= 1:
                        sched.release(r, t, step=decode_steps)
            return state, tokens

        while not sched.all_done:
            rnd = round_idx
            round_idx += 1
            # (a) chaos: forced preemptions at this round edge
            if chaos is not None and sched.slot_map:
                by_id = {r.req_id: r for r in sched.slot_map.values()}
                for rid in chaos.victims_for(rnd, list(by_id)):
                    preempt_req(by_id[rid])
            # (b) overcommit growth for mid-flight rows (may itself evict);
            # speculative macro-steps write up to spec+1 positions each
            grow_rows((ctrl.k if ctrl else K) * spec_mult)
            # (c) admission pressure: evict strictly-less-urgent victims
            preempt_for_admission()
            plan = None
            admitted = []
            want_admit = (sched.n_waiting and sched.n_free >=
                          min(max(admit_min_free, 1), sched.n_waiting,
                              n_slots))
            if want_admit and fused_admission:
                # admission rides the NEXT burst dispatch: the plan's padded
                # sources/destinations become burst-program inputs
                plan = sched.plan_admission(now(), step=decode_steps,
                                            enc_len=enc_len,
                                            oob_row=n_slots)
                if plan.n_admitted:
                    prefill_rounds += 1
                encoder_tokens += len(plan.requests) * enc_len
                if plan.resumed:
                    restore_resumed(plan.resumed)
                for r in plan.staged:
                    staging[r.slot] = {"req": r, "x": None, "li": 0,
                                       "lens": None}
                chunked_admissions += len(plan.staged)
                encoder_tokens += len(plan.staged) * enc_len
            elif want_admit:
                admitted = sched.admit(now(), step=decode_steps)
                if admitted:
                    prefill_rounds += 1
                    resumed = [r for r in admitted if r.spill is not None]
                    fresh = [r for r in admitted if r.spill is None]
                    if resumed:
                        restore_resumed(resumed)
                    hits: List[Request] = []
                    if pc is not None:
                        # zero-budget requests skip prefix routing: they
                        # release inside prefill_into_slots before any
                        # finish() could pair with their admit()
                        misses, hits = sched.assign_prefix(
                            [r for r in fresh if r.max_new_tokens > 0])
                        enc_list = misses + [r for r in fresh
                                             if r.max_new_tokens <= 0]
                    else:
                        enc_list = fresh
                    if enc_list:
                        prefill_dispatches += 1
                        host_syncs += 1   # first-token drain syncs the host
                        encoder_tokens += len(enc_list) * enc_len
                        state, tokens = prefill_into_slots(enc_list, state,
                                                           tokens)
                    if hits:
                        # no encoder: gather the cached chains and defer
                        # the first token to the next burst (BOS seed)
                        hrows, hlens, hpages, hw = sched.shape_hits(
                            hits, enc_len=enc_len, oob_row=n_slots)
                        extra = ({"dec_pages": jnp.asarray(self._page_rows(
                                     hits, 1, hw, allocator.n_pages))}
                                 if allocator else {})
                        state, tokens = self._hit_splice_fn(1)(
                            state, tokens, jnp.asarray(hpages),
                            jnp.asarray(hlens), jnp.asarray(hrows), extra)
            peak_running = max(peak_running, sched.n_running)
            if not sched.slot_map:
                continue        # every admitted request finished on token 1

            # per-row budgets: every occupied slot has ≥1 token left to
            # emit.  Staging slots stay at 0 — they hold no KV yet, so the
            # fused prologue treats them as dead (re-sentinels their
            # tables) until their chunked encode completes.
            remaining = np.zeros((n_slots,), np.int32)
            for slot, req in sched.slot_map.items():
                if slot in staging:
                    continue
                remaining[slot] = req.max_new_tokens - len(req.tokens)
            has_adm = plan is not None and (plan.width or plan.hit_width)
            if not remaining.any() and not has_adm:
                # pure-staging round: nothing to decode — push the staged
                # encodes one layer and come back
                advance_staging()
                continue
            cap = jnp.asarray(ctrl.k, jnp.int32) if ctrl else cap_fixed
            t_dispatch = time.perf_counter()
            if plan is not None and (plan.width or plan.hit_width):
                extra = {}
                if allocator and plan.width:
                    extra["pages"] = jnp.asarray(self._page_rows(
                        plan.requests, 1, plan.width, allocator.n_pages))
                if pc is not None and plan.width:
                    extra["ins_pages"] = jnp.asarray(plan.ins_pages)
                if plan.hit_width:
                    extra["hit_rows"] = jnp.asarray(plan.hit_rows)
                    extra["hit_lens"] = jnp.asarray(plan.hit_lengths)
                    extra["hit_pages"] = jnp.asarray(plan.hit_pages)
                    if allocator:
                        extra["hit_dec_pages"] = jnp.asarray(self._page_rows(
                            plan.hits, 1, plan.hit_width, allocator.n_pages))
                tokens, _, state, buf, steps_dev = fused_burst(
                    self.params, tokens, jnp.asarray(remaining), cap, state,
                    jnp.asarray(plan.src_tokens),
                    jnp.asarray(plan.src_lengths),
                    jnp.asarray(plan.base_rows), extra)
            else:
                tokens, _, state, buf, steps_dev = burst(
                    self.params, tokens, jnp.asarray(remaining), cap, state)
            buf_host = np.asarray(buf)         # ONE host sync per burst
            steps = int(steps_dev)
            burst_wall = time.perf_counter() - t_dispatch
            host_syncs += 1
            step_base = decode_steps
            decode_steps += steps

            # drain the ring buffer: release at EOS / budget exhaustion;
            # latencies are observed at the burst edge (burst granularity)
            t = now()
            freed = []
            wasted_row_steps = 0
            emit_col = width * spec_mult    # first packed-counter column
            for slot, req in list(sched.slot_map.items()):
                if slot in staging:
                    # mid-stage rows are inert grid: their ring columns
                    # are masked EOS, not output (draining one would
                    # falsely release the request)
                    wasted_row_steps += steps
                    continue
                if req.first_token_s is None:
                    req.first_token_s = t   # fused: emitted by this burst
                if spec:
                    # speculative ring: rows emit different counts per
                    # macro-step, so the drain is driven by the per-row
                    # emitted counter, and busy/wasted are counted in
                    # macro-steps the row was live (act column).  Release
                    # steps are attributed at burst granularity.
                    n_emit = int(buf_host[slot, emit_col])
                    act = int(buf_host[slot, emit_col + 3])
                    for i in range(n_emit):
                        tok = int(buf_host[slot, i])
                        if tok == self.eos_id:
                            freed.append(sched.release(
                                req, t, step=step_base + steps))
                            break
                        req.tokens.append(tok)
                        if len(req.tokens) >= req.max_new_tokens:
                            freed.append(sched.release(
                                req, t, step=step_base + steps))
                            break
                    busy_slot_steps += act
                    wasted_row_steps += steps - act
                    draft_tokens += int(buf_host[slot, emit_col + 1])
                    accepted_tokens += int(buf_host[slot, emit_col + 2])
                    continue
                used = steps
                for s in range(steps):
                    tok = int(buf_host[slot, s])
                    if tok == self.eos_id:
                        used = s + 1
                        freed.append(sched.release(req, t,
                                                   step=step_base + s + 1))
                        break
                    req.tokens.append(tok)
                    if len(req.tokens) >= req.max_new_tokens:
                        used = s + 1
                        freed.append(sched.release(req, t,
                                                   step=step_base + s + 1))
                        break
                busy_slot_steps += used
                wasted_row_steps += steps - used
            if ctrl:
                ctrl.observe(burst_wall, steps, wasted_row_steps, n_slots)
            watchdog.observe(burst_wall +
                             (chaos.slow_for(rnd) if chaos else 0.0))
            if freed and (not fused_admission or eager_free):
                # fused mode normally resets dead cursors inside the next
                # admission burst's prologue — but under growth/preemption
                # freed pages can be handed out before any prologue runs,
                # so dead rows are sentineled eagerly here
                state = dict(state)
                free = kvc.free_slots_paged if self.paged else kvc.free_slots
                state["cache"] = free(state["cache"],
                                      np.asarray(freed, np.int32))
            # (h) advance chunked prefills one encoder layer, after the
            # drain so a stage admitted this round runs its first layer
            # in this round but never rides this round's burst
            advance_staging()

        if pc is not None:
            # hand the (possibly donated-through) pool arrays back to the
            # engine so the next serve and the tree agree on contents
            self._prefix_pool = (state["prefix_k"], state["prefix_v"])
        return ServeResult(requests=reqs, n_slots=n_slots,
                           decode_steps=decode_steps,
                           busy_slot_steps=busy_slot_steps,
                           prefill_rounds=prefill_rounds, wall_s=now(),
                           host_syncs=host_syncs,
                           burst_len=ctrl.k if ctrl else K,
                           prefill_dispatches=prefill_dispatches,
                           encoder_tokens=encoder_tokens,
                           fused_admission=fused_admission,
                           auto_burst=ctrl is not None,
                           paged=self.paged, page_size=self.page_size,
                           pages_in_use=allocator.in_use if allocator else 0,
                           page_hwm=allocator.hwm if allocator else 0,
                           speculative_k=spec,
                           draft_tokens=draft_tokens,
                           accepted_tokens=accepted_tokens,
                           **self._mesh_result_fields(n_slots),
                           **self._overload_result_fields(
                               overcommit, preempt_count, store, watchdog,
                               sched, reqs, allocator, peak_running,
                               chunked_admissions, chunk_rounds),
                           **self._prefix_result_fields(pc, stats0))

    # ------------------------------------------------- continuous beam search
    def _serve_beam(self, requests: Sequence[Any], *, n_slots: int,
                    beam: Union[int, Sequence[int]], alpha: float,
                    max_new_tokens: Union[int, Sequence[int]],
                    prefill_token_budget: Optional[int],
                    admit_min_free: int, pad_to_multiple: int,
                    burst_len: Optional[Union[int, str]],
                    fused_admission: bool = True,
                    prefix_cache: Optional[bool] = None,
                    overcommit: float = 1.0,
                    prefill_chunk: Optional[int] = None,
                    chaos: Optional[ChaosSchedule] = None) -> ServeResult:
        """Continuous beam search: beam-group slot lifecycle.

        Structure mirrors the greedy ``serve`` loop, at group granularity:

        * a request is admitted into ``beam`` contiguous rows; its source
          is prefilled replicated across the group (exactly as
          ``generate_beam`` tiles its batch) and its first ``beam`` tokens
          come from one top-k over the group's beam-0 logits;
        * each burst runs ``_make_beam_serve_burst``'s group-masked body;
          at the edge the host replays the group's composed beam
          permutation over its token history, appends the new ring-buffer
          columns, and — when the group's budget is spent or every row has
          finished — picks the length-penalized winner, releases the
          request, and frees all ``beam`` rows atomically
          (``kv_cache.free_groups``) so the next waiting request can take
          the group mid-decode.

        Host-visible per-group state (scores, finished mask) round-trips
        through float32/bool numpy between bursts — bit-exact, which is
        what keeps the output token-identical to per-request
        :meth:`generate_beam` at every ``burst_len``.

        With ``fused_admission=True`` the admission round rides the burst
        program (one dispatch per round): each source is encoded **once**
        and broadcast across its group's rows, group scores are seeded
        host-side as ``[0, -1e30, …]`` so the burst's first step takes the
        top-k over beam-0 logits exactly as ``generate_beam`` does, and
        the group's token history starts empty (the first tokens arrive
        with the burst drain, in final beam order).

        **Mixed beam widths**: ``beam`` may be a per-request sequence (or
        ``Request.beam`` may be set).  The grid compiles one program at
        the *maximum* width; a narrower request runs only the first
        ``beam_req`` rows of its group and the tail rows are *parked*
        (see ``_make_beam_step``) — each step is then exactly a
        ``beam_req``-wide beam step, so every request stays
        token-identical to ``generate_beam(beam=beam_req)``.  With the
        paged cache, parked rows reserve **no pages**, so mixed widths
        cost HBM proportional to the widths actually requested — no
        fragmentation-aware free list, because pages cannot fragment.

        Overload machinery (overcommit growth, preempt-by-page-spill,
        deadline shedding, chunked prefill, chaos) works at *group*
        granularity: a preemption spills the whole group — all ``beam``
        rows' pages plus the host-side search state (scores, finished
        mask, token history, budget) — and resume re-seeds both sides
        bit-identically.
        """
        self._check_overload_args(overcommit, prefill_chunk, chaos,
                                  fused_admission)
        reqs = self._as_requests(requests, max_new_tokens)
        # resolve each request's effective width WITHOUT mutating the
        # caller's Request objects (a serve()-written default would stick
        # to a reused Request and silently shadow a later serve's beam):
        # an explicit `beam` sequence wins, then a user-set Request.beam,
        # then the scalar default
        if isinstance(beam, (list, tuple, np.ndarray)):
            seq = [int(b) for b in beam]
            if len(seq) != len(reqs):
                raise ValueError(f"beam sequence length {len(seq)} != "
                                 f"{len(reqs)} requests")
            width_of = {r.req_id: b for r, b in zip(reqs, seq)}
            default_beam = max(seq) if seq else 1
        else:
            default_beam = int(beam)
            if default_beam < 1:
                raise ValueError(f"beam must be ≥ 1, got {default_beam}")
            width_of = {r.req_id: (int(r.beam) if r.beam is not None
                                   else default_beam) for r in reqs}
        for r in reqs:
            if width_of[r.req_id] < 1:
                raise ValueError(f"beam must be ≥ 1, got "
                                 f"{width_of[r.req_id]} "
                                 f"(request {r.req_id})")
        beam = max(list(width_of.values()) + [default_beam])  # grid width
        K = self._resolve_burst(burst_len)
        ctrl = self._burst_controller(K)
        n_groups = n_slots // beam
        if n_groups < 1:
            raise ValueError(f"n_slots={n_slots} rows cannot hold a "
                             f"beam-{beam} group")
        R = n_groups * beam                 # rows actually in the grid
        if not reqs:
            return ServeResult(requests=[], n_slots=R, decode_steps=0,
                               busy_slot_steps=0, prefill_rounds=0,
                               wall_s=0.0, host_syncs=0,
                               burst_len=ctrl.k if ctrl else K,
                               beam=beam, fused_admission=fused_admission,
                               auto_burst=ctrl is not None,
                               paged=self.paged, page_size=self.page_size,
                               **self._mesh_result_fields(R))
        if max(r.max_new_tokens for r in reqs) > self.max_len:
            raise ValueError("a request's max_new_tokens exceeds the "
                             f"engine KV capacity {self.max_len}")
        width = next_pow2(ctrl.max_burst if ctrl else K)
        burst = self._beam_serve_burst_fn(width, beam)
        fused_burst = (self._fused_beam_serve_burst_fn(width, beam)
                       if fused_admission else None)
        enc_len = self._enc_bucket(reqs, pad_to_multiple)
        pc = self._resolve_prefix_cache(prefix_cache)
        stats0 = pc.stats.snapshot() if pc else None

        allocator = None
        if self.paged:
            allocator = self._make_allocator(R, overcommit)
            for r in reqs:
                need = self._pages_per_request(r, width_of[r.req_id])
                if need > allocator.n_pages:
                    raise ValueError(
                        f"request {r.req_id} needs {need} pages but the "
                        f"pool holds {allocator.n_pages}")
        burst_hint = ctrl.max_burst if ctrl else K
        initial_fn = None
        if allocator is not None and overcommit > 1.0:
            initial_fn = lambda r: self._initial_pages(
                r, width_of[r.req_id], burst_hint)
        sched = ContinuousScheduler(
            R, group_size=beam, prefill_token_budget=prefill_token_budget,
            allocator=allocator,
            pages_per_request=(
                (lambda r: self._pages_per_request(r, width_of[r.req_id]))
                if allocator else None),
            prefix_cache=pc, initial_pages=initial_fn,
            prefill_chunk=prefill_chunk)
        sched.submit_many(reqs)

        quantized = self.quant.quantize_kv
        state = self.model.init_decode_state(
            R, self.max_len, quantized=quantized, enc_len=enc_len,
            paged=self.paged, page_size=self.page_size,
            n_pages=allocator.n_pages if allocator else None)
        if pc is not None:
            state["prefix_k"], state["prefix_v"] = self._prefix_pool
        state = self._shard_state(state)
        tokens = jnp.zeros((R,), jnp.int32)
        # bytes one beam step's cache reorder moves: paged = the table
        # permutation + one partial-page copy per row; unpaged = the whole
        # KV slab plus the per-row cross-K/V gather
        cache0 = state["cache"]
        if self.paged:
            reorder_step_bytes = cache0.reorder_bytes_per_step()
        else:
            cross_bytes = 0
            if state["cross_k"] is not None:
                cross_bytes = 2 * (state["cross_k"].size
                                   * state["cross_k"].dtype.itemsize)
            reorder_step_bytes = cache0.nbytes() + cross_bytes
        # host-side per-row beam state (re-uploaded each burst, bit-exact)
        scores_np = np.zeros((R,), np.float32)
        finished_np = np.ones((R,), bool)        # unoccupied rows are inert
        histories: Dict[int, List[np.ndarray]] = {}  # base → (beam,) columns
        budget_left: Dict[int, int] = {}             # base → decode steps left

        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0
        decode_steps = 0
        busy_slot_steps = 0
        prefill_rounds = 0
        host_syncs = 0
        prefill_dispatches = 0
        encoder_tokens = 0
        # fixed caps upload the device scalar once; auto rebuilds per round
        cap_fixed = None if ctrl else jnp.asarray(K, jnp.int32)
        # ---- overload machinery (all inert on an unloaded serve)
        store = SpillStore()
        watchdog = StepWatchdog()
        staging: Dict[int, Dict[str, Any]] = {}   # base → staged-encode state
        eager_free = (overcommit > 1.0) or (chaos is not None)
        preempt_count = 0
        peak_running = 0
        chunked_admissions = 0
        chunk_rounds = 0
        round_idx = 0
        maxP = self._max_pages

        def preempt_req(req: Request) -> None:
            """Spill one running group — all ``beam`` rows' device state
            plus the host-side search state — and evict it (a mid-stage
            chunked prefill just drops its stage and restages later)."""
            nonlocal state, host_syncs, preempt_count
            base = req.slot
            if base in staging:
                staging.pop(base)
                sched.preempt(req, now())
            else:
                rows = np.arange(base, base + beam, dtype=np.int32)
                outs = self._spill_fn(beam)(state, tokens,
                                            jnp.asarray(rows))
                k, v, ks, vs, lens, toks, ck, cv, slens = [
                    None if o is None else np.asarray(o) for o in outs]
                host_syncs += 1
                req.spill = SpilledRequest(
                    req_id=req.req_id, n_rows=beam, k=k, v=v, k_scale=ks,
                    v_scale=vs, lengths=lens, tokens_row=toks, cross_k=ck,
                    cross_v=cv, src_lengths=slens,
                    n_pages=len(req.pages or []),
                    beam={"scores": scores_np[base:base + beam].copy(),
                          "finished": finished_np[base:base + beam].copy(),
                          "history": histories.pop(base, []),
                          "budget_left": budget_left.pop(base, 0)})
                store.put(req.spill)
                sched.preempt(req, now())
                finished_np[base:base + beam] = True   # rows now inert
            preempt_count += 1
            state = dict(state)
            state["cache"] = kvc.free_slots_paged(
                state["cache"],
                np.arange(base, base + beam, dtype=np.int32))

        def grow_rows(k_cap: int) -> None:
            """Pre-burst page growth at group granularity: each live row
            of a running group gets pages for its cursor + next burst."""
            nonlocal state
            if initial_fn is None:
                return
            for base, req in list(sched.slot_map.items()):
                if sched.slot_map.get(base) is not req or base in staging:
                    continue
                b = width_of[req.req_id]
                cursor = req.max_new_tokens - budget_left[base]
                cap_tok = min(req.max_new_tokens, self.max_len)
                need = kvc.pages_per_row(min(cursor + k_cap, cap_tok),
                                         self.page_size)
                have_pr = len(req.pages) // b
                extra_pr = need - have_pr
                if extra_pr <= 0:
                    continue
                extra = extra_pr * b
                newp = allocator.alloc(extra)
                while newp is None:
                    victims, covered = pick_victims(
                        [r for r in sched.slot_map.values() if r is not req],
                        pages_needed=extra - allocator.n_free,
                        key_fn=sched.victim_key,
                        pages_held_fn=lambda r: len(r.pages or []))
                    if not victims or not covered:
                        # fail BEFORE spilling (see greedy grow_rows)
                        raise RuntimeError(
                            "page growth wedged: no preemptable victim "
                            f"set covers request {req.req_id}'s need "
                            f"({extra} pages)")
                    for v in victims:
                        preempt_req(v)
                    newp = allocator.alloc(extra)
                upd = np.full((beam, maxP), -1, np.int32)
                for i in range(b):
                    upd[i, have_pr:have_pr + extra_pr] = \
                        newp[i * extra_pr:(i + 1) * extra_pr]
                # flat page list becomes interleaved after growth — only
                # len() (growth) and release (order-agnostic) read it from
                # here on; a resume always reallocates fresh
                req.pages.extend(newp)
                state = self._grow_fn(beam)(
                    state,
                    jnp.asarray(np.arange(base, base + beam,
                                          dtype=np.int32)),
                    jnp.asarray(upd))

        def preempt_for_admission() -> None:
            if initial_fn is None:
                return
            for _ in range(n_groups + len(reqs)):
                short = sched.admission_shortfall()
                if short is None:
                    return
                need = max(short["pages_short"], 1)
                victims, covered = pick_victims(
                    list(sched.slot_map.values()), pages_needed=need,
                    key_fn=sched.victim_key,
                    pages_held_fn=lambda r: len(r.pages or []),
                    min_key=short["head_key"])
                if not victims or not covered:
                    # insufficient coverage: spilling these victims would
                    # not let the head request in — keep them running
                    return
                for v in victims:
                    preempt_req(v)

        def restore_resumed(resumed: List[Request]) -> None:
            """Re-splice spilled groups: device KV through the paged
            splice, host search state verbatim."""
            nonlocal state, tokens
            for req in resumed:
                sp = req.spill
                base, b = req.slot, width_of[req.req_id]
                pages = self._page_rows([req], beam, 1, allocator.n_pages,
                                        widths=[b])
                rows = np.arange(base, base + beam, dtype=np.int32)
                state, tokens = self._resume_fn(beam)(
                    state, tokens, jnp.asarray(rows), jnp.asarray(pages),
                    jnp.asarray(sp.k), jnp.asarray(sp.v),
                    None if sp.k_scale is None else jnp.asarray(sp.k_scale),
                    None if sp.v_scale is None else jnp.asarray(sp.v_scale),
                    jnp.asarray(sp.lengths), jnp.asarray(sp.tokens_row),
                    jnp.asarray(sp.cross_k), jnp.asarray(sp.cross_v),
                    jnp.asarray(sp.src_lengths))
                scores_np[base:base + beam] = sp.beam["scores"]
                finished_np[base:base + beam] = sp.beam["finished"]
                histories[base] = list(sp.beam["history"])
                budget_left[base] = sp.beam["budget_left"]
                store.pop(req.req_id)
                allocator.unspill(sp.n_pages)
                req.spill = None

        def advance_staging() -> None:
            """One encoder layer per round for staged (chunked) prefills;
            completion splices the group and seeds its beam state exactly
            like fused admission."""
            nonlocal state, tokens, chunk_rounds
            n_enc = self.model.cfg.n_enc_layers
            for base, st in list(staging.items()):
                req = st["req"]
                if st["x"] is None:
                    src = np.zeros((1, enc_len), np.int32)
                    src[0, :req.n_src_tokens] = req.src
                    st["lens"] = jnp.asarray(
                        np.asarray([req.n_src_tokens], np.int32))
                    begin, _ = self._stage_fns()
                    st["x"] = begin(self.params, jnp.asarray(src),
                                    st["lens"])
                st["x"] = self._stage_layer_fn(st["li"])(
                    self.params, st["x"], st["lens"])
                st["li"] += 1
                chunk_rounds += 1
                if st["li"] >= n_enc:
                    _, finish = self._stage_fns()
                    ck, cv, slens = finish(self.params, st["x"], st["lens"])
                    b = width_of[req.req_id]
                    extra = {}
                    if allocator:
                        extra["pages"] = jnp.asarray(self._page_rows(
                            [req], beam, 1, allocator.n_pages, widths=[b]))
                    state, tokens = self._chunk_splice_fn(beam)(
                        state, tokens, ck, cv, slens,
                        jnp.asarray(np.asarray([base], np.int32)), extra)
                    scores_np[base] = 0.0
                    scores_np[base + 1:base + beam] = BEAM_SEED_NEG
                    finished_np[base:base + b] = False
                    finished_np[base + b:base + beam] = True
                    histories[base] = []
                    budget_left[base] = req.max_new_tokens
                    staging.pop(base)

        def finalize(req: Request, base: int, t: float, step: int) -> int:
            """Pick the group's winner (same helper ``generate_beam``
            uses), then release the request (returns the freed base row).
            Only the request's own ``beam`` rows compete — parked tail
            rows of a narrow group carry no hypotheses."""
            b = width_of[req.req_id]
            grid = np.stack(histories.pop(base), axis=1)[:b]   # (b, T)
            toks, score = self._winner(grid, scores_np[base:base + b],
                                       alpha, self.eos_id)
            req.tokens = [int(x) for x in toks]
            req.score = score
            budget_left.pop(base, None)
            finished_np[base:base + beam] = True
            return sched.release(req, t, step=step)

        def prefill_groups(admitted, state, tokens):
            """Prefill admitted requests replicated to their beam rows and
            splice the groups in; drain first tokens (one top-k per group,
            identical to ``generate_beam``'s first step)."""
            g = len(admitted)
            rows = g * beam
            src_pad, lens = pad_batch([r.src for r in admitted],
                                      length=enc_len)
            logits, sub, width = self._prefill_padded(
                np.repeat(src_pad, beam, axis=0),
                np.repeat(lens, beam, axis=0))
            if pc is not None and any(r.prefix_role == "insert"
                                      for r in admitted):
                # the tiled side batch holds request i's (batch-independent)
                # encode at row i*beam — scatter that row into its chain
                ins = sched.chain_pages_matrix(admitted, width, enc_len,
                                               stride=beam)
                state = self._pool_insert_fn()(
                    state, sub["cross_k"], sub["cross_v"], jnp.asarray(ins))
            # log-softmax at the padded width (device shapes stay a
            # function of the pow2 bucket); the (g, beam)-shaped first-step
            # top-k moves to the host, where a stable argsort of the
            # negated row reproduces jax.lax.top_k exactly (descending
            # values, ties broken by ascending index) on the same float32
            # log-probs generate_beam's device top-k selects from
            lp = np.asarray(
                jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1))
            first = lp[:rows].reshape(g, beam, -1)[:, 0]     # (g, V)
            tok_host = np.argsort(-first, axis=-1,
                                  kind="stable")[:, :beam].astype(np.int32)
            sc_host = np.take_along_axis(first, tok_host, axis=-1)
            # narrow requests: only the first beam_req candidates become
            # hypotheses; the parked tail rows seed as finished EOS rows
            # at the score floor (exactly the fused path's park seed)
            for i, r in enumerate(admitted):
                b = width_of[r.req_id]
                tok_host[i, b:] = self.eos_id
                sc_host[i, b:] = BEAM_SEED_NEG
            sub_np = np.full((width,), self.eos_id, np.int32)
            sub_np[:rows] = tok_host.reshape(rows)
            pages = None
            if allocator:
                pages = np.full((width, self._max_pages), allocator.n_pages,
                                np.int32)
                pages[:rows] = self._page_rows(
                    admitted, beam, g, allocator.n_pages,
                    widths=[width_of[r.req_id] for r in admitted])
            state, tokens = self._splice_rows(
                state, tokens, sub, jnp.asarray(sub_np),
                np.asarray(kvc.group_rows(
                    np.asarray([r.slot for r in admitted], np.int32),
                    beam)),
                width, pages=pages)
            t = now()
            for i, r in enumerate(admitted):
                base, b = r.slot, width_of[r.req_id]
                r.first_token_s = t
                if r.max_new_tokens <= 0:
                    finished_np[base:base + beam] = True
                    sched.release(r, t, step=decode_steps)
                    continue                     # zero budget: empty output
                scores_np[base:base + beam] = sc_host[i]
                fin = tok_host[i] == self.eos_id
                fin[b:] = True                   # parked rows stay finished
                finished_np[base:base + beam] = fin
                histories[base] = [tok_host[i].astype(np.int32)]
                budget_left[base] = r.max_new_tokens - 1
                if fin.all() or budget_left[base] <= 0:
                    finalize(r, base, t, step=decode_steps)
            return state, tokens

        while not sched.all_done:
            rnd = round_idx
            round_idx += 1
            # (a) chaos: forced preemptions at this round edge
            if chaos is not None and sched.slot_map:
                by_id = {r.req_id: r for r in sched.slot_map.values()}
                for rid in chaos.victims_for(rnd, list(by_id)):
                    preempt_req(by_id[rid])
            # (b) overcommit growth for mid-flight groups (may itself evict)
            grow_rows(ctrl.k if ctrl else K)
            # (c) admission pressure: evict strictly-less-urgent victims
            preempt_for_admission()
            plan = None
            admitted = []
            want_admit = (sched.n_waiting and sched.n_free >=
                          min(max(admit_min_free, 1), sched.n_waiting,
                              n_groups))
            if want_admit and fused_admission:
                # encode-once fused admission: the plan carries ONE source
                # row per request; the burst program broadcasts it across
                # the group's rows.  Host seeds the group's beam state so
                # the shared step's first iteration IS generate_beam's
                # first step (see _make_fused_beam_serve_burst).
                plan = sched.plan_admission(now(), step=decode_steps,
                                            enc_len=enc_len, oob_row=R)
                if plan.n_admitted:
                    prefill_rounds += 1
                encoder_tokens += len(plan.requests) * enc_len
                if plan.resumed:
                    restore_resumed(plan.resumed)
                for r in plan.staged:
                    staging[r.slot] = {"req": r, "x": None, "li": 0,
                                       "lens": None}
                chunked_admissions += len(plan.staged)
                encoder_tokens += len(plan.staged) * enc_len
                for r in plan.requests + plan.hits:
                    base, b = r.slot, width_of[r.req_id]
                    scores_np[base] = 0.0
                    scores_np[base + 1:base + beam] = BEAM_SEED_NEG
                    finished_np[base:base + b] = False
                    finished_np[base + b:base + beam] = True   # parked tail
                    histories[base] = []
                    budget_left[base] = r.max_new_tokens
            elif want_admit:
                admitted = sched.admit(now(), step=decode_steps)
                if admitted:
                    prefill_rounds += 1
                    resumed = [r for r in admitted if r.spill is not None]
                    fresh = [r for r in admitted if r.spill is None]
                    if resumed:
                        restore_resumed(resumed)
                    hits: List[Request] = []
                    if pc is not None:
                        # zero-budget requests skip prefix routing: they
                        # release inside prefill_groups before any
                        # finish() could pair with their admit()
                        misses, hits = sched.assign_prefix(
                            [r for r in fresh if r.max_new_tokens > 0])
                        enc_list = misses + [r for r in fresh
                                             if r.max_new_tokens <= 0]
                    else:
                        enc_list = fresh
                    if enc_list:
                        prefill_dispatches += 1
                        host_syncs += 1   # first-token drain syncs the host
                        # the unfused side batch tiles each source beam×
                        # through the encoder — the FLOP tax encode-once
                        # fusion removes
                        encoder_tokens += len(enc_list) * beam * enc_len
                        state, tokens = prefill_groups(enc_list, state,
                                                       tokens)
                    if hits:
                        # no encoder: gather cached chains, splice them
                        # across each group's rows, and seed the group
                        # exactly like fused admission (first tokens arrive
                        # with the next burst, in final beam order)
                        hrows, hlens, hpages, hw = sched.shape_hits(
                            hits, enc_len=enc_len, oob_row=R)
                        extra = ({"dec_pages": jnp.asarray(self._page_rows(
                                     hits, beam, hw, allocator.n_pages,
                                     widths=[width_of[r.req_id]
                                             for r in hits]))}
                                 if allocator else {})
                        state, tokens = self._hit_splice_fn(beam)(
                            state, tokens, jnp.asarray(hpages),
                            jnp.asarray(hlens), jnp.asarray(hrows), extra)
                        for r in hits:
                            base, b = r.slot, width_of[r.req_id]
                            scores_np[base] = 0.0
                            scores_np[base + 1:base + beam] = BEAM_SEED_NEG
                            finished_np[base:base + b] = False
                            finished_np[base + b:base + beam] = True
                            histories[base] = []
                            budget_left[base] = r.max_new_tokens
            peak_running = max(peak_running, sched.n_running)
            if not sched.slot_map:
                continue    # every admitted group finished on token 1

            # staging groups stay at budget 0 / finished rows — they hold
            # no KV yet; the fused prologue re-sentinels their tables and
            # the burst's act mask keeps their rows frozen
            remaining_in = np.zeros((n_groups,), np.int32)
            parked_np = np.zeros((R,), bool)
            for base, req in sched.slot_map.items():
                if base in staging:
                    continue
                remaining_in[base // beam] = budget_left[base]
                parked_np[base + width_of[req.req_id]:base + beam] = True
            has_adm = plan is not None and (plan.width or plan.hit_width)
            if not remaining_in.any() and not has_adm:
                # pure-staging round: nothing to decode — push the staged
                # encodes one layer and come back
                advance_staging()
                continue
            parked = jnp.asarray(parked_np)
            cap = jnp.asarray(ctrl.k, jnp.int32) if ctrl else cap_fixed
            t_dispatch = time.perf_counter()
            if plan is not None and (plan.width or plan.hit_width):
                extra = {}
                if allocator and plan.width:
                    extra["pages"] = jnp.asarray(self._page_rows(
                        plan.requests, beam, plan.width, allocator.n_pages,
                        widths=[width_of[r.req_id]
                                for r in plan.requests]))
                if pc is not None and plan.width:
                    extra["ins_pages"] = jnp.asarray(plan.ins_pages)
                if plan.hit_width:
                    extra["hit_rows"] = jnp.asarray(plan.hit_rows)
                    extra["hit_lens"] = jnp.asarray(plan.hit_lengths)
                    extra["hit_pages"] = jnp.asarray(plan.hit_pages)
                    if allocator:
                        extra["hit_dec_pages"] = jnp.asarray(self._page_rows(
                            plan.hits, beam, plan.hit_width,
                            allocator.n_pages,
                            widths=[width_of[r.req_id]
                                    for r in plan.hits]))
                (tokens, scores_dev, finished_dev, remaining_dev, comp,
                 state, buf, steps_dev) = fused_burst(
                    self.params, tokens, jnp.asarray(scores_np),
                    jnp.asarray(finished_np), jnp.asarray(remaining_in),
                    cap, state, parked, jnp.asarray(plan.src_tokens),
                    jnp.asarray(plan.src_lengths),
                    jnp.asarray(plan.base_rows), extra)
            else:
                (tokens, scores_dev, finished_dev, remaining_dev, comp,
                 state, buf, steps_dev) = burst(
                    self.params, tokens, jnp.asarray(scores_np),
                    jnp.asarray(finished_np), jnp.asarray(remaining_in),
                    cap, state, parked)
            buf_host = np.asarray(buf)         # ONE host sync per burst
            comp_host = np.asarray(comp)
            scores_np = np.array(scores_dev, np.float32)
            finished_np = np.array(finished_dev, bool)
            remaining_out = np.asarray(remaining_dev)
            steps = int(steps_dev)
            burst_wall = time.perf_counter() - t_dispatch
            host_syncs += 1
            step_base = decode_steps
            decode_steps += steps

            # drain at the burst edge: replay each group's composed beam
            # permutation over its host-side history, append its new ring
            # columns, finalize groups that finished or spent their budget
            t = now()
            freed = []
            wasted_row_steps = 0
            for base, req in list(sched.slot_map.items()):
                if base in staging:
                    # staged encode in flight: the group's rows rode the
                    # burst frozen (finished, budget 0) — pure overhead
                    wasted_row_steps += steps * beam
                    continue
                gi = base // beam
                s_g = int(remaining_in[gi] - remaining_out[gi])
                if req.first_token_s is None:
                    req.first_token_s = t   # fused: emitted by this burst
                if s_g:
                    local = comp_host[base:base + beam] - base
                    hist = [c[local] for c in histories[base]]
                    hist.extend(buf_host[base:base + beam, j]
                                for j in range(s_g))
                    histories[base] = hist
                    budget_left[base] -= s_g
                # parked rows of narrow requests are computed-but-idle grid
                b_req = width_of[req.req_id]
                busy_slot_steps += s_g * b_req
                wasted_row_steps += (steps - s_g) * beam + \
                    s_g * (beam - b_req)
                if finished_np[base:base + beam].all() or \
                        budget_left[base] <= 0:
                    freed.append(finalize(req, base, t,
                                          step=step_base + s_g))
            if ctrl:
                ctrl.observe(burst_wall, steps, wasted_row_steps, R)
            watchdog.observe(burst_wall +
                             (chaos.slow_for(rnd) if chaos else 0.0))
            if freed and (not fused_admission or eager_free):
                # fused mode resets dead cursors inside the next admission
                # burst's prologue (kv_cache.free_inactive) — no dispatch.
                # Under overcommit/chaos, free eagerly even then: growth or
                # resume may hand the freed pages to another group before
                # any admission prologue runs, and the dead group's stale
                # block table would route masked-but-real writes into them.
                state = dict(state)
                if self.paged:
                    state["cache"] = kvc.free_slots_paged(
                        state["cache"],
                        kvc.group_rows(np.asarray(freed, np.int32), beam))
                else:
                    state["cache"] = kvc.free_groups(
                        state["cache"], np.asarray(freed, np.int32), beam)
            # staged encodes advance one layer per serving round
            advance_staging()

        if pc is not None:
            # hand the (possibly donated-through) pool arrays back to the
            # engine so the next serve and the tree agree on contents
            self._prefix_pool = (state["prefix_k"], state["prefix_v"])
        return ServeResult(requests=reqs, n_slots=R,
                           decode_steps=decode_steps,
                           busy_slot_steps=busy_slot_steps,
                           prefill_rounds=prefill_rounds, wall_s=now(),
                           host_syncs=host_syncs,
                           burst_len=ctrl.k if ctrl else K, beam=beam,
                           prefill_dispatches=prefill_dispatches,
                           encoder_tokens=encoder_tokens,
                           fused_admission=fused_admission,
                           auto_burst=ctrl is not None,
                           paged=self.paged, page_size=self.page_size,
                           pages_in_use=allocator.in_use if allocator else 0,
                           page_hwm=allocator.hwm if allocator else 0,
                           reorder_bytes=reorder_step_bytes * decode_steps,
                           **self._mesh_result_fields(R),
                           **self._overload_result_fields(
                               overcommit, preempt_count, store, watchdog,
                               sched, reqs, allocator, peak_running,
                               chunked_admissions, chunk_rounds),
                           **self._prefix_result_fields(pc, stats0))

    # ------------------------------------------------------------------ beam
    def generate_beam(self, batch: Dict[str, np.ndarray], *, beam: int = 4,
                      max_new_tokens: int = 64, alpha: float = 0.6,
                      burst_len: Optional[int] = None) -> GenerationResult:
        """Beam search with per-step cache reordering (paper's GatherNd).

        The whole per-step body — log-softmax, top-k, score update, cache
        gather — runs inside the jitted burst; the host reorders the token
        history once per burst via the composed beam permutation.
        """
        K = self._resolve_burst(burst_len)
        if K == "auto":
            K = 8      # adaptation targets serve(); static batches use a mid cap
        bfn = self._beam_burst_fn(next_pow2(K), beam)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        B = next(iter(batch.values())).shape[0]

        # expand each request to `beam` rows
        def tile(a):
            return jnp.repeat(a, beam, axis=0)
        beam_batch = {k: tile(v) for k, v in batch.items()}
        BB = B * beam

        t0 = time.perf_counter()
        state = self._init_state(BB)
        logits, state = self._prefill(self.params, beam_batch, state)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        logprobs = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        V = logprobs.shape[-1]
        # first step: take top-`beam` distinct tokens of beam 0 per request
        first = logprobs.reshape(B, beam, V)[:, 0]              # (B, V)
        scores, tok0 = jax.lax.top_k(first, beam)               # (B, beam)
        scores = scores.reshape(BB)
        tokens = tok0.reshape(BB).astype(jnp.int32)
        seq = [np.asarray(tokens)]
        host_syncs = 1
        finished = tokens == self.eos_id
        all_done = bool(jnp.all(finished))

        steps_left = max_new_tokens - 1
        while steps_left > 0 and not all_done:
            cap = jnp.asarray(min(K, steps_left), jnp.int32)
            tokens, scores, finished, comp, state, buf, s = bfn(
                self.params, tokens, scores, finished, cap, state)
            s = int(s)
            comp_host = np.asarray(comp)
            buf_host = np.asarray(buf)
            all_done = bool(np.asarray(finished).all())
            host_syncs += 1
            # ---- the paper's §5.3 hot op happened on device; replay the
            # composed reorder over the host-side history once per burst
            seq = [c[comp_host] for c in seq]
            seq.extend(buf_host[:, i] for i in range(s))
            steps_left -= s
        jax.block_until_ready(scores)
        t2 = time.perf_counter()

        # best beam per request by length-penalized score
        grid = np.stack(seq, axis=1)                             # (BB, T)
        scores_host = np.asarray(scores, np.float32)
        seqs = [self._winner(grid[b * beam:(b + 1) * beam],
                             scores_host[b * beam:(b + 1) * beam],
                             alpha, self.eos_id)[0]
                for b in range(B)]
        return GenerationResult(tokens=seqs, steps=len(seq),
                                prefill_s=t1 - t0, decode_s=t2 - t1,
                                host_syncs=host_syncs)
