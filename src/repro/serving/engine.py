"""Serving engine: prefill + auto-regressive decode (greedy & beam search).

This is the paper's workload: batched NMT inference with a decoder
while-loop.  Beam search reorders the KV cache every step through
``kv_cache.gather_beams`` — the GatherNd the paper quantized (§5.3); with an
INT8 cache the reorder moves 4× fewer bytes.

The decode loop runs in Python calling jitted step functions (the standard
serving pattern — state stays on device; only the finished-check syncs).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.data.synthetic import EOS
from repro.models import kv_cache as kvc


@dataclasses.dataclass
class GenerationResult:
    tokens: List[np.ndarray]          # per-sequence generated ids (no EOS)
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def n_tokens(self) -> int:
        return int(sum(len(t) for t in self.tokens))


class ServingEngine:
    def __init__(self, model, params, *, quant: QuantContext = FP_CONTEXT,
                 max_len: int = 256, eos_id: int = EOS,
                 donate_state: bool = True):
        self.model = model
        self.params = params
        self.quant = quant
        self.max_len = max_len
        self.eos_id = eos_id

        self._prefill = jax.jit(
            lambda p, b, s: model.prefill(p, b, s, quant=quant))
        donate = (2,) if donate_state else ()
        self._decode = jax.jit(
            lambda p, t, s: model.decode_step(p, t, s, quant=quant),
            donate_argnums=donate)
        self._gather = jax.jit(self._beam_gather_state)

    # ------------------------------------------------------------------ util
    def _init_state(self, batch_size: int):
        return self.model.init_decode_state(
            batch_size, self.max_len, quantized=self.quant.quantize_kv)

    @staticmethod
    def _beam_gather_state(state: Dict[str, Any], idx: jax.Array):
        """Reorder every batch-major leaf of the decode state (paper §5.3)."""
        def gather(leaf):
            return jnp.take(leaf, idx, axis=0)

        out = {}
        for k, v in state.items():
            if k == "cache" and isinstance(v, kvc.KVCache):
                out[k] = kvc.gather_beams(v, idx)
            elif v is None:
                out[k] = None
            else:
                out[k] = jax.tree_util.tree_map(gather, v)
        return out

    # ---------------------------------------------------------------- greedy
    def generate(self, batch: Dict[str, np.ndarray], *,
                 max_new_tokens: int = 64) -> GenerationResult:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        B = next(iter(batch.values())).shape[0]

        t0 = time.perf_counter()
        state = self._init_state(B)
        logits, state = self._prefill(self.params, batch, state)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        tokens = jnp.argmax(logits, axis=-1)
        out = [tokens]
        finished = tokens == self.eos_id
        steps = 1
        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tokens, state)
            tokens = jnp.argmax(logits, axis=-1)
            tokens = jnp.where(finished, self.eos_id, tokens)
            out.append(tokens)
            finished = finished | (tokens == self.eos_id)
            steps += 1
            if bool(jnp.all(finished)):
                break
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()

        grid = np.stack([np.asarray(t) for t in out], axis=1)   # (B, T)
        seqs = []
        for b in range(B):
            row = grid[b]
            stop = np.argmax(row == self.eos_id) if (row == self.eos_id).any() \
                else len(row)
            seqs.append(row[:stop])
        return GenerationResult(tokens=seqs, steps=steps,
                                prefill_s=t1 - t0, decode_s=t2 - t1)

    # ------------------------------------------------------------------ beam
    def generate_beam(self, batch: Dict[str, np.ndarray], *, beam: int = 4,
                      max_new_tokens: int = 64, alpha: float = 0.6
                      ) -> GenerationResult:
        """Beam search with per-step cache reordering (paper's GatherNd)."""
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        B = next(iter(batch.values())).shape[0]

        # expand each request to `beam` rows
        def tile(a):
            return jnp.repeat(a, beam, axis=0)
        beam_batch = {k: tile(v) for k, v in batch.items()}
        BB = B * beam

        t0 = time.perf_counter()
        state = self._init_state(BB)
        logits, state = self._prefill(self.params, beam_batch, state)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        logprobs = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        V = logprobs.shape[-1]
        # first step: take top-`beam` distinct tokens of beam 0 per request
        first = logprobs.reshape(B, beam, V)[:, 0]              # (B, V)
        scores, tok0 = jax.lax.top_k(first, beam)               # (B, beam)
        scores = scores.reshape(BB)
        tokens = tok0.reshape(BB)
        seq = [np.asarray(tokens)]
        reorders = 0
        finished = tokens == self.eos_id

        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tokens, state)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            # finished beams only extend with EOS at no cost
            eos_only = jnp.full_like(lp, -1e30).at[:, self.eos_id].set(0.0)
            lp = jnp.where(finished[:, None], eos_only, lp)
            cand = scores[:, None] + lp                          # (BB, V)
            cand = cand.reshape(B, beam * V)
            scores_new, flat_idx = jax.lax.top_k(cand, beam)     # (B, beam)
            src_beam = flat_idx // V                             # (B, beam)
            tokens = (flat_idx % V).reshape(BB)
            gather_idx = (src_beam + jnp.arange(B)[:, None] * beam
                          ).reshape(BB)
            # ---- the paper's §5.3 hot op: cache reorder ----
            state = self._gather(state, gather_idx)
            reorders += 1
            scores = scores_new.reshape(BB)
            finished = jnp.take(finished, gather_idx, axis=0) | \
                (tokens == self.eos_id)
            seq = [s[np.asarray(gather_idx)] for s in seq]
            seq.append(np.asarray(tokens))
            if bool(jnp.all(finished)):
                break
        jax.block_until_ready(tokens)
        t2 = time.perf_counter()

        # best beam per request by length-penalized score
        grid = np.stack(seq, axis=1)                             # (BB, T)
        lengths = np.argmax(grid == self.eos_id, axis=1)
        lengths = np.where((grid == self.eos_id).any(axis=1), lengths,
                           grid.shape[1])
        lp_pen = ((5 + lengths) / 6.0) ** alpha
        final = np.asarray(scores).reshape(B, beam) / \
            lp_pen.reshape(B, beam)
        best = final.argmax(axis=1)
        seqs = []
        for b in range(B):
            row = grid[b * beam + best[b]]
            stop = lengths[b * beam + best[b]]
            seqs.append(row[:stop])
        return GenerationResult(tokens=seqs, steps=len(seq),
                                prefill_s=t1 - t0, decode_s=t2 - t1)
