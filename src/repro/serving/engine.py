"""Serving engine: prefill + auto-regressive decode (greedy, beam, continuous).

This is the paper's workload: batched NMT inference with a decoder
while-loop.  Beam search reorders the KV cache every step through
``kv_cache.gather_beams`` — the GatherNd the paper quantized (§5.3); with an
INT8 cache the reorder moves 4× fewer bytes.

Beyond the paper's static batches, :meth:`ServingEngine.serve` implements
**continuous batching**: a fixed pool of ``n_slots`` decode rows runs one
shared decode step; when a sequence finishes, its KV-cache slot is refilled
by prefilling the next waiting request (``kv_cache.insert_at_slots``) while
the other slots keep decoding.  Admission order and pacing come from
``scheduler.ContinuousScheduler``; prefill side-batches are padded to
power-of-two widths so the whole serve compiles O(log slots) programs.
Greedy decode through ``serve`` is token-identical to per-request
:meth:`generate` — every per-row computation is batch-independent.

The decode loop runs in Python calling jitted step functions (the standard
serving pattern — state stays on device; only the finished-check syncs).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.data.synthetic import EOS, pad_batch
from repro.models import kv_cache as kvc
from repro.serving.scheduler import ContinuousScheduler, Request


@dataclasses.dataclass
class GenerationResult:
    tokens: List[np.ndarray]          # per-sequence generated ids (no EOS)
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def n_tokens(self) -> int:
        return int(sum(len(t) for t in self.tokens))


@dataclasses.dataclass
class ServeResult:
    """Outcome of one continuous-batching serve."""

    requests: List[Request]           # submission order, lifecycle filled in
    n_slots: int
    decode_steps: int
    busy_slot_steps: int              # Σ over steps of occupied slots
    prefill_rounds: int
    wall_s: float

    @property
    def n_tokens(self) -> int:
        return int(sum(len(r.tokens) for r in self.requests))

    @property
    def utilization(self) -> float:
        """Occupied-slot fraction of the decode grid actually computed."""
        return self.busy_slot_steps / max(self.n_slots * self.decode_steps, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.wall_s, 1e-9)

    def tokens_for(self, req_id: int) -> np.ndarray:
        for r in self.requests:
            if r.req_id == req_id:
                return np.asarray(r.tokens, np.int32)
        raise KeyError(req_id)

    def metrics(self) -> Dict[str, float]:
        first = [r.first_token_latency_s for r in self.requests
                 if r.first_token_latency_s is not None]
        total = [r.total_latency_s for r in self.requests
                 if r.total_latency_s is not None]
        return {
            "n_requests": float(len(self.requests)),
            "n_tokens": float(self.n_tokens),
            "wall_s": self.wall_s,
            "tokens_per_s": self.tokens_per_s,
            "utilization": self.utilization,
            "decode_steps": float(self.decode_steps),
            "prefill_rounds": float(self.prefill_rounds),
            "first_token_latency_mean_s": float(np.mean(first)) if first else 0.0,
            "first_token_latency_p95_s":
                float(np.percentile(first, 95)) if first else 0.0,
            "total_latency_mean_s": float(np.mean(total)) if total else 0.0,
            "total_latency_p95_s":
                float(np.percentile(total, 95)) if total else 0.0,
        }


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class ServingEngine:
    def __init__(self, model, params, *, quant: QuantContext = FP_CONTEXT,
                 max_len: int = 256, eos_id: int = EOS,
                 donate_state: bool = True):
        self.model = model
        self.params = params
        self.quant = quant
        self.max_len = max_len
        self.eos_id = eos_id

        self._prefill = jax.jit(
            lambda p, b, s: model.prefill(p, b, s, quant=quant))
        donate = (2,) if donate_state else ()
        self._decode = jax.jit(
            lambda p, t, s: model.decode_step(p, t, s, quant=quant),
            donate_argnums=donate)
        self._gather = jax.jit(self._beam_gather_state)
        # continuous-batching row splice: scatter a prefilled side-batch into
        # the long-lived decode state.  Donates the old state/token buffers —
        # the caller always rebinds to the returned ones.
        self._insert = jax.jit(self._insert_rows, donate_argnums=(0, 2))

    # ------------------------------------------------------------------ util
    def _init_state(self, batch_size: int):
        return self.model.init_decode_state(
            batch_size, self.max_len, quantized=self.quant.quantize_kv)

    @staticmethod
    def _beam_gather_state(state: Dict[str, Any], idx: jax.Array):
        """Reorder every batch-major leaf of the decode state (paper §5.3)."""
        def gather(leaf):
            return jnp.take(leaf, idx, axis=0)

        out = {}
        for k, v in state.items():
            if k == "cache" and isinstance(v, kvc.KVCache):
                out[k] = kvc.gather_beams(v, idx)
            elif v is None:
                out[k] = None
            else:
                out[k] = jax.tree_util.tree_map(gather, v)
        return out

    @staticmethod
    def _insert_rows(state: Dict[str, Any], sub: Dict[str, Any],
                     tokens: jax.Array, sub_tokens: jax.Array,
                     slots: jax.Array):
        """Splice a prefilled side-batch into the running decode state.

        ``slots``: (B_sub,) destination rows; entries ≥ n_slots are padding
        and dropped by jax scatter semantics (admission groups are padded to
        a power-of-two width for compile stability).
        """
        out = dict(state)
        out["cache"] = kvc.insert_at_slots(state["cache"], sub["cache"],
                                           slots)
        out["cross_k"] = state["cross_k"].at[:, slots].set(sub["cross_k"])
        out["cross_v"] = state["cross_v"].at[:, slots].set(sub["cross_v"])
        out["src_lengths"] = state["src_lengths"].at[slots].set(
            sub["src_lengths"])
        tokens = tokens.at[slots].set(sub_tokens)
        return out, tokens

    # ---------------------------------------------------------------- greedy
    def generate(self, batch: Dict[str, np.ndarray], *,
                 max_new_tokens: int = 64) -> GenerationResult:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        B = next(iter(batch.values())).shape[0]

        t0 = time.perf_counter()
        state = self._init_state(B)
        logits, state = self._prefill(self.params, batch, state)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        tokens = jnp.argmax(logits, axis=-1)
        out = [tokens]
        finished = tokens == self.eos_id
        steps = 1
        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tokens, state)
            tokens = jnp.argmax(logits, axis=-1)
            tokens = jnp.where(finished, self.eos_id, tokens)
            out.append(tokens)
            finished = finished | (tokens == self.eos_id)
            steps += 1
            if bool(jnp.all(finished)):
                break
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()

        grid = np.stack([np.asarray(t) for t in out], axis=1)   # (B, T)
        seqs = []
        for b in range(B):
            row = grid[b]
            stop = np.argmax(row == self.eos_id) if (row == self.eos_id).any() \
                else len(row)
            seqs.append(row[:stop])
        return GenerationResult(tokens=seqs, steps=steps,
                                prefill_s=t1 - t0, decode_s=t2 - t1)

    # ------------------------------------------------------------ continuous
    def _as_requests(
        self, requests: Sequence[Any],
        max_new_tokens: Union[int, Sequence[int]],
    ) -> List[Request]:
        per_req = (list(max_new_tokens)
                   if isinstance(max_new_tokens, (list, tuple, np.ndarray))
                   else [int(max_new_tokens)] * len(requests))
        if len(per_req) != len(requests):
            raise ValueError("max_new_tokens sequence length "
                             f"{len(per_req)} != {len(requests)} requests")
        out = []
        for i, (r, m) in enumerate(zip(requests, per_req)):
            if isinstance(r, Request):
                out.append(r)
                continue
            src = r.src if hasattr(r, "src") else np.asarray(r, np.int32)
            out.append(Request(req_id=i, src=np.asarray(src, np.int32),
                               max_new_tokens=int(m)))
        ids = [r.req_id for r in out]
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate req_ids in serve() input (raw "
                             "requests are numbered by position; supplied "
                             "Request ids must not collide)")
        return out

    def serve(self, requests: Sequence[Any], *, n_slots: int = 8,
              max_new_tokens: Union[int, Sequence[int]] = 64,
              prefill_token_budget: Optional[int] = None,
              admit_min_free: int = 1,
              pad_to_multiple: int = 8) -> ServeResult:
        """Continuous-batching greedy decode over a request stream.

        ``requests`` may be ``Sentence``s, raw token arrays, or ``Request``
        objects (the latter carry their own ``max_new_tokens``); submission
        order is arrival order.  All ``n_slots`` rows share one jitted
        decode step; finished rows are released mid-decode
        (``kv_cache.free_slots``) and refilled from the waiting queue
        (``kv_cache.insert_at_slots``), so the decode grid stays saturated
        even when generation lengths are wildly skewed.  Greedy decode is
        token-identical to per-request :meth:`generate`.

        ``admit_min_free`` is admission hysteresis: wait until that many
        slots are free before paying for a prefill round (larger values
        amortize prefill dispatches at a small utilization/latency cost;
        1 = refill immediately).  The last stragglers are always admitted.
        """
        reqs = self._as_requests(requests, max_new_tokens)
        if not reqs:
            return ServeResult(requests=[], n_slots=n_slots, decode_steps=0,
                               busy_slot_steps=0, prefill_rounds=0,
                               wall_s=0.0)
        if max(r.max_new_tokens for r in reqs) > self.max_len:
            raise ValueError("a request's max_new_tokens exceeds the "
                             f"engine KV capacity {self.max_len}")
        m = pad_to_multiple
        enc_len = max(r.n_src_tokens for r in reqs)
        enc_len = ((enc_len + m - 1) // m) * m

        sched = ContinuousScheduler(
            n_slots, prefill_token_budget=prefill_token_budget)
        sched.submit_many(reqs)

        quantized = self.quant.quantize_kv
        state = self.model.init_decode_state(
            n_slots, self.max_len, quantized=quantized, enc_len=enc_len)
        tokens = jnp.zeros((n_slots,), jnp.int32)

        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0
        decode_steps = 0
        busy_slot_steps = 0
        prefill_rounds = 0

        def prefill_into_slots(admitted, state, tokens):
            """Prefill newly admitted requests and splice them in."""
            g = len(admitted)
            width = _next_pow2(g)
            src_pad, lens = pad_batch([r.src for r in admitted],
                                      length=enc_len)
            if width > g:
                # padding rows replay request 0 (results are discarded:
                # their slot index is out of range → the scatter drops them)
                pad_rows = np.broadcast_to(src_pad[0], (width - g, enc_len))
                src_pad = np.concatenate([src_pad, pad_rows], axis=0)
                lens = np.concatenate(
                    [lens, np.broadcast_to(lens[0], (width - g,))])
            sub = self.model.init_decode_state(
                width, self.max_len, quantized=quantized)
            logits, sub = self._prefill(
                self.params,
                {"src_tokens": jnp.asarray(src_pad),
                 "src_lengths": jnp.asarray(lens)},
                sub)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            slots = np.full((width,), n_slots, np.int32)   # OOB sentinel
            slots[:g] = [r.slot for r in admitted]
            state, tokens = self._insert(state, sub, tokens, first,
                                         jnp.asarray(slots))
            first_host = np.asarray(first[:g])
            t = now()
            for r, tok in zip(admitted, first_host):
                r.first_token_s = t
                tok = int(tok)
                if r.max_new_tokens <= 0 or tok == self.eos_id:
                    sched.release(r, t)    # zero budget / empty translation
                else:
                    r.tokens.append(tok)
                    if r.max_new_tokens <= 1:
                        sched.release(r, t)
            return state, tokens

        while not sched.all_done:
            admitted = []
            if sched.n_free >= min(max(admit_min_free, 1), sched.n_waiting,
                                   n_slots) and sched.n_waiting:
                admitted = sched.admit(now())
            if admitted:
                prefill_rounds += 1
                state, tokens = prefill_into_slots(admitted, state, tokens)
            if not sched.slot_map:
                continue        # every admitted request finished on token 1

            logits, state = self._decode(self.params, tokens, state)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks = np.asarray(tokens)              # host sync per step
            decode_steps += 1
            busy_slot_steps += len(sched.slot_map)

            t = now()
            freed = []
            for slot, req in list(sched.slot_map.items()):
                tok = int(toks[slot])
                if tok == self.eos_id:
                    freed.append(sched.release(req, t))
                else:
                    req.tokens.append(tok)
                    if len(req.tokens) >= req.max_new_tokens:
                        freed.append(sched.release(req, t))
            if freed:
                state = dict(state)
                state["cache"] = kvc.free_slots(
                    state["cache"], np.asarray(freed, np.int32))

        return ServeResult(requests=reqs, n_slots=n_slots,
                           decode_steps=decode_steps,
                           busy_slot_steps=busy_slot_steps,
                           prefill_rounds=prefill_rounds, wall_s=now())

    # ------------------------------------------------------------------ beam
    def generate_beam(self, batch: Dict[str, np.ndarray], *, beam: int = 4,
                      max_new_tokens: int = 64, alpha: float = 0.6
                      ) -> GenerationResult:
        """Beam search with per-step cache reordering (paper's GatherNd)."""
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        B = next(iter(batch.values())).shape[0]

        # expand each request to `beam` rows
        def tile(a):
            return jnp.repeat(a, beam, axis=0)
        beam_batch = {k: tile(v) for k, v in batch.items()}
        BB = B * beam

        t0 = time.perf_counter()
        state = self._init_state(BB)
        logits, state = self._prefill(self.params, beam_batch, state)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        logprobs = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        V = logprobs.shape[-1]
        # first step: take top-`beam` distinct tokens of beam 0 per request
        first = logprobs.reshape(B, beam, V)[:, 0]              # (B, V)
        scores, tok0 = jax.lax.top_k(first, beam)               # (B, beam)
        scores = scores.reshape(BB)
        tokens = tok0.reshape(BB)
        seq = [np.asarray(tokens)]
        reorders = 0
        finished = tokens == self.eos_id

        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tokens, state)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            # finished beams only extend with EOS at no cost
            eos_only = jnp.full_like(lp, -1e30).at[:, self.eos_id].set(0.0)
            lp = jnp.where(finished[:, None], eos_only, lp)
            cand = scores[:, None] + lp                          # (BB, V)
            cand = cand.reshape(B, beam * V)
            scores_new, flat_idx = jax.lax.top_k(cand, beam)     # (B, beam)
            src_beam = flat_idx // V                             # (B, beam)
            tokens = (flat_idx % V).reshape(BB)
            gather_idx = (src_beam + jnp.arange(B)[:, None] * beam
                          ).reshape(BB)
            # ---- the paper's §5.3 hot op: cache reorder ----
            state = self._gather(state, gather_idx)
            reorders += 1
            scores = scores_new.reshape(BB)
            finished = jnp.take(finished, gather_idx, axis=0) | \
                (tokens == self.eos_id)
            seq = [s[np.asarray(gather_idx)] for s in seq]
            seq.append(np.asarray(tokens))
            if bool(jnp.all(finished)):
                break
        jax.block_until_ready(tokens)
        t2 = time.perf_counter()

        # best beam per request by length-penalized score
        grid = np.stack(seq, axis=1)                             # (BB, T)
        lengths = np.argmax(grid == self.eos_id, axis=1)
        lengths = np.where((grid == self.eos_id).any(axis=1), lengths,
                           grid.shape[1])
        lp_pen = ((5 + lengths) / 6.0) ** alpha
        final = np.asarray(scores).reshape(B, beam) / \
            lp_pen.reshape(B, beam)
        best = final.argmax(axis=1)
        seqs = []
        for b in range(B):
            row = grid[b * beam + best[b]]
            stop = lengths[b * beam + best[b]]
            seqs.append(row[:stop])
        return GenerationResult(tokens=seqs, steps=len(seq),
                                prefill_s=t1 - t0, decode_s=t2 - t1)
