from repro.serving.engine import GenerationResult, ServingEngine  # noqa: F401
from repro.serving.scheduler import BatchQueue, TokenSortedScheduler, WorkItem  # noqa: F401
from repro.serving.streams import ParallelStreams, simulate_streams  # noqa: F401
