from repro.serving.engine import (  # noqa: F401
    GenerationResult,
    ServeResult,
    ServingEngine,
)
from repro.serving.scheduler import (  # noqa: F401
    BatchQueue,
    ContinuousScheduler,
    Request,
    TokenSortedScheduler,
    WorkItem,
)
from repro.serving.streams import (  # noqa: F401
    ParallelStreams,
    simulate_continuous,
    simulate_streams,
)
