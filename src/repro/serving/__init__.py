from repro.serving.burst_control import AdaptiveBurst  # noqa: F401
from repro.serving.chaos import ChaosSchedule, make_chaos  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    GenerationResult,
    ServeResult,
    ServingEngine,
)
from repro.serving.preemption import (  # noqa: F401
    SpilledRequest,
    SpillStore,
    pick_victims,
)
from repro.serving.router import (  # noqa: F401
    ReplicaRouter,
    RouterResult,
)
from repro.serving.sharding import (  # noqa: F401
    decode_state_shardings,
    kv_pools_shardable,
    tp_degree,
)
from repro.serving.prefix_cache import (  # noqa: F401
    CachedChain,
    PrefixCache,
    PrefixCacheStats,
)
from repro.serving.scheduler import (  # noqa: F401
    AdmissionPlan,
    BatchQueue,
    ContinuousScheduler,
    Request,
    TokenSortedScheduler,
    WorkItem,
)
from repro.serving.streams import (  # noqa: F401
    ParallelStreams,
    simulate_continuous,
    simulate_streams,
)
