"""Serving chaos harness: deterministic, seeded fault injection.

A :class:`ChaosSchedule` rides along a ``ServingEngine.serve`` call and
injects faults at serving-round edges (the only points where the host
touches the loop, so injection composes with fused bursts):

* **forced preemptions** — at round ``r``, preempt ``n`` running victims
  chosen by a seeded RNG over the currently running request ids (so the
  choice is reproducible but not anticipatable by the code under test);
* **synthetic slow rounds** — seconds added to the round's measured wall
  time and fed to ``distributed/fault.py:StepWatchdog.observe`` so the
  straggler path is exercised without real sleeps.

Allocator pressure — the third chaos axis — needs no hook here: build
the engine with a shrunken ``n_pages`` and overcommit does the rest.

The harness exists for one invariant: under ANY schedule, every
request's tokens are bit-identical to an uninterrupted serve, nothing
deadlocks, and the allocator reports full reclaim (0 in use, 0 spilled)
afterwards.  ``tests/test_preemption.py`` runs the matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ChaosSchedule:
    """Seeded fault plan, keyed by serving round index."""

    seed: int = 0
    # round → number of running requests to force-preempt at that edge
    preempt_rounds: Dict[int, int] = dataclasses.field(default_factory=dict)
    # round → synthetic extra wall seconds (feeds the step watchdog)
    slow_rounds: Dict[int, float] = dataclasses.field(default_factory=dict)

    def victims_for(self, round_idx: int,
                    running_ids: Sequence[int]) -> List[int]:
        """Request ids to preempt at this round edge (deterministic in
        ``(seed, round_idx, running_ids)``)."""
        n = self.preempt_rounds.get(round_idx, 0)
        if n <= 0 or not running_ids:
            return []
        ids = sorted(running_ids)
        rng = np.random.default_rng(self.seed * 1000003 + round_idx)
        take = min(n, len(ids))
        return sorted(int(ids[i])
                      for i in rng.choice(len(ids), size=take, replace=False))

    def slow_for(self, round_idx: int) -> float:
        return float(self.slow_rounds.get(round_idx, 0.0))

    @property
    def n_preemptions_planned(self) -> int:
        return sum(self.preempt_rounds.values())


def make_chaos(seed: int, *, n_rounds: int = 16,
               preempt_every: int = 3, victims_per_round: int = 1,
               slow_every: Optional[int] = None,
               slow_s: float = 1.0) -> ChaosSchedule:
    """Convenience schedule: preempt ``victims_per_round`` victims every
    ``preempt_every`` rounds (offset varies with the seed so schedules
    hit different burst edges), optionally marking every ``slow_every``-th
    round as a synthetic straggler."""
    if preempt_every < 1:
        raise ValueError(f"preempt_every must be >= 1, got {preempt_every}")
    offset = seed % preempt_every
    preempt = {r: victims_per_round
               for r in range(1 + offset, n_rounds, preempt_every)}
    slow = {}
    if slow_every:
        slow = {r: slow_s for r in range(slow_every, n_rounds, slow_every)}
    return ChaosSchedule(seed=seed, preempt_rounds=preempt, slow_rounds=slow)
