"""Serving-side batch composition (paper §5.4 + §5.6 front half).

Two generations of scheduler live here:

* ``TokenSortedScheduler`` — the paper's static composer: orders incoming
  requests by **token count** (descending — long batches first keeps the
  stream pipeline busy at the tail), composes fixed-size batches padded to
  bucketed lengths, and exposes them through a thread-safe ``BatchQueue``
  that the parallel streams (``streams.py``) drain asynchronously — the
  paper's parent-session batch queue.

* ``ContinuousScheduler`` — the request-lifecycle manager behind
  ``ServingEngine.serve``: requests flow *waiting → running → finished*
  through a fixed pool of decode **slots**.  Admission is strict FIFO (no
  starvation by construction) with an optional per-round prefill token
  budget; a slot freed by a finished sequence is refilled mid-decode
  instead of idling until the whole batch drains.  Per-request arrival /
  first-token / finish timestamps feed the latency metrics the benchmarks
  report.

  With ``group_size > 1`` (continuous **beam** serving) a request occupies
  a *group* of ``group_size`` contiguous decode rows instead of one: the
  free list holds group base rows, admission hands out whole groups, and
  release frees all ``group_size`` rows atomically — so the engine's
  beam-reorder gathers always stay inside one group's row span and freed
  row sets are always multiples of the beam width.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.data.sorting import make_batches, next_pow2, padding_stats
from repro.data.synthetic import Sentence, pad_batch


@dataclasses.dataclass
class WorkItem:
    batch_id: int
    indices: List[int]                 # request ids in this batch
    batch: Dict[str, np.ndarray]
    n_real_tokens: int
    n_padded_tokens: int


class TokenSortedScheduler:
    """Requests → ordered, padded batches (+ padding accounting)."""

    def __init__(self, batch_size: int, *, sort_mode: str = "tokens",
                 pad_to_multiple: int = 8):
        self.batch_size = batch_size
        self.sort_mode = sort_mode
        self.pad_to_multiple = pad_to_multiple

    def _round(self, n: int) -> int:
        m = self.pad_to_multiple
        return ((n + m - 1) // m) * m

    def plan(self, requests: Sequence[Sentence]) -> List[WorkItem]:
        batches = make_batches(requests, self.batch_size, self.sort_mode)
        items = []
        for bid, idx in enumerate(batches):
            sents = [requests[i] for i in idx]
            L = self._round(max(s.n_tokens for s in sents))
            src, lens = pad_batch([s.src for s in sents], length=L)
            items.append(WorkItem(
                batch_id=bid,
                indices=list(idx),
                batch={"src_tokens": src, "src_lengths": lens},
                n_real_tokens=int(lens.sum()),
                n_padded_tokens=int(L * len(sents)),
            ))
        return items

    def stats(self, requests: Sequence[Sentence]) -> dict:
        batches = make_batches(requests, self.batch_size, self.sort_mode)
        return padding_stats(requests, batches)


@dataclasses.dataclass
class Request:
    """One serving request and its measured lifecycle."""

    req_id: int
    src: np.ndarray                     # (S,) int32 source tokens
    max_new_tokens: int = 64
    arrival_s: float = 0.0
    # SLO knobs (caller-owned config, like ``beam``): absolute deadline on
    # the serve clock (None = best-effort) and a priority boost — both
    # feed the EDF-with-aging wait-queue order and victim selection.
    deadline_s: Optional[float] = None
    priority: float = 0.0

    # lifecycle (scheduler/engine-maintained)
    status: str = "waiting"             # waiting | running | finished | rejected
    slot: Optional[int] = None          # base row of the request's group
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    # decode-step attribution: with burst decode, wall-clock latencies are
    # observed at burst *edges*, so the step counters carry the exact
    # position — admission and release in global decode-step time.
    admitted_step: Optional[int] = None
    finish_step: Optional[int] = None
    # beam serving: winning hypothesis' length-penalized log-prob (None for
    # greedy decode, where there is exactly one hypothesis per request)
    score: Optional[float] = None
    # mixed-beam serving: this request's own beam width (None = the serve
    # call's default).  A request with beam < the grid's group width only
    # runs (and reserves KV pages for) `beam` of its group's rows; the
    # rest are parked.  Caller-owned config — the engine resolves widths
    # into its own map and never writes this field.
    beam: Optional[int] = None
    # paged KV cache: flat page ids reserved for this request (scheduler-
    # managed: allocated at admission, returned at release)
    pages: Optional[List[int]] = None
    # prefix cache (scheduler-managed): how this admission was routed
    # ("hit" | "insert" | "skip" | None when the cache is off) and the
    # chain whose reference this request holds until release
    prefix_role: Optional[str] = None
    prefix_chain: Optional[object] = None
    # overload machinery (scheduler/engine-maintained): why a shed request
    # was rejected; how many times it was preempted; the host-side spill
    # payload (serving/preemption.py:SpilledRequest) while preempted; how
    # many admission rounds it has waited (starvation aging); and the
    # virtual worst-case page reservation it holds under overcommit
    reject_reason: Optional[str] = None
    preemptions: int = 0
    spill: Optional[object] = None
    wait_rounds: int = 0
    reserved_pages: int = 0

    @property
    def n_src_tokens(self) -> int:
        return int(len(self.src))

    @property
    def first_token_latency_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def total_latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s


def pad_rows_pow2(src: np.ndarray, lens: np.ndarray
                  ) -> "tuple[np.ndarray, np.ndarray, int]":
    """Pad an admission batch to the next power-of-two row count.

    Padding rows replay row 0 — their results are discarded downstream
    (out-of-range destination sentinels; jax scatter drop semantics) — so
    prefill programs compile one variant per pow2 width, never per
    admission-group size.  The ONE padding contract shared by the fused
    (``ContinuousScheduler.plan_admission``) and unfused
    (``ServingEngine._prefill_padded``) admission paths: both must
    specialize on identical device shapes or the compile-cache bound and
    the fused/unfused identity guarantee silently break.
    Returns ``(src, lens, width)``.
    """
    n = src.shape[0]
    width = next_pow2(n)
    if width > n:
        src = np.concatenate(
            [src, np.broadcast_to(src[0], (width - n,) + src.shape[1:])],
            axis=0)
        lens = np.concatenate(
            [lens, np.broadcast_to(lens[0], (width - n,))])
    return src, lens, width


_EMPTY_I32 = np.zeros((0,), np.int32)
_EMPTY_I32_2D = np.zeros((0, 0), np.int32)


@dataclasses.dataclass
class AdmissionPlan:
    """One admission round, shaped for the fused decode-burst program.

    The fused-admission engine feeds admissions to the device as *burst
    program inputs* instead of a separate prefill dispatch, so the
    padding contract is device-shaped and compile-stable: sources are
    right-padded to ``enc_len`` columns and the batch is padded to a
    power-of-two ``width`` (padding rows replay row 0; their ``base_rows``
    entry is the out-of-range sentinel ``oob_row``, so every scatter
    inside the burst program drops them).  Zero-budget requests never
    reach the device — they are finished at admission and reported in
    ``released``.
    """

    requests: List[Request]            # admitted, budget > 0, slot order
    released: List[Request]            # zero-budget: finished at admission
    src_tokens: np.ndarray             # (width, enc_len) int32
    src_lengths: np.ndarray            # (width,) int32
    base_rows: np.ndarray              # (width,) int32; padding → oob_row
    width: int                         # pow2 batch width (0 = no device work)
    # ---- prefix cache extension (all empty/zero when the cache is off).
    # ``requests`` above then holds only the *encode* rows (prefix misses);
    # hits skip the encoder entirely and arrive pre-shaped here.
    hits: List[Request] = dataclasses.field(default_factory=list)
    hit_rows: np.ndarray = _EMPTY_I32          # (hit_width,) base rows
    hit_lengths: np.ndarray = _EMPTY_I32       # (hit_width,) source lengths
    hit_pages: np.ndarray = _EMPTY_I32_2D      # (hit_width, maxPP) chains
    hit_width: int = 0                         # pow2 (0 = no hits)
    # per-encode-row chain reservations: rows routed "insert" carry their
    # chain's page ids (sentinel-padded); "skip"/padding rows all-sentinel
    ins_pages: np.ndarray = _EMPTY_I32_2D      # (width, maxPP)
    # overload extensions: ``resumed`` requests carry a host spill payload
    # (preempted earlier; the engine restores their KV instead of encoding)
    # and ``staged`` requests have sources past the chunked-prefill budget
    # (the engine spreads their encode across rounds, one layer per round;
    # neither kind occupies an encode row in this plan)
    resumed: List[Request] = dataclasses.field(default_factory=list)
    staged: List[Request] = dataclasses.field(default_factory=list)

    @property
    def n_admitted(self) -> int:
        return (len(self.requests) + len(self.hits) + len(self.released)
                + len(self.resumed) + len(self.staged))

    @property
    def prefix_hit_pages(self) -> int:
        """Chain pages whose encode+store this round's hits skipped."""
        return sum(r.prefix_chain.n_pages for r in self.hits)


class ContinuousScheduler:
    """Admission control + slot lifecycle for continuous batching.

    ``n_slots`` decode rows exist for the whole serve; a request occupies
    exactly one slot *group* of ``group_size`` contiguous rows from
    admission to finish (``group_size=1`` — greedy — makes a group one
    row, the original behaviour).  ``admit`` hands out free groups to
    waiting requests in strict FIFO order — bounded per round by
    ``prefill_token_budget`` (sum of source tokens prefillable in one go)
    so a burst of long requests cannot monopolize a prefill round.  The
    first waiting request is always admitted when a group is free, so no
    request can starve regardless of the length mix.

    ``Request.slot`` and ``slot_map`` keys are group *base rows* (always
    multiples of ``group_size``); a group's rows are
    ``[base, base + group_size)``.  Rows past ``n_groups * group_size``
    (when ``group_size`` does not divide ``n_slots``) are never assigned —
    that is the beam-starvation tax the README quantifies.
    ``prefill_token_budget`` is denominated in prefilled **row**-tokens:
    a group prefill replicates the source across its rows, so a request
    charges ``group_size × n_src_tokens`` against the round's budget.
    (Fused encode-once admission actually *encodes* the source only once
    per group, but the budget deliberately keeps the row-token
    denomination so admission pacing — and therefore the token stream —
    is identical between the fused and unfused engines.)
    """

    def __init__(self, n_slots: int, *, group_size: int = 1,
                 prefill_token_budget: Optional[int] = None,
                 allocator=None,
                 pages_per_request: Optional[Callable[[Request], int]] = None,
                 prefix_cache=None,
                 initial_pages: Optional[Callable[[Request], int]] = None,
                 prefill_chunk: Optional[int] = None,
                 starvation_aging: float = 0.5):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        if group_size < 1:
            raise ValueError(f"group_size must be ≥ 1, got {group_size}")
        if n_slots < group_size:
            raise ValueError(f"{n_slots} rows cannot hold a group of "
                             f"{group_size}")
        if (allocator is None) != (pages_per_request is None):
            raise ValueError("allocator and pages_per_request go together")
        self.n_slots = n_slots
        self.group_size = group_size
        self.n_groups = n_slots // group_size
        self.prefill_token_budget = prefill_token_budget
        # paged KV admission: a request needs a free slot group AND pages
        # from the allocator.  ``pages_per_request`` is the worst case
        # (the request's full budget); by default it is also what gets
        # physically allocated, so admission can never over-commit and
        # decode never needs to preempt — the head of the queue blocks
        # the round when the pool is short (pages return at release, so
        # it always eventually admits).  With ``initial_pages`` set the
        # worst case becomes a *virtual* reservation (allocator.reserve,
        # capped at overcommit_limit × n_pages) and only next-burst pages
        # are allocated up front — the engine grows rows mid-flight and
        # preempts-by-page-spill when growth or admission comes up short.
        self.allocator = allocator
        self.pages_per_request = pages_per_request
        # cross-request prefix cache: routes each admission "hit" /
        # "insert" / "skip".  Chain pages come from the cache's OWN
        # allocator (separate pool), so chain reservations can never eat
        # into the decode page budget above — a full prefix pool degrades
        # to uncached admission, it cannot wedge the FIFO.
        self.prefix_cache = prefix_cache
        # overcommit: ``pages_per_request`` stays the worst case (virtual,
        # tracked by allocator.reserve); ``initial_pages`` — when given —
        # is what admission *physically* allocates (enough for the next
        # burst), with growth/preemption covering the gap.  None keeps the
        # legacy reserve-everything behaviour exactly.
        self.initial_pages = initial_pages
        # chunked prefill: sources longer than this (in tokens) are routed
        # to AdmissionPlan.staged instead of the round's encode rows
        self.prefill_chunk = prefill_chunk
        # EDF aging: each admission round a request waits shrinks its
        # urgency key by this many (virtual) seconds, so a best-effort
        # request eventually outranks any stream of tight deadlines
        if starvation_aging < 0:
            raise ValueError(f"starvation_aging must be >= 0, "
                             f"got {starvation_aging}")
        self.starvation_aging = float(starvation_aging)
        self._waiting: Deque[Request] = collections.deque()
        self._free: List[int] = [g * group_size for g in range(self.n_groups)]
        self.slot_map: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.rejected: List[Request] = []

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        # reset the whole lifecycle so a Request object can be re-served
        req.status = "waiting"
        req.slot = None
        req.admitted_s = None
        req.first_token_s = None
        req.finish_s = None
        req.tokens = []
        req.admitted_step = None
        req.finish_step = None
        req.score = None
        req.pages = None
        req.prefix_role = None
        req.prefix_chain = None
        req.reject_reason = None
        req.preemptions = 0
        req.spill = None
        req.wait_rounds = 0
        req.reserved_pages = 0
        self._waiting.append(req)

    def submit_many(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.submit(r)

    # ------------------------------------------------ deadline-aware order
    _NO_DEADLINE = 1e6                 # best-effort = very late deadline

    def urgency_key(self, req: Request) -> float:
        """Scalar wait-queue/victim key — smaller = more urgent.

        Earliest-deadline-first, nudged by ``priority`` and by starvation
        aging (every round spent waiting makes a request
        ``starvation_aging`` virtual seconds more urgent, so best-effort
        traffic cannot starve behind a stream of tight deadlines).
        """
        d = req.deadline_s if req.deadline_s is not None else self._NO_DEADLINE
        return d - req.priority - self.starvation_aging * req.wait_rounds

    def victim_key(self, req: Request) -> float:
        """Preemption-comparison key — deadline and priority ONLY.

        Starvation aging is deliberately excluded: aging exists to move a
        waiting request up the *queue*, not to let it evict an
        equally-urgent running one (with aging in the key, any deadline-
        free request would eventually out-rank every running peer and the
        pool would thrash on evictions that buy nothing).
        """
        d = req.deadline_s if req.deadline_s is not None else self._NO_DEADLINE
        return d - req.priority

    def _sort_waiting(self) -> None:
        """EDF-with-aging order; preempted (spilled) requests win ties.

        Skipped entirely when nothing in the queue carries a deadline, a
        priority, aging credit, or a spill — the default stays strict
        submission-order FIFO, byte-for-byte.
        """
        if len(self._waiting) < 2:
            return
        if not any(r.deadline_s is not None or r.priority or r.spill
                   is not None or r.wait_rounds for r in self._waiting):
            return
        self._waiting = collections.deque(sorted(
            self._waiting,
            key=lambda r: (self.urgency_key(r),
                           0 if r.spill is not None else 1)))

    def _shed(self, now: float) -> List[Request]:
        """Reject waiting requests whose deadline is provably unmeetable
        (already in the past — no admission order can produce a first
        token before a deadline that has elapsed).  Preempted requests are
        exempt: they already consumed encode + decode work, and their
        spilled KV is freed only through the engine's resume/abandon path.
        """
        shed: List[Request] = []
        keep: Deque[Request] = collections.deque()
        for req in self._waiting:
            if (req.deadline_s is not None and now > req.deadline_s
                    and req.spill is None):
                req.status = "rejected"
                req.reject_reason = (
                    f"deadline {req.deadline_s:.3f}s already passed at "
                    f"admission (now={now:.3f}s)")
                req.finish_s = now
                self.rejected.append(req)
                shed.append(req)
            else:
                keep.append(req)
        self._waiting = keep
        return shed

    def admit(self, now: float = 0.0, *,
              step: Optional[int] = None) -> List[Request]:
        """Move waiting requests into free slot groups (one prefill round).

        With burst decode, admission happens only at burst edges; ``step``
        records the global decode-step count at that edge so queueing can
        be attributed exactly even though ``now`` is burst-granular.

        Order: shed provably-late requests, sort by urgency (no-op for
        deadline-free traffic — strict FIFO is preserved exactly), then
        admit while slots, the prefill budget, and the page pool allow.
        Under overcommit (``initial_pages`` set) a request is gated by a
        *virtual* worst-case reservation (``allocator.reserve``) but only
        its next-burst pages are physically allocated.
        """
        self._shed(now)
        self._sort_waiting()
        admitted: List[Request] = []
        budget = self.prefill_token_budget
        used = 0
        while self._waiting and self._free:
            req = self._waiting[0]
            # budget is in prefilled *row*-tokens: a beam group encodes its
            # source once per row, so a request costs group_size × its
            # source length (group_size=1 reduces to plain source tokens)
            cost = req.n_src_tokens * self.group_size
            if admitted and budget is not None and used + cost > budget:
                break                    # next round; queue order preserved
            pages = None
            worst = 0
            if self.allocator is not None:
                worst = self.pages_per_request(req)
                if not self.allocator.can_reserve(worst):
                    break    # virtual budget exhausted: head waits
                n_pages = worst
                if self.initial_pages is not None:
                    n_pages = min(self.initial_pages(req), worst)
                pages = self.allocator.alloc(n_pages)
                if pages is None:
                    break    # pool short: the head waits (or the engine
                             # preempts a victim and retries next round)
                self.allocator.reserve(worst)
            self._waiting.popleft()
            slot = self._free.pop(0)
            req.status = "running"
            req.slot = slot
            req.pages = pages
            req.reserved_pages = worst
            req.admitted_s = now
            req.admitted_step = step
            self.slot_map[slot] = req
            used += cost
            admitted.append(req)
        for req in self._waiting:
            req.wait_rounds += 1         # starvation aging
        return admitted

    def admission_shortfall(self) -> Optional[Dict[str, int]]:
        """Why the most urgent waiting request cannot be admitted *now*,
        in pages — or None when nothing page-related blocks it.

        ``pages_short``: physical pages missing for its initial
        allocation; ``reserve_short``: virtual reservation room missing
        under the overcommit cap.  Both are fixable by preempting running
        victims (preemption spills physical pages AND returns the
        victim's worst-case reservation), which is exactly what the
        engine does with this signal.
        """
        if not self._waiting or not self._free or self.allocator is None:
            return None
        self._sort_waiting()
        req = self._waiting[0]
        worst = self.pages_per_request(req)
        n_pages = worst
        if self.initial_pages is not None:
            n_pages = min(self.initial_pages(req), worst)
        reserve_short = max(
            0, self.allocator.reserved + worst - self.allocator.reserve_cap)
        pages_short = max(0, n_pages - self.allocator.n_free)
        if not reserve_short and not pages_short:
            return None
        return {"reserve_short": reserve_short, "pages_short": pages_short,
                "head_key": self.victim_key(req)}

    def preempt(self, req: Request, now: float = 0.0) -> int:
        """Evict a running request back to the wait queue; returns its
        freed group base row.

        The caller (engine) has already copied the victim's KV pages to
        host — ``req.spill`` holds the payload — so its pages go back to
        the pool through the allocator's spill accounting (a staged victim
        whose encode never finished has nothing to spill: plain release).
        The victim keeps its emitted tokens and re-enters at the *front*
        of its urgency class (spilled requests win ties), so resume beats
        fresh admissions and a preempted request cannot starve.
        """
        if req.status != "running" or req.slot is None:
            raise ValueError(f"request {req.req_id} is not running "
                             f"(status={req.status})")
        slot = req.slot
        req.status = "waiting"
        req.slot = None
        req.preemptions += 1
        if req.pages is not None:
            if req.spill is not None:
                self.allocator.spill(req.pages)
            else:
                self.allocator.release(req.pages)
            req.pages = None
        if req.reserved_pages:
            self.allocator.unreserve(req.reserved_pages)
            req.reserved_pages = 0
        if req.prefix_chain is not None:
            # drop the chain reference: resume re-splices cross K/V from
            # the spill payload, not from the prefix pool
            self.prefix_cache.finish(req.prefix_chain)
            req.prefix_chain = None
            req.prefix_role = None
        del self.slot_map[slot]
        self._free.append(slot)
        self._free.sort()
        self._waiting.appendleft(req)
        return slot

    def assign_prefix(self, reqs: Sequence[Request]
                      ) -> "tuple[List[Request], List[Request]]":
        """Route live admissions through the prefix cache.

        Returns ``(misses, hits)``: misses (roles "insert"/"skip") must be
        encoded; hits skip the encoder and splice their cached chain.
        Routing is sequential on purpose — a source admitted twice in ONE
        round makes the first occurrence the "insert" and the second a
        "hit" on the chain reserved moments earlier (the engine orders the
        pool scatter before the hit gather inside one program, so the
        same-round hit reads the freshly written pages).
        """
        if self.prefix_cache is None:
            return list(reqs), []
        misses: List[Request] = []
        hits: List[Request] = []
        for req in reqs:
            role, chain = self.prefix_cache.admit(req.src)
            req.prefix_role = role
            req.prefix_chain = chain
            (hits if role == "hit" else misses).append(req)
        return misses, hits

    def chain_pages_matrix(self, reqs: Sequence[Request], width: int,
                           enc_len: int, stride: int = 1) -> np.ndarray:
        """(width, maxPP) chain page ids, sentinel-padded.

        ``maxPP`` is the chain length of a full ``enc_len`` source against
        the *prefix* allocator's page size; rows without a chain (role
        "skip", padding) are all-sentinel so their page-chunk scatters and
        gathers drop/clamp.  ``stride``: request ``i``'s chain lands on
        row ``i × stride`` (the unfused beam side batch tiles each source
        ``beam×``, and only the group's first row feeds the pool insert).
        """
        al = self.prefix_cache.allocator
        maxPP = (enc_len + al.page_size - 1) // al.page_size
        out = np.full((width, max(maxPP, 1)), al.n_pages, np.int32)
        for i, req in enumerate(reqs):
            if req.prefix_chain is not None:
                out[i * stride, :req.prefix_chain.n_pages] = \
                    req.prefix_chain.pages
        return out

    def shape_hits(self, hits: Sequence[Request], *, enc_len: int,
                   oob_row: int
                   ) -> "tuple[np.ndarray, np.ndarray, np.ndarray, int]":
        """Shape prefix hits for a device splice: pow2-padded
        ``(hit_rows, hit_lengths, hit_pages, hit_width)`` under the same
        row-0-replay / oob-destination contract as :func:`pad_rows_pow2`.
        """
        hlens = np.asarray([r.n_src_tokens for r in hits], np.int32)
        hrows = np.asarray([r.slot for r in hits], np.int32)
        hw = next_pow2(len(hits))
        pad = hw - len(hits)
        hit_lengths = np.concatenate(
            [hlens, np.broadcast_to(hlens[:1], (pad,))])
        hit_rows = np.concatenate(
            [hrows, np.full((pad,), oob_row, np.int32)])
        hit_pages = self.chain_pages_matrix(hits, hw, enc_len)
        hit_pages[len(hits):] = hit_pages[0]         # padding replays row 0
        return hit_rows, hit_lengths, hit_pages, hw

    def plan_admission(self, now: float = 0.0, *, step: Optional[int] = None,
                       enc_len: int, oob_row: int) -> AdmissionPlan:
        """Admit one round and shape it for the fused burst program.

        Runs :meth:`admit`, finishes zero-budget requests on the spot
        (their output is empty by definition; they need no device work),
        and packs the remainder into the :class:`AdmissionPlan` padding
        contract: sources right-padded to ``enc_len``, batch padded to a
        power-of-two width with row-0 replays, destinations padded with
        the ``oob_row`` sentinel so in-program scatters drop them.

        With a prefix cache attached the round splits: cache hits skip the
        encoder (``hit_*`` fields carry their chain pages, base rows and
        source lengths, pow2-padded under the same row-0-replay contract)
        and only the misses occupy encode rows; misses routed "insert"
        additionally carry their chain reservation in ``ins_pages`` so the
        fused program can store the fresh encode for the next requester.
        Zero-budget requests are excluded *before* cache routing — they
        never encode, so an "insert" for one would cache garbage.
        """
        live: List[Request] = []
        released: List[Request] = []
        resumed: List[Request] = []
        staged: List[Request] = []
        for req in self.admit(now, step=step):
            if req.max_new_tokens <= 0:
                req.first_token_s = now          # observed: empty output
                self.release(req, now, step=step)
                released.append(req)
            elif req.spill is not None:
                # preempted earlier: KV restores from the host spill
                # payload — no encode row, no prefix routing (the cross
                # K/V in the spill already reflects any chain it read)
                resumed.append(req)
            elif (self.prefill_chunk is not None
                    and req.n_src_tokens > self.prefill_chunk):
                # chunked prefill: encode spreads across rounds (engine-
                # driven, one encoder layer per round), so the source
                # never occupies this round's encode rows.  Staged
                # sources bypass the prefix cache both ways: an exact-hit
                # would have no reason to stage (hits skip the encoder),
                # and inserting a chain would force the monolithic
                # encode layout this path exists to avoid.
                staged.append(req)
            else:
                live.append(req)
        misses, hits = self.assign_prefix(live)
        if misses:
            src, lens = pad_batch([r.src for r in misses], length=enc_len)
            src, lens, width = pad_rows_pow2(src, lens)
            base = np.full((width,), oob_row, np.int32)
            base[:len(misses)] = [r.slot for r in misses]
        else:
            width = 0
            src = np.zeros((0, enc_len), np.int32)
            lens = base = np.zeros((0,), np.int32)
        plan = AdmissionPlan(requests=misses, released=released,
                             src_tokens=np.ascontiguousarray(src),
                             src_lengths=np.ascontiguousarray(lens),
                             base_rows=base, width=width,
                             resumed=resumed, staged=staged)
        if self.prefix_cache is not None:
            plan.ins_pages = self.chain_pages_matrix(misses, width, enc_len)
            if hits:
                (plan.hit_rows, plan.hit_lengths, plan.hit_pages,
                 plan.hit_width) = self.shape_hits(hits, enc_len=enc_len,
                                                   oob_row=oob_row)
                plan.hits = hits
        return plan

    def release(self, req: Request, now: float = 0.0, *,
                step: Optional[int] = None) -> int:
        """Finish a running request and return its freed group base row
        (all ``group_size`` rows of the group are freed atomically).

        ``step``: the exact global decode step the request finished at —
        inside a burst this is finer-grained than ``now``, which is only
        observed at the burst edge.
        """
        if req.status != "running" or req.slot is None:
            raise ValueError(f"request {req.req_id} is not running "
                             f"(status={req.status})")
        slot = req.slot
        req.status = "finished"
        req.finish_s = now
        req.finish_step = step
        req.slot = None
        if req.pages is not None:
            self.allocator.release(req.pages)
            req.pages = None
        if req.reserved_pages:
            self.allocator.unreserve(req.reserved_pages)
            req.reserved_pages = 0
        if req.prefix_chain is not None:
            self.prefix_cache.finish(req.prefix_chain)
            req.prefix_chain = None
        del self.slot_map[slot]
        self._free.append(slot)
        self._free.sort()
        self.finished.append(req)
        return slot

    # ------------------------------------------------------------ inspection
    @property
    def n_free(self) -> int:
        """Free slot *groups* (== free rows when ``group_size == 1``)."""
        return len(self._free)

    @property
    def n_running(self) -> int:
        return len(self.slot_map)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    @property
    def all_done(self) -> bool:
        return not self._waiting and not self.slot_map


class BatchQueue:
    """Thread-safe queue feeding the worker streams (paper Fig. 6)."""

    def __init__(self, items: Optional[Sequence[WorkItem]] = None):
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self.enqueued = 0
        if items:
            for item in items:
                self.put(item)

    def put(self, item: WorkItem) -> None:
        with self._lock:
            self.enqueued += 1
        self._q.put(item)

    def close(self, n_consumers: int) -> None:
        for _ in range(n_consumers):
            self._q.put(None)

    def get(self) -> Optional[WorkItem]:
        return self._q.get()
