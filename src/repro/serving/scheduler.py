"""Serving-side batch composition (paper §5.4 + §5.6 front half).

``TokenSortedScheduler`` orders incoming requests by **token count**
(descending — long batches first keeps the stream pipeline busy at the
tail), composes fixed-size batches padded to bucketed lengths, and exposes
them through a thread-safe ``BatchQueue`` that the parallel streams
(``streams.py``) drain asynchronously — the paper's parent-session batch
queue.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.sorting import make_batches, padding_stats
from repro.data.synthetic import Sentence, pad_batch


@dataclasses.dataclass
class WorkItem:
    batch_id: int
    indices: List[int]                 # request ids in this batch
    batch: Dict[str, np.ndarray]
    n_real_tokens: int
    n_padded_tokens: int


class TokenSortedScheduler:
    """Requests → ordered, padded batches (+ padding accounting)."""

    def __init__(self, batch_size: int, *, sort_mode: str = "tokens",
                 pad_to_multiple: int = 8):
        self.batch_size = batch_size
        self.sort_mode = sort_mode
        self.pad_to_multiple = pad_to_multiple

    def _round(self, n: int) -> int:
        m = self.pad_to_multiple
        return ((n + m - 1) // m) * m

    def plan(self, requests: Sequence[Sentence]) -> List[WorkItem]:
        batches = make_batches(requests, self.batch_size, self.sort_mode)
        items = []
        for bid, idx in enumerate(batches):
            sents = [requests[i] for i in idx]
            L = self._round(max(s.n_tokens for s in sents))
            src, lens = pad_batch([s.src for s in sents], length=L)
            items.append(WorkItem(
                batch_id=bid,
                indices=list(idx),
                batch={"src_tokens": src, "src_lengths": lens},
                n_real_tokens=int(lens.sum()),
                n_padded_tokens=int(L * len(sents)),
            ))
        return items

    def stats(self, requests: Sequence[Sentence]) -> dict:
        batches = make_batches(requests, self.batch_size, self.sort_mode)
        return padding_stats(requests, batches)


class BatchQueue:
    """Thread-safe queue feeding the worker streams (paper Fig. 6)."""

    def __init__(self, items: Optional[Sequence[WorkItem]] = None):
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self.enqueued = 0
        if items:
            for item in items:
                self.put(item)

    def put(self, item: WorkItem) -> None:
        with self._lock:
            self.enqueued += 1
        self._q.put(item)

    def close(self, n_consumers: int) -> None:
        for _ in range(n_consumers):
            self._q.put(None)

    def get(self) -> Optional[WorkItem]:
        return self._q.get()
