"""Parallel inference streams (paper §5.6).

The paper: a parent session owns a batch queue; children processes, each
affinitized to a CPU-core/NUMA subset, dequeue batches asynchronously so
long- and short-sentence batches overlap and utilization rises 43%.

TPU mapping: a *stream* is an independent model replica on a slice of the
mesh (e.g. 2 streams = the two halves of the "data" axis).  In this
CPU container the streams run as threads over engine replicas — the queue/
worker mechanism is identical, and jax releases the GIL during compute.

``simulate_streams`` additionally provides the deterministic queueing model
used by ``benchmarks/bench_batching.py`` to report the serial-vs-parallel
scaling the paper shows in Figure 6/8 (wall-clock on 1 CPU core cannot).
``simulate_continuous`` is the same idea for the slot-refill engine
(``ServingEngine.serve``): it predicts the decode-grid utilization gap
between static and continuous batching from the decode-length distribution
alone — group-granular when ``beam > 1``, where a request holds ``beam``
rows and the grid has correspondingly fewer refillable servers.  It runs
at burst granularity and models **fused admission** (the engine default):
prefill is no longer a separate service event, so an admission round costs
zero extra host events and a request's first token is observed at its
admitting burst's edge — set ``fused_admission=False`` for the unfused
(separate-prefill-dispatch) baseline the host-event counts are compared
against.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.scheduler import BatchQueue, WorkItem


@dataclasses.dataclass
class StreamRecord:
    stream_id: int
    batch_id: int
    start_s: float
    end_s: float
    n_tokens: int


class ParallelStreams:
    """N worker streams draining one batch queue."""

    def __init__(self, run_batch: Callable[[int, WorkItem], int],
                 n_streams: int):
        """``run_batch(stream_id, item) -> n_generated_tokens``."""
        self.run_batch = run_batch
        self.n_streams = n_streams
        self.records: List[StreamRecord] = []
        self._lock = threading.Lock()

    def _worker(self, sid: int, q: BatchQueue, t0: float) -> None:
        while True:
            item = q.get()
            if item is None:
                return
            s = time.perf_counter() - t0
            n = self.run_batch(sid, item)
            e = time.perf_counter() - t0
            with self._lock:
                self.records.append(StreamRecord(sid, item.batch_id, s, e, n))

    def run(self, items: Sequence[WorkItem]) -> Dict:
        q = BatchQueue(items)
        q.close(self.n_streams)
        t0 = time.perf_counter()
        threads = [threading.Thread(target=self._worker, args=(i, q, t0))
                   for i in range(self.n_streams)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        makespan = max((r.end_s for r in self.records), default=0.0)
        busy = sum(r.end_s - r.start_s for r in self.records)
        return {
            "makespan_s": makespan,
            "throughput_tok_s": sum(r.n_tokens for r in self.records)
            / max(makespan, 1e-9),
            "utilization": busy / max(makespan * self.n_streams, 1e-9),
            "records": self.records,
        }


def simulate_continuous(decode_lengths: Sequence[int], n_slots: int,
                        *, static_batch: Optional[int] = None,
                        beam: int = 1, burst_len: int = 1,
                        fused_admission: bool = True,
                        preempt_rounds: Optional[Dict[int, int]] = None,
                        src_lengths: Optional[Sequence[int]] = None,
                        prefill_chunk: Optional[int] = None,
                        n_enc_layers: int = 1,
                        deadline_steps: Optional[
                            Sequence[Optional[int]]] = None) -> Dict:
    """Deterministic slot-refill model of continuous vs static batching.

    Cost unit = one decode step of one slot row (the decode grid is computed
    for every slot whether or not it holds a live request).  Continuous
    batching finishes a request after exactly ``decode_lengths[i]`` steps in
    its slot and refills at the next burst edge; static batching
    (``static_batch`` *requests* per batch, FIFO) holds every row until the
    *longest* request in the batch finishes.  Returns slot-steps and
    utilization for both, the analogue of the paper's Fig. 6 queueing model
    for the refill engine — used by ``benchmarks/bench_continuous.py`` and
    the scheduler tests.

    The continuous side is an **event simulation at burst granularity**:
    admission and release happen only at burst edges (every ``burst_len``
    grid steps, early-exiting when every server goes idle), mirroring the
    decode-burst engine.  ``fused_admission=True`` (the engine's default)
    models prefill folded into the burst program: an admission round costs
    **no separate host event**, a request occupies its server for exactly
    ``decode_lengths[i]`` in-burst steps (the first token is emitted by the
    burst's first step), and its first token is *observed* at the admitting
    burst's edge.  ``fused_admission=False`` models the PR 3 engine: each
    admission round is a separate prefill service event (``prefill_events``,
    counted in ``host_events``) that emits the first token at the admission
    edge, leaving ``decode_lengths[i] - 1`` in-burst steps.  The fused/
    unfused gap in ``host_events`` at equal token output is exactly what
    ``ServeResult.host_syncs`` measures on the real engine.

    ``beam > 1`` models **group-granular** queueing (continuous beam
    serving): a request occupies a whole group of ``beam`` rows, so the
    grid holds only ``n_slots // beam`` independent servers, every useful
    or idle step is charged ``beam`` rows, and ``idle_rows`` rows (when
    ``beam`` does not divide ``n_slots``) can never hold a group at all —
    the precise sense in which a coarse beam *starves* the grid: fewer
    refill opportunities per burst edge and a utilization ceiling of
    ``(n_slots - idle_rows) / n_slots``.

    Overload extensions (all inert at their ``None`` defaults, so legacy
    outputs are unchanged):

    * ``preempt_rounds`` — round → victim count, the queueing model of
      preempt-by-page-spill (``serving/chaos.py`` uses the same keying):
      at that burst edge the youngest-admitted running requests are
      spilled (progress preserved) back to the *head* of the queue, each
      costing one extra host event (the spill gather's sync).
    * ``prefill_chunk`` + ``src_lengths`` — a request whose source
      exceeds ``prefill_chunk`` tokens stages its encode depth-wise: it
      occupies a server for ``n_enc_layers`` rounds emitting nothing
      (the rows ride the grid idle) before decoding starts.  Requires
      ``fused_admission``, like the engine.
    * ``deadline_steps`` — per-request deadline on the step clock: a
      request still queued past its deadline is shed (never admitted,
      counted in ``shed``); ``deadline_misses`` adds requests that
      finished late.  Resumed (preempted) requests are never shed,
      matching the scheduler.
    """
    lens = [int(x) for x in decode_lengths]
    if beam < 1:
        raise ValueError(f"beam must be ≥ 1, got {beam}")
    if burst_len < 1:
        raise ValueError(f"burst_len must be ≥ 1, got {burst_len}")
    if prefill_chunk is not None:
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if not fused_admission:
            raise ValueError("chunked prefill requires fused_admission "
                             "(staged encodes ride the fused plan)")
        if src_lengths is None:
            raise ValueError("prefill_chunk needs src_lengths")
    n_groups = n_slots // beam
    if n_groups < 1:
        raise ValueError(f"{n_slots} rows cannot hold a beam-{beam} group")
    idle_rows = n_slots - n_groups * beam      # stranded by non-dividing beam
    useful = sum(lens) * beam
    preempt = dict(preempt_rounds or {})
    slens = list(src_lengths) if src_lengths is not None else [0] * len(lens)
    deadlines = (list(deadline_steps) if deadline_steps is not None
                 else [None] * len(lens))

    # --- continuous: burst-granular event simulation over group servers
    waiting = collections.deque(enumerate(lens))
    free = list(range(n_groups))
    remaining: Dict[int, int] = {}             # server → in-burst steps left
    server_req: Dict[int, int] = {}
    staging: Dict[int, List[int]] = {}         # server → [stage rounds left]
    admit_seq: Dict[int, int] = {}             # server → admission order
    resumed: set = set()
    first_token_step = [0] * len(lens)         # edge the first token drains
    finish_step = [0] * len(lens)
    steps = 0
    rounds = 0
    seq = 0
    host_events = 0
    admission_events = 0
    prefill_events = 0
    preemptions = 0
    shed_ids: set = set()
    chunk_stage_rounds = 0

    def advance_staging() -> None:
        nonlocal chunk_stage_rounds
        for g in list(staging):
            staging[g][0] -= 1
            chunk_stage_rounds += 1
            if staging[g][0] <= 0:             # encode complete: decoding
                del staging[g]                 # starts next round (BOS now)
                remaining[g] = lens[server_req[g]]

    while waiting or remaining or staging:
        # forced preemption at this round edge: spill the youngest-admitted
        # running servers, requeue at the head (progress preserved)
        for g in sorted(remaining,
                        key=lambda s: -admit_seq[s])[:preempt.pop(rounds, 0)]:
            i = server_req.pop(g)
            waiting.appendleft((i, remaining.pop(g)))
            resumed.add(i)
            free.append(g)
            preemptions += 1
            host_events += 1                   # the spill gather's sync
        free.sort()
        admitted = False
        released_now: List[int] = []
        while waiting and free:
            i, ln = waiting.popleft()
            if (deadlines[i] is not None and steps > deadlines[i]
                    and i not in resumed):
                shed_ids.add(i)                # expired in queue: rejected
                first_token_step[i] = steps
                finish_step[i] = steps
                continue
            admitted = True
            if ln <= 0:                        # zero budget: finished at
                first_token_step[i] = steps    # admission, occupies nothing
                finish_step[i] = steps
                continue
            if (prefill_chunk is not None and i not in resumed
                    and slens[i] > prefill_chunk):
                g = free.pop(0)                # staged: encode over rounds,
                staging[g] = [n_enc_layers]    # server held but silent
                server_req[g] = i
                admit_seq[g] = seq
                seq += 1
                continue
            g = free.pop(0)
            admit_seq[g] = seq
            seq += 1
            if fused_admission:
                remaining[g] = ln              # token 1 comes from the burst
            else:
                first_token_step[i] = steps    # prefill drains token 1 here
                if ln == 1:
                    finish_step[i] = steps     # done at the prefill itself
                    released_now.append(g)
                    continue
                remaining[g] = ln - 1
            server_req[g] = i
        if admitted:
            admission_events += 1
            if not fused_admission:            # separate prefill dispatch +
                prefill_events += 1            # first-token drain
                host_events += 1
        free.extend(released_now)              # groups freed at the prefill
        free.sort()                            # edge refill only next round
        if not remaining:
            advance_staging()                  # pure-staging round: no
            rounds += 1                        # burst, no grid cost
            continue
        k = min(burst_len, max(remaining.values()))    # burst early exit
        steps += k
        host_events += 1                       # the burst-edge drain
        for g in list(remaining):
            used = min(remaining[g], k)
            remaining[g] -= used
            i = server_req[g]
            if fused_admission and not first_token_step[i]:
                first_token_step[i] = steps    # observed at this edge
            if remaining[g] == 0:
                finish_step[i] = steps
                del remaining[g]
                del server_req[g]
                free.append(g)
        free.sort()
        advance_staging()
        rounds += 1
    deadline_misses = len(shed_ids) + sum(
        1 for i, d in enumerate(deadlines)
        if d is not None and i not in shed_ids and finish_step[i] > d)
    cont_steps = steps
    cont_grid = cont_steps * n_slots

    # --- static: batches of `static_batch` requests (each `beam` rows)
    # run max(len) steps each (a partial final batch is charged its actual
    # rows, matching how the measured baseline in bench_continuous.py
    # accounts its grid)
    bsz = static_batch or n_groups
    static_grid = 0
    static_steps = 0
    for i in range(0, len(lens), bsz):
        chunk = lens[i:i + bsz]
        static_steps += max(chunk)
        static_grid += max(chunk) * len(chunk) * beam
    first = np.asarray(first_token_step, float)
    return {
        "useful_slot_steps": useful,
        "continuous_steps": cont_steps,
        "continuous_utilization": useful / max(cont_grid, 1),
        "static_steps": static_steps,
        "static_utilization": useful / max(static_grid, 1),
        "speedup_steps": static_steps / max(cont_steps, 1),
        "beam": beam,
        "n_groups": n_groups,
        "idle_rows": idle_rows,
        "burst_len": burst_len,
        "fused_admission": fused_admission,
        "host_events": host_events,
        "admission_events": admission_events,
        "prefill_events": prefill_events,
        "preemptions": preemptions,
        "shed": len(shed_ids),
        "deadline_misses": deadline_misses,
        "chunk_stage_rounds": chunk_stage_rounds,
        "first_token_steps_mean": float(first.mean()) if len(lens) else 0.0,
        "first_token_steps_p95":
            float(np.percentile(first, 95)) if len(lens) else 0.0,
    }


def simulate_streams(batch_costs: Sequence[float], n_streams: int,
                     order: Optional[Sequence[int]] = None) -> Dict:
    """Deterministic greedy-queue simulation: each stream takes the next
    batch when free.  Returns makespan + utilization — the queueing model of
    the paper's Figure 6 (serial vs parallel execution)."""
    costs = list(batch_costs) if order is None else \
        [batch_costs[i] for i in order]
    free = np.zeros(n_streams)
    for c in costs:
        s = int(np.argmin(free))
        free[s] += c
    makespan = float(free.max())
    busy = float(sum(costs))
    return {
        "makespan_s": makespan,
        "utilization": busy / max(makespan * n_streams, 1e-12),
        "speedup_vs_serial": busy / max(makespan, 1e-12),
    }
