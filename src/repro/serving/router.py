"""Data-parallel replica router: fan requests across N serving engines.

Tensor parallelism (``ServingEngine(mesh=...)``) buys per-step latency;
this buys throughput: N independent engine replicas — each its own
params copy, page pool and scheduler — behind a host-side router that
assigns every request to the replica with the shallowest queue, breaking
ties by the most *estimated free pages* (a shadow
``kv_cache.PageAllocator`` per replica mirrors what that replica's serve
pool will reserve, using the engine's own worst-case
``pages_per_row(max_new_tokens)`` accounting).  Queue depth leads the
score so counts can never drift more than one apart — the page estimate
arbitrates which near-even replica absorbs a long request.

Replicas serve concurrently (one host thread each, ``parallel=True``):
every engine's burst loop alternates dispatch / host-drain, so the
threads interleave at burst edges — each replica's serve is untouched
and its output bit-identical to running that share alone.  The merged
:class:`RouterResult` restores submission order and re-exposes the
``ServeResult`` surface the benches read.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.models import kv_cache as kvc
from repro.serving.engine import ServeResult, ServingEngine
from repro.serving.scheduler import Request

__all__ = ["ReplicaRouter", "RouterResult"]


@dataclasses.dataclass
class RouterResult:
    """Merged outcome of one routed serve across all replicas."""

    results: List[ServeResult]        # one per replica, replica order
    assignment: List[int]             # replica index per request, submission order
    requests: List[Request]           # submission order, lifecycle filled in
    wall_s: float

    @property
    def replicas(self) -> int:
        return len(self.results)

    @property
    def n_tokens(self) -> int:
        return int(sum(len(r.tokens) for r in self.requests))

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.wall_s, 1e-9)

    @property
    def peak_running_per_replica(self) -> List[int]:
        return [r.peak_running for r in self.results]

    @property
    def host_syncs(self) -> int:
        return int(sum(r.host_syncs for r in self.results))

    def tokens_for(self, req_id: int) -> np.ndarray:
        for r in self.requests:
            if r.req_id == req_id:
                return np.asarray(r.tokens, np.int32)
        raise KeyError(req_id)

    def metrics(self) -> Dict[str, float]:
        out = {"replicas": float(self.replicas),
               "n_requests": float(len(self.requests)),
               "n_tokens": float(self.n_tokens),
               "wall_s": self.wall_s,
               "tokens_per_s": self.tokens_per_s,
               "host_syncs": float(self.host_syncs)}
        for i, r in enumerate(self.results):
            out[f"replica{i}_peak_running"] = float(r.peak_running)
            out[f"replica{i}_n_tokens"] = float(
                sum(len(q.tokens) for q in r.requests))
        return out


class ReplicaRouter:
    def __init__(self, engines: Sequence[ServingEngine]):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines = list(engines)

    # ------------------------------------------------------------- routing
    def route(self, reqs: Sequence[Request], *, n_slots: int = 8
              ) -> List[int]:
        """Replica index per request: shallowest queue, then free pages.

        The shadow allocators are sized like each replica's serve pool
        (``engine._make_allocator(n_slots)``) and charged the worst-case
        reservation the engine's admission would hold for the request —
        free-page *estimates*, not live pool state (the pools don't exist
        until the serves run), which is exactly what a front-end router
        has to work from.
        """
        shadows = []
        for eng in self.engines:
            if eng.paged:
                shadows.append(kvc.PageAllocator(
                    eng.n_pages or n_slots * eng._max_pages, eng.page_size))
            else:
                shadows.append(None)
        depth = [0] * len(self.engines)
        out = []
        for req in reqs:
            def score(i):
                free = shadows[i].n_free if shadows[i] is not None else 0
                return (depth[i], -free, i)
            best = min(range(len(self.engines)), key=score)
            out.append(best)
            depth[best] += 1
            if shadows[best] is not None:
                eng = self.engines[best]
                need = kvc.pages_per_row(
                    min(req.max_new_tokens, eng.max_len), eng.page_size)
                shadows[best].alloc(min(need, shadows[best].n_free))
            else:
                # unpaged replicas balance on token budget via queue depth
                pass
        return out

    # ------------------------------------------------------------- serving
    def serve(self, requests: Sequence[Any], *, n_slots: int = 8,
              max_new_tokens: int = 64, parallel: bool = True,
              chaos: Optional[Sequence] = None, **kw) -> RouterResult:
        """Route ``requests`` and serve every share, merging the results.

        ``kw`` is broadcast to every replica's ``ServingEngine.serve``;
        ``chaos`` may be a per-replica sequence of schedules (or one
        schedule applied to all).  Requests keep their submission-order
        ``req_id``s, so ``tokens_for`` works on the merged result.
        """
        reqs = self.engines[0]._as_requests(requests, max_new_tokens)
        assignment = self.route(reqs, n_slots=n_slots)
        # shares are Request objects carrying their own budgets — the
        # per-replica serves only see a scalar default
        mx_default = (int(np.max(max_new_tokens))
                      if isinstance(max_new_tokens, (list, tuple, np.ndarray))
                      else int(max_new_tokens))
        shares: List[List[Request]] = [[] for _ in self.engines]
        for req, idx in zip(reqs, assignment):
            shares[idx].append(req)

        per_chaos: List[Any] = [None] * len(self.engines)
        if chaos is not None:
            if isinstance(chaos, (list, tuple)):
                if len(chaos) != len(self.engines):
                    raise ValueError(
                        f"per-replica chaos needs {len(self.engines)} "
                        f"schedules, got {len(chaos)}")
                per_chaos = list(chaos)
            else:
                per_chaos = [chaos] * len(self.engines)

        import time
        t0 = time.perf_counter()
        results: List[Optional[ServeResult]] = [None] * len(self.engines)
        errors: List[Optional[BaseException]] = [None] * len(self.engines)

        def run(i: int) -> None:
            skw = dict(kw)
            if per_chaos[i] is not None:
                skw["chaos"] = per_chaos[i]
            try:
                results[i] = self.engines[i].serve(
                    shares[i], n_slots=n_slots,
                    max_new_tokens=mx_default, **skw)
            except BaseException as e:       # surfaced after join
                errors[i] = e

        if parallel and len(self.engines) > 1:
            threads = [threading.Thread(target=run, args=(i,), daemon=True)
                       for i in range(len(self.engines))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for i in range(len(self.engines)):
                run(i)
        for e in errors:
            if e is not None:
                raise e

        done = [r for r in results if r is not None]
        for r in done:
            r.replicas = len(self.engines)
        return RouterResult(results=done, assignment=assignment,
                            requests=reqs, wall_s=time.perf_counter() - t0)
