"""Cross-request prefix sharing: a host-side radix tree over source tokens.

ROADMAP open item 1: at production traffic the same source sentences (and
templated prefixes) arrive over and over, and the per-request encoder +
cross-K/V projection is pure recomputation.  This module hash-conses the
*encoded* cross-attention K/V across requests:

* The tree is keyed by page-granular chunks of the source token ids
  (``page_size`` tokens per chunk, the last chunk partial), so walking it
  costs O(len(src) / page_size) hash lookups and common page-aligned
  prefixes share tree spine.  Payload chains hang off **terminal** nodes
  only — a cached entry is used when the incoming source matches it
  *exactly*.  That exactness is what keeps the token-identity gate intact:
  this repo's encoder is bidirectional, so the encoding of a strict prefix
  is NOT a prefix of the longer source's encoding, and reusing partial
  prefixes would change tokens.  (On a causal decoder-only stack the same
  tree generalizes to interior-node chains; the page-chunk keys are chosen
  so that needs no re-keying.)

* The payload lives in a dedicated device-side page pool (see
  ``models.kv_cache.insert_chain_pages`` / ``gather_chain_pages``) managed
  by this cache's own :class:`~repro.models.kv_cache.PageAllocator`.
  Refcounts > 1 are real here: the tree holds one reference per chain and
  every request currently reading the chain holds another (taken with
  ``retain`` at admission — the "refcount bump instead of alloc" that
  replaces encode+splice on a hit — and dropped with :meth:`finish` at
  release).

* Eviction is LRU over chains nobody is reading (every page at refcount
  exactly 1, i.e. only the tree's own reference): when a reservation fails
  the cache evicts cold chains one at a time until the allocation fits or
  nothing is evictable — in which case the admission proceeds *uncached*
  (role ``"skip"``), so a small pool degrades throughput, never progress.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.kv_cache import PageAllocator, pages_per_row

__all__ = ["CachedChain", "PrefixCache", "PrefixCacheStats"]


@dataclasses.dataclass(frozen=True)
class CachedChain:
    """One cached source: its tree key, page chain, and token length."""

    key: Tuple[bytes, ...]
    pages: Tuple[int, ...]
    src_len: int

    @property
    def n_pages(self) -> int:
        return len(self.pages)


@dataclasses.dataclass
class PrefixCacheStats:
    """Monotonic counters (the engine reports per-serve deltas)."""

    hits: int = 0
    misses: int = 0
    inserts: int = 0
    skipped_inserts: int = 0
    evictions: int = 0
    hit_pages: int = 0          # pages whose encode+store a hit skipped
    pages_allocated: int = 0    # chain pages reserved by inserts

    def snapshot(self) -> "PrefixCacheStats":
        return dataclasses.replace(self)


class _Node:
    __slots__ = ("children", "chain")

    def __init__(self):
        self.children: Dict[bytes, "_Node"] = {}
        self.chain: Optional[CachedChain] = None


class PrefixCache:
    """Radix tree of cached sources + LRU eviction over their page chains.

    Purely host-side bookkeeping: the engine owns the device pool arrays
    and performs the actual scatter/gather; this object decides *which*
    pages hold *which* source and who is currently reading them.
    """

    def __init__(self, allocator: PageAllocator):
        self.allocator = allocator
        self.page_size = allocator.page_size
        self._root = _Node()
        self._lru: Dict[Tuple[bytes, ...], CachedChain] = {}  # insertion = LRU
        self.stats = PrefixCacheStats()

    # ------------------------------------------------------------- keying
    def _chunks(self, src) -> Tuple[bytes, ...]:
        toks = np.ascontiguousarray(np.asarray(src, np.int32))
        if toks.size == 0:
            return (b"",)
        ps = self.page_size
        return tuple(toks[i:i + ps].tobytes()
                     for i in range(0, toks.size, ps))

    # ------------------------------------------------------------- lookup
    def _find(self, key: Tuple[bytes, ...]) -> Optional[CachedChain]:
        node = self._root
        for chunk in key:
            node = node.children.get(chunk)
            if node is None:
                return None
        return node.chain

    def lookup(self, src) -> Optional[CachedChain]:
        """Side-effect-free probe (no stats, no refcounts, no LRU bump)."""
        return self._find(self._chunks(src))

    # ---------------------------------------------------------- admission
    def admit(self, src) -> Tuple[str, Optional[CachedChain]]:
        """Route one admission through the cache.

        Returns ``(role, chain)``:

        * ``("hit", chain)`` — the exact source is cached; every chain
          page got ``retain``-ed for this request.  Skip the encoder and
          gather the chain instead.
        * ``("insert", chain)`` — miss with a successful reservation; the
          pages are retained for this request *and* referenced by the
          tree.  Encode normally and scatter the result into ``pages``.
        * ``("skip", None)`` — miss and the pool could not fit the chain
          even after eviction.  Encode normally, cache nothing.

        For "hit"/"insert" the caller must hand ``chain`` back to
        :meth:`finish` exactly once when the request releases.
        """
        key = self._chunks(src)
        chain = self._find(key)
        if chain is not None:
            self.allocator.retain(chain.pages)
            self._lru.pop(key, None)
            self._lru[key] = chain                   # bump to most-recent
            self.stats.hits += 1
            self.stats.hit_pages += chain.n_pages
            return "hit", chain
        self.stats.misses += 1
        n = pages_per_row(len(np.asarray(src).reshape(-1)), self.page_size)
        pages = self._reserve(n)
        if pages is None:
            self.stats.skipped_inserts += 1
            return "skip", None
        chain = CachedChain(key=key, pages=tuple(pages),
                            src_len=int(np.asarray(src).reshape(-1).size))
        node = self._root
        for chunk in key:
            node = node.children.setdefault(chunk, _Node())
        node.chain = chain
        self._lru[key] = chain
        self.allocator.retain(chain.pages)           # requester's reference
        self.stats.inserts += 1
        self.stats.pages_allocated += n
        return "insert", chain

    def finish(self, chain: Optional[CachedChain]) -> None:
        """Drop one request's reference on its chain (release-time)."""
        if chain is not None:
            self.allocator.release(chain.pages)

    # ----------------------------------------------------------- eviction
    def _reserve(self, n: int) -> Optional[List[int]]:
        while True:
            pages = self.allocator.alloc(n)
            if pages is not None:
                return pages
            if not self._evict_one():
                return None

    def _evict_one(self) -> bool:
        """Evict the least-recently-used chain nobody is reading."""
        for key, chain in self._lru.items():
            if all(self.allocator.refcount(p) == 1 for p in chain.pages):
                self._remove(key)
                self.allocator.release(chain.pages)
                self.stats.evictions += 1
                return True
        return False

    def _remove(self, key: Tuple[bytes, ...]) -> None:
        self._lru.pop(key, None)
        path = [self._root]
        for chunk in key:
            nxt = path[-1].children.get(chunk)
            if nxt is None:
                return
            path.append(nxt)
        path[-1].chain = None
        for depth in range(len(key) - 1, -1, -1):    # prune empty spine
            node = path[depth + 1]
            if node.chain is None and not node.children:
                del path[depth].children[key[depth]]
            else:
                break

    def clear(self) -> None:
        """Drop every chain nobody is reading (pool reset between runs)."""
        for key in [k for k, c in self._lru.items()
                    if all(self.allocator.refcount(p) == 1
                           for p in c.pages)]:
            chain = self._lru[key]
            self._remove(key)
            self.allocator.release(chain.pages)
            self.stats.evictions += 1

    # ----------------------------------------------------------- metrics
    @property
    def n_chains(self) -> int:
        return len(self._lru)

    @property
    def pages_held(self) -> int:
        return sum(c.n_pages for c in self._lru.values())
