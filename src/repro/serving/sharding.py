"""Decode-state shardings for tensor-parallel serving.

The engine's burst programs run unchanged under GSPMD: we place the
*inputs* — params via ``distributed.sharding.named_shardings`` and the
decode state via :func:`decode_state_shardings` below — and jit compiles
one SPMD program per mesh, with the per-layer all-reduces inside the
``lax.while_loop``.  Nothing host-side changes: block tables, the token
ring, cursors and allocator state stay replicated, so the scheduler,
prefix cache and preemption spill paths never see the mesh.

What shards where (``tensor`` axis, default ``"model"``):

* K/V pools — paged ``(L, n_pages, ps, HKV, dh)``, contiguous
  ``(L, B, S, HKV, dh)``, cross ``(L, B, enc, HKV, dh)`` and prefix
  pools — split on the heads axis: ``P(None, None, None, tensor, None)``.
* their per-token quant scales ``(..., HKV)``: ``P(None, None, None,
  tensor)``.
* everything else (block tables, lengths, cursors, token ring):
  replicated.

GQA guard: when ``HKV`` does not divide the tensor axis the pools fall
back to replicated — mirroring ``_base_spec``'s k/v_proj rule — instead
of crashing in ``NamedSharding`` construction.  Q heads still shard, so
the attention math stays correct (each device holds every KV head but
only its Q-head slice).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["tp_degree", "kv_pools_shardable", "decode_state_specs",
           "decode_state_shardings", "mesh_axis_sizes"]


def tp_degree(mesh, tensor: str = "model") -> int:
    """Size of the tensor axis (1 when the mesh doesn't have it)."""
    if mesh is None or tensor not in mesh.axis_names:
        return 1
    return int(mesh.shape[tensor])


def mesh_axis_sizes(mesh) -> tuple:
    """Mesh shape as a plain tuple in axis order — for ServeResult."""
    return tuple(int(mesh.shape[a]) for a in mesh.axis_names)


def kv_pools_shardable(mesh, kv_heads: int, tensor: str = "model") -> bool:
    """True iff the K/V pools can split their heads over ``tensor``."""
    tp = tp_degree(mesh, tensor)
    return tp > 1 and kv_heads > 0 and kv_heads % tp == 0


def decode_state_specs(state: Any, *, kv_heads: int, head_dim: int,
                       shard_kv: bool, tensor: str = "model"):
    """PartitionSpec tree matching ``state`` (pools on heads, rest replicated).

    Leaves are recognised structurally — every head-carrying array in a
    decode state is rank-5 ``(..., HKV, dh)`` and every quant scale is a
    rank-4 float ``(..., HKV)``; nothing else in the state has those
    trailing dims.
    """
    def spec(x):
        if not shard_kv:
            return P()
        shape = getattr(x, "shape", ())
        if len(shape) == 5 and shape[-2] == kv_heads and shape[-1] == head_dim:
            return P(None, None, None, tensor, None)
        if (len(shape) == 4 and shape[-1] == kv_heads
                and np.issubdtype(np.dtype(x.dtype), np.floating)):
            return P(None, None, None, tensor)
        return P()

    return jax.tree_util.tree_map(spec, state)


def decode_state_shardings(state: Any, mesh, *, kv_heads: int, head_dim: int,
                           tensor: str = "model"):
    """NamedSharding tree for ``jax.device_put(state, ...)`` on ``mesh``."""
    specs = decode_state_specs(
        state, kv_heads=kv_heads, head_dim=head_dim,
        shard_kv=kv_pools_shardable(mesh, kv_heads, tensor), tensor=tensor)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))
