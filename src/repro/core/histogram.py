"""Streaming activation histograms + distribution classification (paper §4.2).

Calibration is an offline, host-side pass, so this module is numpy — the
observed tensors are pulled off-device once per calibration batch.

Two pieces:

* ``StreamingHistogram`` — fixed bin *count* (2×2048 signed bins), dynamic
  range.  When a new batch exceeds the current range the range doubles and
  bin counts fold pairwise, so a single pass over the calibration set
  suffices (no separate min/max pre-pass).
* ``classify`` — the paper's Figure-2 taxonomy: **sparse** (mass is almost
  entirely at zero with isolated spikes; quantizing these destroys accuracy
  → keep FP32), **narrow** (mass concentrated in a small slice of the
  observed range; clipping helps a lot), **gaussian** (bell-ish; clipping
  helps a little).  12/97 MatMul inputs were sparse in the paper's model.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

HALF_BINS = 2048            # bins per sign → 4096 signed bins, TensorRT-style
_EXPAND = 2.0               # range growth factor (exact pairwise bin folding)


class StreamingHistogram:
    """Signed histogram over [-range, +range] with power-of-two expansion."""

    def __init__(self, half_bins: int = HALF_BINS):
        self.half_bins = int(half_bins)
        self.counts = np.zeros(2 * self.half_bins, dtype=np.int64)
        self.range: float = 0.0          # current |x| range covered
        self.total: int = 0
        self.observed_min: float = np.inf
        self.observed_max: float = -np.inf
        self.zero_count: int = 0         # exact zeros (sparse detection)

    # -- streaming ----------------------------------------------------------
    def observe(self, x: np.ndarray) -> None:
        x = np.asarray(x, dtype=np.float32).ravel()
        x = x[np.isfinite(x)]
        if x.size == 0:
            return
        self.observed_min = min(self.observed_min, float(x.min()))
        self.observed_max = max(self.observed_max, float(x.max()))
        self.zero_count += int(np.count_nonzero(x == 0.0))
        self.total += int(x.size)

        amax = float(np.abs(x).max())
        if amax > self.range:
            self._expand_to(amax)
        if self.range == 0.0:            # all zeros so far
            return
        # bin index: [-range, range) -> [0, 2*half_bins)
        idx = np.floor((x / self.range + 1.0) * self.half_bins).astype(np.int64)
        np.clip(idx, 0, 2 * self.half_bins - 1, out=idx)
        np.add.at(self.counts, idx, 1)

    def _expand_to(self, amax: float) -> None:
        if self.range == 0.0:
            self.range = amax
            return
        while self.range < amax:
            # fold pairs of bins toward the centre: new bin j covers old
            # bins [2j - half, 2j - half + 1] shifted about the zero bin.
            old = self.counts
            n = self.half_bins
            new = np.zeros_like(old)
            # negative side: old bins [0, 2n) span [-r, r); after doubling,
            # old bin i maps to new bin n + (i - n)//2 (floor toward -inf).
            src = np.arange(2 * n)
            dst = n + np.floor_divide(src - n, 2)
            np.add.at(new, dst, old)
            self.counts = new
            self.range *= _EXPAND

    # -- views ----------------------------------------------------------------
    def edges(self) -> np.ndarray:
        return np.linspace(-self.range, self.range, 2 * self.half_bins + 1)

    def positive_half(self) -> Tuple[np.ndarray, float]:
        """Counts over [0, range) with bin width range/half_bins."""
        return self.counts[self.half_bins:].astype(np.float64), self.range

    def negative_half(self) -> Tuple[np.ndarray, float]:
        """Counts over (0, range] of |negative side| (reversed)."""
        return self.counts[:self.half_bins][::-1].astype(np.float64), self.range

    def magnitude(self) -> Tuple[np.ndarray, float]:
        """|x| histogram: fold the two halves together."""
        pos, r = self.positive_half()
        neg, _ = self.negative_half()
        return pos + neg, r

    # -- statistics -----------------------------------------------------------
    def quantile_abs(self, q: float) -> float:
        """Approximate |x| quantile from the magnitude histogram."""
        counts, r = self.magnitude()
        csum = np.cumsum(counts)
        if csum[-1] == 0:
            return 0.0
        k = int(np.searchsorted(csum, q * csum[-1]))
        k = min(k, len(counts) - 1)
        return (k + 1) / len(counts) * r

    def occupancy(self) -> float:
        nz = int(np.count_nonzero(self.counts))
        return nz / self.counts.size

    def zero_fraction(self) -> float:
        return self.zero_count / max(self.total, 1)


@dataclasses.dataclass(frozen=True)
class HistogramClass:
    kind: str                 # "sparse" | "narrow" | "gaussian"
    zero_fraction: float
    occupancy: float
    p999_over_amax: float


# Classification thresholds — validated by tests/test_calibration.py against
# synthetically generated sparse / narrow / gaussian tensors.
SPARSE_ZERO_FRACTION = 0.90
SPARSE_OCCUPANCY = 0.05
NARROW_P999_RATIO = 0.30


def classify(hist: StreamingHistogram) -> HistogramClass:
    """Paper Fig. 2 taxonomy.  ``sparse`` sites must not be quantized."""
    zf = hist.zero_fraction()
    occ = hist.occupancy()
    amax = max(abs(hist.observed_min), abs(hist.observed_max), 1e-30)
    p999 = hist.quantile_abs(0.999)
    ratio = p999 / amax

    if zf >= SPARSE_ZERO_FRACTION and occ <= SPARSE_OCCUPANCY:
        kind = "sparse"
    elif ratio <= NARROW_P999_RATIO:
        # 99.9% of mass sits in <30% of the observed range: a tight core
        # with long-tail outliers — the paper's "narrow" histograms.
        kind = "narrow"
    else:
        kind = "gaussian"
    return HistogramClass(kind=kind, zero_fraction=zf, occupancy=occ,
                          p999_over_amax=ratio)
