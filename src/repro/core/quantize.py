"""Quantization modes (paper §4) and activation/weight quantizers.

The paper evaluates four ways of turning a calibrated histogram into INT8
thresholds (Table 1):

* ``naive``       — absolute Min/Max of the tensor (fails: long tails).
* ``symmetric``   — KL-divergence search on the |x| distribution; thresholds
                    are (-T, T).  Zero zero-point → fastest kernel. Shipped
                    by the paper.
* ``independent`` — split the histogram at zero, search the negative and
                    positive halves independently; thresholds (T_min, T_max)
                    are asymmetric → non-zero zero-point (best accuracy,
                    slightly slower kernel).
* ``conjugate``   — independent search, then report the symmetric envelope
                    T = max(|T_min|, |T_max|).

This module holds the pure-jnp quantizers that *consume* thresholds; the
threshold search itself (which needs histograms) lives in ``calibration.py``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import (
    QTensor,
    abs_max,
    quantize_affine,
    quantize_symmetric,
    quantize_tensor_minmax,
)


class QuantMode(str, enum.Enum):
    NONE = "none"
    NAIVE = "naive"
    SYMMETRIC = "symmetric"
    INDEPENDENT = "independent"
    CONJUGATE = "conjugate"


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """Calibrated clipping thresholds for one tensor site."""

    t_min: float
    t_max: float

    @property
    def symmetric(self) -> bool:
        return abs(self.t_min + self.t_max) <= 1e-9 * max(abs(self.t_max), 1e-30)

    def symmetric_envelope(self) -> "Thresholds":
        t = max(abs(self.t_min), abs(self.t_max))
        return Thresholds(-t, t)


def quantize_with_thresholds(
    x: jax.Array, thr: Thresholds, axis: Optional[int] = None
) -> QTensor:
    """Clip ``x`` to the calibrated range and quantize.

    Symmetric thresholds take the zero-point-free path (paper's shipped
    config); asymmetric thresholds use the affine map.
    """
    if thr.symmetric:
        return quantize_symmetric(x, jnp.float32(thr.t_max), axis=axis)
    return quantize_affine(
        x, jnp.float32(thr.t_min), jnp.float32(thr.t_max), axis=axis
    )


def quantize_dynamic(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Dynamic symmetric quantization (per-call abs-max).

    Used for activations at sites with no calibration record, and as the
    weight quantizer's fallback.  This is the O(N) scan the paper's §5.5
    removes for calibrated sites — keep calibrated scales wherever possible.
    """
    return quantize_symmetric(x, abs_max(x, axis=axis), axis=axis)


def quantize_weight(w: jax.Array, channel_axis: int = -1) -> QTensor:
    """Per-output-channel symmetric weight quantization.

    Weights have well-behaved ranges (no long activation tails), so abs-max
    per channel is the standard choice; per-channel scales fold into the
    matmul epilogue at zero cost.
    """
    axis = channel_axis % w.ndim
    return quantize_symmetric(w, abs_max(w, axis=axis), axis=axis)


def quantize_naive(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Paper §4.1 — absolute Min/Max mapping (kept for the Table-1 repro)."""
    return quantize_tensor_minmax(x, axis=axis)


def fake_quant(x: jax.Array, thr: Thresholds, axis: Optional[int] = None) -> jax.Array:
    """Quantize→dequantize round trip in the original dtype.

    Used to simulate INT8 accuracy loss (Table-1 experiments) without
    running the int8 kernels, and as the straight-through estimator body
    for the (beyond-paper) QAT mode.
    """
    qt = quantize_with_thresholds(x, thr, axis=axis)
    return qt.dequantize(x.dtype)


def fake_quant_dynamic(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    qt = quantize_dynamic(x, axis=axis)
    return qt.dequantize(x.dtype)


def thresholds_for_mode(
    mode: QuantMode,
    observed_min: float,
    observed_max: float,
    kl_min: Optional[float] = None,
    kl_max: Optional[float] = None,
) -> Thresholds:
    """Combine calibration outputs into final thresholds per mode.

    ``kl_min``/``kl_max`` come from the KL-divergence search
    (``calibration.kl_thresholds``); observed_{min,max} are the raw extrema
    (used by ``naive``).
    """
    mode = QuantMode(mode)
    if mode == QuantMode.NAIVE:
        return Thresholds(float(observed_min), float(observed_max))
    if mode == QuantMode.SYMMETRIC:
        assert kl_max is not None
        return Thresholds(-float(kl_max), float(kl_max))
    if mode == QuantMode.INDEPENDENT:
        assert kl_min is not None and kl_max is not None
        return Thresholds(float(kl_min), float(kl_max))
    if mode == QuantMode.CONJUGATE:
        assert kl_min is not None and kl_max is not None
        return Thresholds(float(kl_min), float(kl_max)).symmetric_envelope()
    raise ValueError(f"no thresholds for mode {mode}")
