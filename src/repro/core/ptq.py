"""Post-training quantization: FP32/bf16 model → INT8 model (paper §4).

The transform is purely functional:

    calibrations = Calibrator(fwd).run(batches).compute(mode="symmetric")
    qparams, qctx = quantize_model(params, calibrations, policy)
    logits = model.apply(qparams, batch, quant=qctx)

``quantize_model`` walks the parameter pytree, finds linear nodes (dicts with
a ``"w"`` leaf of rank ≥ 2 — the repo-wide convention), and replaces approved
weights with per-output-channel symmetric :class:`QTensor`.  ``QuantContext``
is the runtime companion the model consults for activation thresholds and
kernel implementation choice.

Site naming convention
----------------------
A linear living at params path ``("decoder", "blocks.3", "attn", "q_proj")``
has site name ``decoder/blocks.3/attn/q_proj``.  Calibration taps record the
matmul *input* under exactly this name.  Scanned (stacked-layer) execution
uses the layer-agnostic name ``decoder/blocks.*/attn/q_proj``; the context
merges per-layer calibration records into a conservative envelope for it.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import SiteCalibration
from repro.core.histogram import HistogramClass
from repro.core.policy import QuantPolicy
from repro.core.qtensor import (
    BlockQTensor,
    QTensor,
    quantize_block,
    quantize_symmetric,
)
from repro.core.quantize import QuantMode, Thresholds

_LAYER_SEG = re.compile(r"blocks\.(\d+)")


def generic_site(site: str) -> str:
    """``decoder/blocks.3/attn/q_proj`` → ``decoder/blocks.*/attn/q_proj``."""
    return _LAYER_SEG.sub("blocks.*", site)


def merge_calibrations(records) -> SiteCalibration:
    """Conservative envelope across per-layer records of one generic site."""
    t_min = min(r.thresholds.t_min for r in records)
    t_max = max(r.thresholds.t_max for r in records)
    any_sparse = any(r.classification.kind == "sparse" for r in records)
    kind = "sparse" if any_sparse else records[0].classification.kind
    cls = HistogramClass(
        kind=kind,
        zero_fraction=max(r.classification.zero_fraction for r in records),
        occupancy=min(r.classification.occupancy for r in records),
        p999_over_amax=max(r.classification.p999_over_amax for r in records),
    )
    return SiteCalibration(
        name=generic_site(records[0].name),
        thresholds=Thresholds(t_min, t_max),
        classification=cls,
        quantize=all(r.quantize for r in records),
    )


@dataclasses.dataclass
class QuantContext:
    """Runtime quantization state consulted by the model's linear layers."""

    policy: QuantPolicy
    calibrations: Dict[str, SiteCalibration] = dataclasses.field(default_factory=dict)
    impl: str = "xla"            # "xla" | "pallas" | "interpret" (kernel choice)
    enabled: bool = True

    def __post_init__(self):
        # Pre-merge layer-indexed records into generic-site envelopes so
        # scanned execution can look them up without knowing layer indices.
        merged: Dict[str, list] = {}
        for name, rec in self.calibrations.items():
            g = generic_site(name)
            if g != name:
                merged.setdefault(g, []).append(rec)
        for g, records in merged.items():
            if g not in self.calibrations:
                self.calibrations[g] = merge_calibrations(records)

    # -- queries the model makes -------------------------------------------
    def lookup(self, site: str) -> Optional[SiteCalibration]:
        rec = self.calibrations.get(site)
        if rec is None:
            rec = self.calibrations.get(generic_site(site))
        return rec

    def activation_thresholds(self, site: str) -> Optional[Thresholds]:
        """Static calibrated thresholds, or None → dynamic quantization."""
        if self.policy.act_quant != "static":
            return None
        rec = self.lookup(site)
        if rec is not None:
            return rec.thresholds
        if self.policy.default_amax is not None:
            t = float(self.policy.default_amax)
            return Thresholds(-t, t)
        return None

    def quantize_activations(self, site: str) -> bool:
        if not self.enabled or self.policy.mode == QuantMode.NONE:
            return False
        return self.policy.should_quantize(site, self.lookup(site))

    @property
    def quantize_kv(self) -> bool:
        return self.enabled and self.policy.quantize_kv_cache


# A context that disables quantization everywhere (FP32/bf16 baseline).
FP_CONTEXT = QuantContext(policy=QuantPolicy(mode=QuantMode.NONE), enabled=False)


# ---------------------------------------------------------------------------
# Parameter transform
# ---------------------------------------------------------------------------

def _is_linear_node(node: Any) -> bool:
    return (
        isinstance(node, dict)
        and "w" in node
        and not isinstance(node["w"], (dict, QTensor, BlockQTensor))
        and getattr(node["w"], "ndim", 0) >= 2
    )


def quantize_weight(w: jax.Array) -> QTensor:
    """Per-output-channel symmetric weight quantization.

    Convention: every linear weight is ``(..., d_in, d_out)`` (leading dims
    are layer-stack / expert dims).  The contraction axis is ``-2``; scales
    keep dims so stacked weights slice cleanly inside ``lax.scan``.
    """
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    amax = jnp.maximum(amax, 1e-12)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) * (127.0 / amax)), -127, 127)
    return QTensor(
        data=q.astype(jnp.int8),
        scale=amax / 127.0,
        zero_point=jnp.zeros_like(amax),
        axis=None,  # scale is pre-broadcast (keepdims)
    )


def quantize_weight_block(
    w: jax.Array,
    group_size: int = 128,
    scale_dtype=jnp.float16,
) -> BlockQTensor:
    """Block-wise INT4 weight quantization (group scale/min along d_in)."""
    return quantize_block(w, group_size=group_size, scale_dtype=scale_dtype)


# Which sites may drop to INT4 (the paper's sensitivity result): decoder FFN
# and attention *output* projections only.  q/k/v projections feed the
# attention score path and the KV cache — those, all encoder weights, the
# logits head and every activation stay INT8/FP.
_INT4_FFN_LEAVES = ("in", "out", "gate", "up", "down")


def int4_eligible_site(site: str) -> bool:
    parts = site.split("/")
    if not any(p == "dec_blocks" or p.startswith("dec_blocks.")
               for p in parts):
        return False
    if parts[-1] == "o_proj":
        return True
    return (len(parts) >= 2 and parts[-2] == "ffn"
            and parts[-1] in _INT4_FFN_LEAVES)


def quantize_model(
    params: Dict[str, Any],
    calibrations: Optional[Dict[str, SiteCalibration]] = None,
    policy: Optional[QuantPolicy] = None,
    impl: str = "xla",
    *,
    weight_bits: int = 8,
    weight_group_size: int = 128,
    weight_scale_dtype=jnp.float16,
) -> Tuple[Dict[str, Any], QuantContext]:
    """PTQ transform: returns (quantized params, runtime QuantContext).

    ``weight_bits=4`` additionally drops the INT4-eligible weights (decoder
    FFN + attention output projections, :func:`int4_eligible_site`) to
    block-wise INT4 with ``weight_group_size`` rows per scale/min block;
    every other approved site keeps the paper's per-channel INT8.
    """
    if weight_bits not in (8, 4):
        raise ValueError(f"weight_bits must be 8 or 4, got {weight_bits}")
    policy = policy or QuantPolicy()
    calibrations = calibrations or {}
    ctx = QuantContext(policy=policy, calibrations=dict(calibrations), impl=impl)

    def walk(node, path):
        if _is_linear_node(node):
            site = "/".join(path)
            out = dict(node)
            if policy.mode != QuantMode.NONE and policy.should_quantize(
                site, ctx.lookup(site)
            ):
                if weight_bits == 4 and int4_eligible_site(site):
                    out["w"] = quantize_weight_block(
                        node["w"], group_size=weight_group_size,
                        scale_dtype=weight_scale_dtype)
                else:
                    out["w"] = quantize_weight(node["w"])
            return out
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        return node

    return walk(params, ()), ctx


def count_quantized(params: Dict[str, Any]) -> Dict[str, int]:
    stats = {"quantized_linears": 0, "fp_linears": 0, "int8_bytes": 0,
             "fp_bytes": 0, "int4_linears": 0, "int4_bytes": 0}

    def walk(node):
        if isinstance(node, QTensor):
            stats["quantized_linears"] += 1
            stats["int8_bytes"] += node.nbytes()
            return
        if isinstance(node, BlockQTensor):
            stats["quantized_linears"] += 1
            stats["int4_linears"] += 1
            stats["int4_bytes"] += node.nbytes()
            return
        if isinstance(node, dict):
            if _is_linear_node(node):
                stats["fp_linears"] += 1
            for v in node.values():
                walk(v)
            return
        if hasattr(node, "nbytes"):
            stats["fp_bytes"] += int(node.nbytes)

    walk(params)
    return stats


def weight_bytes_by_site(params: Dict[str, Any]) -> Dict[str, int]:
    """Per-site weight footprint (bytes actually streamed per decode step):
    quantized payload + scale metadata for Q/BlockQ tensors, raw array bytes
    for FP linears.  Keyed by the linear's site name."""
    out: Dict[str, int] = {}

    def walk(node, path):
        if isinstance(node, dict):
            if _is_linear_node(node) or (
                "w" in node and isinstance(node["w"], (QTensor, BlockQTensor))
            ):
                w = node["w"]
                site = "/".join(path)
                if isinstance(w, (QTensor, BlockQTensor)):
                    out[site] = w.nbytes()
                else:
                    out[site] = int(w.size) * jnp.dtype(w.dtype).itemsize
                return
            for k, v in node.items():
                walk(v, path + (str(k),))

    walk(params, ())
    return out
