"""QTensor — the quantized-tensor pytree used throughout the framework.

A ``QTensor`` carries the int8 payload together with the affine mapping back
to real values:

    real ≈ (data - zero_point) * scale          (per-tensor or per-channel)

This mirrors the paper's Eq. (5)/(6): ``A_q = round((A_f - zero_offset) *
scale)`` with ``scale = target / (Max - Min)``.  ``scale`` here is stored in
the *dequantize* direction (real = q * scale) because that is what the matmul
epilogue consumes; helpers below convert.

Design notes
------------
* Registered as a pytree so QTensors can live inside parameter trees, be
  donated, sharded, and checkpointed like any other leaf-bearing node.
* ``axis`` (static aux data) marks the per-channel axis; ``None`` means
  per-tensor.  ``scale`` broadcasts against ``data`` accordingly.
* ``zero_point`` is kept in float32.  For symmetric quantization it is the
  scalar 0.0 and the epilogue correction folds away at trace time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MIN = -127  # symmetric: avoid -128 so |q| <= 127 (paper keeps ranges symmetric)
INT8_MAX = 127
UINT8_LEVELS = 255


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """int8 payload + affine dequantization parameters."""

    data: jax.Array          # int8
    scale: jax.Array         # f32, broadcastable to ``data`` along ``axis``
    zero_point: jax.Array    # f32, same broadcast rules as ``scale``
    axis: Optional[int] = None   # static: per-channel axis (None = per-tensor)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], Optional[int]]:
        return (self.data, self.scale, self.zero_point), self.axis

    @classmethod
    def tree_unflatten(cls, axis, leaves) -> "QTensor":
        data, scale, zero_point = leaves
        return cls(data=data, scale=scale, zero_point=zero_point, axis=axis)

    # -- conveniences -------------------------------------------------------
    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Paper Eq. (6): ``A_deq = (A_q - zero_offset) * scale``."""
        scale = _expand(self.scale, self.axis, self.data.ndim)
        zp = _expand(self.zero_point, self.axis, self.data.ndim)
        return ((self.data.astype(jnp.float32) - zp) * scale).astype(dtype)

    def nbytes(self) -> int:
        return (int(self.data.size) * jnp.dtype(self.data.dtype).itemsize
                + int(jnp.size(self.scale)) * jnp.dtype(jnp.result_type(self.scale)).itemsize
                + int(jnp.size(self.zero_point)) * jnp.dtype(jnp.result_type(self.zero_point)).itemsize)

    def __repr__(self) -> str:  # avoid dumping arrays in logs
        return (f"QTensor(shape={tuple(self.data.shape)}, axis={self.axis}, "
                f"scale_shape={tuple(jnp.shape(self.scale))})")


def _expand(param: jax.Array, axis: Optional[int], ndim: int) -> jax.Array:
    """Reshape a per-channel vector so it broadcasts along ``axis``."""
    param = jnp.asarray(param, jnp.float32)
    if axis is None or param.ndim == 0:
        return param
    shape = [1] * ndim
    shape[axis] = -1
    return param.reshape(shape)


def quantize_affine(
    x: jax.Array,
    t_min: jax.Array,
    t_max: jax.Array,
    axis: Optional[int] = None,
) -> QTensor:
    """Affine (asymmetric) quantization of ``x`` clipped to [t_min, t_max].

    Maps t_min -> INT8_MIN and t_max -> INT8_MAX (paper Eq. (4)/(5) with a
    signed target).  Used by the ``naive`` and ``independent`` modes where the
    thresholds are not symmetric about zero.
    """
    t_min = jnp.asarray(t_min, jnp.float32)
    t_max = jnp.asarray(t_max, jnp.float32)
    span = jnp.maximum(t_max - t_min, 1e-12)
    # q = round(x * q_scale + q_bias), real = (q - zp) * scale
    q_scale = (INT8_MAX - INT8_MIN) / span
    zp = INT8_MIN - t_min * q_scale            # float zero point in q-space
    xq = jnp.round(x.astype(jnp.float32) * _expand(q_scale, axis, x.ndim)
                   + _expand(zp, axis, x.ndim))
    xq = jnp.clip(xq, INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(data=xq, scale=1.0 / q_scale, zero_point=zp, axis=axis)


def quantize_symmetric(
    x: jax.Array,
    amax: jax.Array,
    axis: Optional[int] = None,
) -> QTensor:
    """Symmetric quantization: thresholds are (-amax, +amax), zero_point = 0.

    This is the mode the paper ultimately ships (§4.2): zero offsets keep the
    QuantizedMatMul kernel on its fast path.  On the TPU MXU (s8 x s8) it
    additionally removes the zero-point correction term entirely.
    """
    amax = jnp.maximum(jnp.asarray(amax, jnp.float32), 1e-12)
    q_scale = INT8_MAX / amax
    xq = jnp.round(x.astype(jnp.float32) * _expand(q_scale, axis, x.ndim))
    xq = jnp.clip(xq, INT8_MIN, INT8_MAX).astype(jnp.int8)
    zp = jnp.zeros_like(amax)
    return QTensor(data=xq, scale=amax / INT8_MAX, zero_point=zp, axis=axis)


def quantize_tensor_minmax(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Paper §4.1 "naive" quantization: absolute Min/Max of the tensor."""
    if axis is None:
        t_min = jnp.min(x)
        t_max = jnp.max(x)
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        t_min = jnp.min(x, axis=reduce_axes)
        t_max = jnp.max(x, axis=reduce_axes)
    return quantize_affine(x, t_min, t_max, axis=axis)


def abs_max(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    if axis is None:
        return jnp.max(jnp.abs(x))
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    return jnp.max(jnp.abs(x), axis=reduce_axes)


def is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


# ---------------------------------------------------------------------------
# BlockQTensor — block-wise (group) INT4 weights, Q4_K spirit
# ---------------------------------------------------------------------------
#
# Layout: the reduction axis (second-to-last, the ``d_in`` of every linear in
# this codebase) is split into groups of ``group_size`` rows.  Each group gets
# an f32/f16 (scale, vmin) pair per output column:
#
#     real[k, n] = q[k, n] * scale[k // G, n] + vmin[k // G, n],  q in [0, 15]
#
# The 4-bit codes are packed two-nibbles-per-int8 *along the reduction axis*:
# logical row 2r is the low nibble of packed row r, logical row 2r+1 the high
# nibble.  Packing never crosses a group boundary because ``group_size`` is
# required to be even.  When K is not a multiple of the group, the tail group
# is padded by replicating the last row (edge padding keeps the group's
# min/max — and therefore its scale — unchanged); ``k_dim`` records the
# logical K so dequant can slice the padding back off.

INT4_LEVELS = 15  # unsigned nibble codes 0..15


def pack_nibbles(q: jax.Array) -> jax.Array:
    """Pack (..., K, N) int codes in [0, 15] → (..., K//2, N) int8 (K even)."""
    if q.shape[-2] % 2:
        raise ValueError(f"packing needs an even row count, got {q.shape}")
    qu = q.astype(jnp.uint8)
    lo = qu[..., 0::2, :]
    hi = qu[..., 1::2, :]
    return jax.lax.bitcast_convert_type(lo | (hi << 4), jnp.int8)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    """Unpack (..., K2, N) int8 → (..., 2*K2, N) int32 codes in [0, 15]."""
    pu = jax.lax.bitcast_convert_type(packed, jnp.uint8).astype(jnp.int32)
    lo = pu & 0xF
    hi = pu >> 4
    stacked = jnp.stack([lo, hi], axis=-2)       # (..., K2, 2, N)
    shape = packed.shape[:-2] + (2 * packed.shape[-2], packed.shape[-1])
    return stacked.reshape(shape)                # row 2r = lo, 2r+1 = hi


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockQTensor:
    """Group-wise INT4 payload (two nibbles per int8) + per-block scale/min."""

    data: jax.Array      # int8, (..., K_store//2, N): packed nibbles along K
    scale: jax.Array     # f32/f16, (..., n_groups, N): dequant scale per block
    vmin: jax.Array      # f32/f16, (..., n_groups, N): block minimum
    group_size: int      # static: rows per block along the reduction axis
    k_dim: int           # static: logical (unpadded) reduction dim

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.scale, self.vmin), (self.group_size, self.k_dim)

    @classmethod
    def tree_unflatten(cls, aux, leaves) -> "BlockQTensor":
        data, scale, vmin = leaves
        group_size, k_dim = aux
        return cls(data=data, scale=scale, vmin=vmin,
                   group_size=group_size, k_dim=k_dim)

    # -- conveniences -------------------------------------------------------
    @property
    def shape(self):
        """Logical (dequantized) shape."""
        return self.data.shape[:-2] + (self.k_dim, self.data.shape[-1])

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def n_groups(self):
        return self.scale.shape[-2]

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Reference dequant: unpack nibbles, apply block scale/min, unpad."""
        q = unpack_nibbles(self.data)                       # (..., K_store, N)
        lead = self.data.shape[:-2]
        n_g, G = self.n_groups, self.group_size
        N = self.data.shape[-1]
        qb = q.reshape(lead + (n_g, G, N)).astype(jnp.float32)
        s = self.scale.astype(jnp.float32)[..., :, None, :]
        m = self.vmin.astype(jnp.float32)[..., :, None, :]
        w = (qb * s + m).reshape(lead + (n_g * G, N))
        return w[..., :self.k_dim, :].astype(dtype)

    def nbytes(self) -> int:
        return (int(self.data.size) * jnp.dtype(self.data.dtype).itemsize
                + int(self.scale.size) * jnp.dtype(self.scale.dtype).itemsize
                + int(self.vmin.size) * jnp.dtype(self.vmin.dtype).itemsize)

    def __repr__(self) -> str:
        return (f"BlockQTensor(shape={tuple(self.shape)}, "
                f"group_size={self.group_size}, n_groups={self.n_groups}, "
                f"scale_dtype={jnp.dtype(self.scale.dtype).name})")


def quantize_block(
    w: jax.Array,
    group_size: int = 128,
    scale_dtype=jnp.float16,
    refine_iters: int = 3,
) -> BlockQTensor:
    """Block-quantize ``w`` (..., K, N) to INT4 along the reduction axis.

    Per group of ``group_size`` rows and per output column the affine map is
    initialized from the group's [min, max] and then refined by
    ``refine_iters`` rounds of alternating least squares (the Q4_K-style
    fit): given the current codes, the MSE-optimal ``(scale, min)`` is the
    closed-form linear regression of the weights on the codes; re-round,
    repeat.  The refinement leaves the byte layout untouched but cuts group
    MSE enough to hold the end-to-end BLEU bar at G=128 where the raw
    min/max fit does not (beam search amplifies per-site error).  Codes are
    finally rounded against the *stored* (possibly f16) scale so the round
    trip sees exactly what the kernel sees.  ``refine_iters=0`` keeps the
    pure min/max fit, whose error is bounded by half a step per element.
    """
    if group_size < 2 or group_size % 2:
        raise ValueError(f"group_size must be even and >= 2, got {group_size}")
    lead = w.shape[:-2]
    K, N = w.shape[-2], w.shape[-1]
    n_g = -(-K // group_size)
    pad = n_g * group_size - K
    wf = jnp.asarray(w, jnp.float32)
    if pad:
        # edge padding: the tail group's min/max (hence scale) is unchanged
        wf = jnp.pad(wf, [(0, 0)] * len(lead) + [(0, pad), (0, 0)],
                     mode="edge")
    wb = wf.reshape(lead + (n_g, group_size, N))
    gmin = jnp.min(wb, axis=-2)
    gmax = jnp.max(wb, axis=-2)
    span = gmax - gmin
    s = jnp.where(span > 0, span / INT4_LEVELS, 0.0)
    m = gmin
    G = group_size
    for _ in range(refine_iters):
        inv = jnp.where(s > 0, 1.0 / jnp.where(s > 0, s, 1.0), 0.0)
        q = jnp.clip(jnp.round((wb - m[..., :, None, :])
                               * inv[..., :, None, :]), 0, INT4_LEVELS)
        # regress w on q per (group, column): minimizes Σ (q·s + m − w)²
        sq = jnp.sum(q, axis=-2)
        sq2 = jnp.sum(q * q, axis=-2)
        sw = jnp.sum(wb, axis=-2)
        sqw = jnp.sum(q * wb, axis=-2)
        det = G * sq2 - sq * sq          # ≥ 0 (Cauchy–Schwarz); 0 ⇔ const q
        s_new = jnp.maximum(
            jnp.where(det > 0, (G * sqw - sq * sw) / jnp.where(det > 0, det,
                                                               1.0), s), 0.0)
        m = jnp.where(det > 0, (sw - s_new * sq) / G, m)
        s = s_new
    scale = s.astype(scale_dtype)
    vmin = m.astype(scale_dtype)
    # quantize against the stored-precision parameters
    scale_f = scale.astype(jnp.float32)
    vmin_f = vmin.astype(jnp.float32)
    inv = jnp.where(scale_f > 0, 1.0 / jnp.where(scale_f > 0, scale_f, 1.0), 0.0)
    q = jnp.clip(jnp.round((wb - vmin_f[..., :, None, :])
                           * inv[..., :, None, :]), 0, INT4_LEVELS)
    packed = pack_nibbles(q.reshape(lead + (n_g * group_size, N)))
    return BlockQTensor(data=packed, scale=scale, vmin=vmin,
                        group_size=group_size, k_dim=K)


def is_block_qtensor(x) -> bool:
    return isinstance(x, BlockQTensor)
