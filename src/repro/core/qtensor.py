"""QTensor — the quantized-tensor pytree used throughout the framework.

A ``QTensor`` carries the int8 payload together with the affine mapping back
to real values:

    real ≈ (data - zero_point) * scale          (per-tensor or per-channel)

This mirrors the paper's Eq. (5)/(6): ``A_q = round((A_f - zero_offset) *
scale)`` with ``scale = target / (Max - Min)``.  ``scale`` here is stored in
the *dequantize* direction (real = q * scale) because that is what the matmul
epilogue consumes; helpers below convert.

Design notes
------------
* Registered as a pytree so QTensors can live inside parameter trees, be
  donated, sharded, and checkpointed like any other leaf-bearing node.
* ``axis`` (static aux data) marks the per-channel axis; ``None`` means
  per-tensor.  ``scale`` broadcasts against ``data`` accordingly.
* ``zero_point`` is kept in float32.  For symmetric quantization it is the
  scalar 0.0 and the epilogue correction folds away at trace time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MIN = -127  # symmetric: avoid -128 so |q| <= 127 (paper keeps ranges symmetric)
INT8_MAX = 127
UINT8_LEVELS = 255


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """int8 payload + affine dequantization parameters."""

    data: jax.Array          # int8
    scale: jax.Array         # f32, broadcastable to ``data`` along ``axis``
    zero_point: jax.Array    # f32, same broadcast rules as ``scale``
    axis: Optional[int] = None   # static: per-channel axis (None = per-tensor)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], Optional[int]]:
        return (self.data, self.scale, self.zero_point), self.axis

    @classmethod
    def tree_unflatten(cls, axis, leaves) -> "QTensor":
        data, scale, zero_point = leaves
        return cls(data=data, scale=scale, zero_point=zero_point, axis=axis)

    # -- conveniences -------------------------------------------------------
    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Paper Eq. (6): ``A_deq = (A_q - zero_offset) * scale``."""
        scale = _expand(self.scale, self.axis, self.data.ndim)
        zp = _expand(self.zero_point, self.axis, self.data.ndim)
        return ((self.data.astype(jnp.float32) - zp) * scale).astype(dtype)

    def nbytes(self) -> int:
        return int(self.data.size) * 1 + int(self.scale.size) * 4 + int(self.zero_point.size) * 4

    def __repr__(self) -> str:  # avoid dumping arrays in logs
        return (f"QTensor(shape={tuple(self.data.shape)}, axis={self.axis}, "
                f"scale_shape={tuple(jnp.shape(self.scale))})")


def _expand(param: jax.Array, axis: Optional[int], ndim: int) -> jax.Array:
    """Reshape a per-channel vector so it broadcasts along ``axis``."""
    param = jnp.asarray(param, jnp.float32)
    if axis is None or param.ndim == 0:
        return param
    shape = [1] * ndim
    shape[axis] = -1
    return param.reshape(shape)


def quantize_affine(
    x: jax.Array,
    t_min: jax.Array,
    t_max: jax.Array,
    axis: Optional[int] = None,
) -> QTensor:
    """Affine (asymmetric) quantization of ``x`` clipped to [t_min, t_max].

    Maps t_min -> INT8_MIN and t_max -> INT8_MAX (paper Eq. (4)/(5) with a
    signed target).  Used by the ``naive`` and ``independent`` modes where the
    thresholds are not symmetric about zero.
    """
    t_min = jnp.asarray(t_min, jnp.float32)
    t_max = jnp.asarray(t_max, jnp.float32)
    span = jnp.maximum(t_max - t_min, 1e-12)
    # q = round(x * q_scale + q_bias), real = (q - zp) * scale
    q_scale = (INT8_MAX - INT8_MIN) / span
    zp = INT8_MIN - t_min * q_scale            # float zero point in q-space
    xq = jnp.round(x.astype(jnp.float32) * _expand(q_scale, axis, x.ndim)
                   + _expand(zp, axis, x.ndim))
    xq = jnp.clip(xq, INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(data=xq, scale=1.0 / q_scale, zero_point=zp, axis=axis)


def quantize_symmetric(
    x: jax.Array,
    amax: jax.Array,
    axis: Optional[int] = None,
) -> QTensor:
    """Symmetric quantization: thresholds are (-amax, +amax), zero_point = 0.

    This is the mode the paper ultimately ships (§4.2): zero offsets keep the
    QuantizedMatMul kernel on its fast path.  On the TPU MXU (s8 x s8) it
    additionally removes the zero-point correction term entirely.
    """
    amax = jnp.maximum(jnp.asarray(amax, jnp.float32), 1e-12)
    q_scale = INT8_MAX / amax
    xq = jnp.round(x.astype(jnp.float32) * _expand(q_scale, axis, x.ndim))
    xq = jnp.clip(xq, INT8_MIN, INT8_MAX).astype(jnp.int8)
    zp = jnp.zeros_like(amax)
    return QTensor(data=xq, scale=amax / INT8_MAX, zero_point=zp, axis=axis)


def quantize_tensor_minmax(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Paper §4.1 "naive" quantization: absolute Min/Max of the tensor."""
    if axis is None:
        t_min = jnp.min(x)
        t_max = jnp.max(x)
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        t_min = jnp.min(x, axis=reduce_axes)
        t_max = jnp.max(x, axis=reduce_axes)
    return quantize_affine(x, t_min, t_max, axis=axis)


def abs_max(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    if axis is None:
        return jnp.max(jnp.abs(x))
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    return jnp.max(jnp.abs(x), axis=reduce_axes)


def is_qtensor(x) -> bool:
    return isinstance(x, QTensor)
