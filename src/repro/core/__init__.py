"""Core INT8 post-training quantization library (the paper's contribution)."""

from repro.core.qtensor import QTensor, quantize_symmetric, quantize_affine  # noqa: F401
from repro.core.quantize import (  # noqa: F401
    QuantMode,
    Thresholds,
    fake_quant,
    fake_quant_dynamic,
    quantize_dynamic,
    quantize_naive,
    quantize_with_thresholds,
)
from repro.core.histogram import StreamingHistogram, classify  # noqa: F401
from repro.core.calibration import (  # noqa: F401
    Calibrator,
    SiteCalibration,
    Taps,
    kl_threshold_search,
    kl_thresholds,
    record,
)
from repro.core.policy import QuantPolicy, summarize  # noqa: F401
from repro.core.ptq import (  # noqa: F401
    FP_CONTEXT,
    QuantContext,
    count_quantized,
    generic_site,
    quantize_model,
    quantize_weight,
)
