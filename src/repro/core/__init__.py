"""Core INT8 post-training quantization library (the paper's contribution)."""

from repro.core.qtensor import (  # noqa: F401
    BlockQTensor,
    QTensor,
    quantize_affine,
    quantize_block,
    quantize_symmetric,
)
from repro.core.quantize import (  # noqa: F401
    QuantMode,
    Thresholds,
    fake_quant,
    fake_quant_dynamic,
    quantize_dynamic,
    quantize_naive,
    quantize_with_thresholds,
)
from repro.core.histogram import StreamingHistogram, classify  # noqa: F401
from repro.core.calibration import (  # noqa: F401
    Calibrator,
    SiteCalibration,
    Taps,
    kl_threshold_search,
    kl_thresholds,
    record,
)
from repro.core.policy import QuantPolicy, summarize  # noqa: F401
from repro.core.ptq import (  # noqa: F401
    FP_CONTEXT,
    QuantContext,
    count_quantized,
    generic_site,
    int4_eligible_site,
    quantize_model,
    quantize_weight,
    quantize_weight_block,
    weight_bytes_by_site,
)
