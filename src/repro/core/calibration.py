"""KL-divergence calibration (paper §4.2).

Workflow (mirrors the paper):

1. Run the FP32/bf16 model over a calibration set (the paper uses 600 of the
   3003 newstest2014 sentences) with activation *taps* enabled; every matmul
   input streams its values into a :class:`StreamingHistogram`.
2. For each site, search the saturation threshold that minimizes the
   KL divergence between the clipped-FP32 distribution and its INT8
   projection (Migacz/TensorRT algorithm).
3. Combine per the requested mode — symmetric / independent / conjugate —
   and classify the histogram; ``sparse`` sites opt out of quantization.

The search runs on host in numpy: calibration is offline and O(bins²/stride),
a few ms per site.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional

import numpy as np
import jax

from repro.core.histogram import HistogramClass, StreamingHistogram, classify
from repro.core.quantize import QuantMode, Thresholds, thresholds_for_mode

_QUANT_LEVELS = 128          # one-sided INT8 target bins (TensorRT uses 128)
_MIN_CANDIDATE = _QUANT_LEVELS
_SEARCH_STRIDE = 8           # evaluate every 8th candidate threshold


# ---------------------------------------------------------------------------
# KL threshold search
# ---------------------------------------------------------------------------

def _kl_divergence(p: np.ndarray, q: np.ndarray) -> float:
    """KL(P||Q) over matching supports; zero bins are handled TensorRT-style."""
    mask = p > 0
    if not mask.any() or q[mask].min() <= 0:
        return np.inf
    p = p[mask] / p.sum()
    q = q[mask] / q[mask].sum()
    return float(np.sum(p * np.log(p / q)))


def kl_threshold_search(
    counts: np.ndarray,
    hist_range: float,
    quant_levels: int = _QUANT_LEVELS,
    stride: int = _SEARCH_STRIDE,
) -> float:
    """Find the clipping threshold minimizing KL(P_clip || Q_int8).

    ``counts`` is a one-sided magnitude histogram over [0, hist_range).
    Returns the threshold magnitude (the bin upper edge minimizing KL).
    """
    counts = np.asarray(counts, dtype=np.float64)
    nbins = len(counts)
    total = counts.sum()
    if total == 0 or hist_range == 0.0:
        return float(hist_range) or 1e-6

    best_kl = np.inf
    best_i = nbins
    for i in range(_MIN_CANDIDATE, nbins + 1, stride):
        # reference distribution: clip everything above bin i into bin i-1
        p = counts[:i].copy()
        outliers = counts[i:].sum()
        p[-1] += outliers
        if p.sum() == 0:
            continue
        # candidate: merge i bins into `quant_levels` groups, then expand
        # back uniformly over the *occupied* bins of each group
        group = i / quant_levels
        idx = (np.arange(i) / group).astype(np.int64)
        np.clip(idx, 0, quant_levels - 1, out=idx)
        q_small = np.bincount(idx, weights=counts[:i], minlength=quant_levels)
        occupied = np.bincount(idx, weights=(counts[:i] > 0).astype(np.float64),
                               minlength=quant_levels)
        expand = np.where(occupied > 0, q_small / np.maximum(occupied, 1), 0.0)
        q = expand[idx] * (counts[:i] > 0)
        kl = _kl_divergence(p, q)
        if kl < best_kl:
            best_kl = kl
            best_i = i
    return best_i / nbins * hist_range


def kl_thresholds(hist: StreamingHistogram, mode: QuantMode) -> Thresholds:
    """Mode-specific threshold extraction (paper §4.2 items 1-3)."""
    mode = QuantMode(mode)
    if mode == QuantMode.NAIVE:
        return Thresholds(hist.observed_min, hist.observed_max)
    amax = max(abs(hist.observed_min), abs(hist.observed_max), 1e-12)
    if mode == QuantMode.SYMMETRIC:
        counts, r = hist.magnitude()
        t = min(kl_threshold_search(counts, r), amax)
        return thresholds_for_mode(mode, hist.observed_min, hist.observed_max,
                                   kl_max=t)
    # independent / conjugate: split about zero, search each half.  The
    # signed histogram spans ±range, so clamp each half's threshold to its
    # own observed extremum (a looser threshold only wastes resolution).
    pos_counts, r = hist.positive_half()
    neg_counts, _ = hist.negative_half()
    t_pos = min(kl_threshold_search(pos_counts, r),
                max(hist.observed_max, 1e-12))
    t_neg = min(kl_threshold_search(neg_counts, r),
                max(-hist.observed_min, 1e-12))
    return thresholds_for_mode(mode, hist.observed_min, hist.observed_max,
                               kl_min=-t_neg, kl_max=t_pos)


# ---------------------------------------------------------------------------
# Activation taps
# ---------------------------------------------------------------------------

class Taps:
    """Collects named intermediate activations during a forward pass.

    Models call ``taps.record(name, x)`` at every quantizable matmul input.
    ``None`` taps (the default everywhere) make ``record`` free.  Calibration
    runs the model with ``scan_layers=False`` so each layer's site gets its
    own name (a ``lax.scan`` body would trace ``record`` only once).
    """

    def __init__(self) -> None:
        self.values: Dict[str, jax.Array] = {}
        self._scope: list[str] = []

    def scope(self, name: str) -> "_TapScope":
        return _TapScope(self, name)

    def record(self, name: str, value: jax.Array) -> None:
        full = "/".join(self._scope + [name])
        self.values[full] = value


class _TapScope:
    def __init__(self, taps: Taps, name: str):
        self.taps, self.name = taps, name

    def __enter__(self):
        self.taps._scope.append(self.name)
        return self.taps

    def __exit__(self, *exc):
        self.taps._scope.pop()


def record(taps: Optional[Taps], name: str, value: jax.Array) -> None:
    if taps is not None:
        taps.record(name, value)


# ---------------------------------------------------------------------------
# Calibrator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SiteCalibration:
    """Final calibration record for one activation site."""

    name: str
    thresholds: Thresholds
    classification: HistogramClass
    quantize: bool                      # False for sparse sites (paper §4.2)


class Calibrator:
    """Streams tapped activations into per-site histograms.

    ``forward_fn(batch, taps)`` is any callable running the model with taps;
    the calibrator owns no model structure, so the same class calibrates
    every architecture in the zoo.
    """

    def __init__(self, forward_fn: Optional[Callable] = None):
        self._forward = forward_fn
        self.histograms: Dict[str, StreamingHistogram] = {}

    # direct observation (tests / custom loops)
    def observe_site(self, name: str, value) -> None:
        hist = self.histograms.get(name)
        if hist is None:
            hist = self.histograms[name] = StreamingHistogram()
        hist.observe(np.asarray(value))

    def observe_taps(self, taps: Taps) -> None:
        for name, value in taps.values.items():
            self.observe_site(name, np.asarray(value))

    def run(self, batches: Iterable) -> "Calibrator":
        assert self._forward is not None, "construct with forward_fn to use run()"
        for batch in batches:
            taps = Taps()
            self._forward(batch, taps)
            self.observe_taps(taps)
        return self

    def compute(self, mode: QuantMode | str = QuantMode.SYMMETRIC
                ) -> Dict[str, SiteCalibration]:
        """Threshold search + classification for every observed site."""
        mode = QuantMode(mode)
        out: Dict[str, SiteCalibration] = {}
        for name, hist in self.histograms.items():
            cls = classify(hist)
            thr = kl_thresholds(hist, mode)
            out[name] = SiteCalibration(
                name=name,
                thresholds=thr,
                classification=cls,
                quantize=(cls.kind != "sparse" and mode != QuantMode.NONE),
            )
        return out
