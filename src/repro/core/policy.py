"""Selective-quantization policy (paper §4.2: "sparse tensors stay FP32").

A policy decides, per matmul site, whether the quantized path is used.  The
decision combines:

* the calibration classification (``sparse`` histograms opt out — the paper
  left 12 of 97 MatMuls in FP32),
* explicit deny-list patterns for numerically sensitive sites the paper's §3
  rules out of INT8 entirely (softmax, layer-norm) plus framework additions
  (MoE router logits, final logits head by default),
* a global mode switch.
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Dict, Optional, Sequence

from repro.core.calibration import SiteCalibration
from repro.core.quantize import QuantMode

# Sites never quantized regardless of calibration — the paper's "keep
# softmax / norm / division in FP32" rule extended to the model zoo.
DEFAULT_DENY: tuple = (
    "*router*",        # MoE routing logits feed a softmax/top-k
    "*gate_ssm*",      # SSM gates/recurrence
    "*logits*",        # final LM head (configurable; BLEU-sensitive)
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    mode: QuantMode = QuantMode.SYMMETRIC
    skip_sparse: bool = True
    deny: Sequence[str] = DEFAULT_DENY
    allow_only: Optional[Sequence[str]] = None   # if set, whitelist mode
    act_quant: str = "static"                    # "static" (calibrated) | "dynamic"
    quantize_kv_cache: bool = True               # paper §5.3 analogue
    # static-mode fallback threshold for uncalibrated sites (paper §5.5:
    # thresholds are trace-time constants — no runtime Min/Max scan, and
    # under SPMD no cross-shard amax reduction on TP-sharded activations)
    default_amax: Optional[float] = None

    def denies(self, site: str) -> bool:
        return any(fnmatch.fnmatch(site, pat) for pat in self.deny)

    def allows(self, site: str) -> bool:
        if self.allow_only is not None:
            return any(fnmatch.fnmatch(site, pat) for pat in self.allow_only)
        return True

    def should_quantize(
        self, site: str, calib: Optional[SiteCalibration] = None
    ) -> bool:
        if self.mode == QuantMode.NONE:
            return False
        if self.denies(site) or not self.allows(site):
            return False
        if calib is not None:
            if self.skip_sparse and calib.classification.kind == "sparse":
                return False
            return calib.quantize
        # No calibration record: static mode cannot quantize activations
        # blindly, dynamic mode can.
        return self.act_quant == "dynamic" or self.mode == QuantMode.NAIVE


def summarize(policy: QuantPolicy,
              calibrations: Dict[str, SiteCalibration]) -> Dict[str, int]:
    """Counts mirroring the paper's '12 of 97 MatMuls stayed FP32' statistic."""
    stats = {"total": 0, "quantized": 0, "sparse_skipped": 0, "denied": 0}
    for site, calib in calibrations.items():
        stats["total"] += 1
        if policy.denies(site) or not policy.allows(site):
            stats["denied"] += 1
        elif policy.skip_sparse and calib.classification.kind == "sparse":
            stats["sparse_skipped"] += 1
        elif policy.should_quantize(site, calib):
            stats["quantized"] += 1
    return stats
