"""Post-compile HLO analysis: collective-traffic accounting.

``cost_analysis()`` gives FLOPs/bytes but no collective bytes, and it counts
while-loop bodies ONCE (verified empirically — see EXPERIMENTS.md
§Methodology).  This module parses the compiled module text:

* finds every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
  ``all-to-all`` / ``collective-permute`` op and sums operand bytes;
* attributes each op to its enclosing computation;
* recovers while-loop trip counts from the loop-condition computations
  (``compare(…, constant(N))``) and multiplies bodies accordingly, so a
  collective inside the layer scan counts n_layers times.

All sizes are **per-device** (the compiled module is the SPMD per-device
program); multiply by device count for fleet totals where needed.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# header: `%name (args…) -> result {`  — args may contain nested parens
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count.*?"n"\s*:\s*"(\d+)"')


def shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string, e.g. 'f32[16,128]' (tuples: sum parts)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    computation: str
    bytes_once: int
    multiplier: int = 1

    @property
    def bytes_total(self) -> int:
        return self.bytes_once * self.multiplier


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        m = _COMP_START.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if m and "{" in line:
            current = m.group(1)
            comps[current] = []
        elif current is not None:
            comps[current].append(line)
            if line.strip() == "}":
                current = None
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Best-effort loop bound from the condition computation's constants."""
    consts = []
    for line in cond_lines:
        if "constant(" in line and "compare" not in line:
            consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def analyze_collectives(hlo: str) -> Dict:
    comps = _split_computations(hlo)

    # while-loop structure: body computation -> trip count.  XLA annotates
    # `backend_config={"known_trip_count":{"n":"48"}}` on the while op; fall
    # back to the condition computation's compare-constant when absent.
    multipliers: Dict[str, int] = defaultdict(lambda: 1)
    edges: List[Tuple[str, str, int]] = []   # (caller, body, trips)
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                t = _TRIP_RE.search(line)
                trips = (int(t.group(1)) if t
                         else _trip_count(comps.get(cond, [])))
                edges.append((name, body, trips))

    # propagate multipliers from the entry computation down (nested loops
    # multiply); entry computations have multiplier 1
    changed = True
    rounds = 0
    while changed and rounds < 10:
        changed = False
        rounds += 1
        for caller, body, trips in edges:
            new = multipliers[caller] * trips
            if new > multipliers[body]:
                multipliers[body] = new
                changed = True

    ops: List[CollectiveOp] = []
    group_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    for comp, lines in comps.items():
        for line in lines:
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1]
            for kind in COLLECTIVES:
                key = f" {kind}("
                start = f" {kind}-start("
                if key not in rhs and start not in rhs:
                    continue
                # result type string sits between '=' and the op keyword
                idx = rhs.find(kind)
                result_b = shape_bytes(rhs[:idx])
                gm = group_re.search(rhs)
                g = int(gm.group(2)) if gm else 2
                # per-device wire bytes for ring implementations
                if kind == "all-reduce":
                    b = int(2 * result_b * (g - 1) / g)
                elif kind == "reduce-scatter":
                    b = int(result_b * (g - 1))          # operand-sized
                elif kind == "collective-permute":
                    b = result_b
                else:                                     # AG / A2A
                    b = int(result_b * (g - 1) / g)
                ops.append(CollectiveOp(kind=kind, computation=comp,
                                        bytes_once=b,
                                        multiplier=multipliers[comp]))
                break

    by_kind: Dict[str, int] = defaultdict(int)
    for op in ops:
        by_kind[op.kind] += op.bytes_total
    return {
        "total_bytes": int(sum(op.bytes_total for op in ops)),
        "by_kind": dict(by_kind),
        "n_ops": len(ops),
        "loop_multipliers": {b: m for (_, b, _), m in
                             zip(edges, [multipliers[b] for _, b, _ in edges])},
    }


def count_hlo_ops(hlo: str, op_names: Tuple[str, ...]) -> Dict[str, int]:
    """Occurrence counts (with loop multipliers) for arbitrary op names."""
    comps = _split_computations(hlo)
    multipliers: Dict[str, int] = defaultdict(lambda: 1)
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                trips = _trip_count(comps.get(m.group(1), []))
                multipliers[m.group(2)] = max(multipliers[m.group(2)], trips)
    out: Dict[str, int] = defaultdict(int)
    for comp, lines in comps.items():
        for line in lines:
            for op in op_names:
                if f" {op}(" in line:
                    out[op] += multipliers[comp]
    return dict(out)
