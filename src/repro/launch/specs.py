"""Abstract input/state specs for the dry-run: ShapeDtypeStruct stand-ins
with NamedShardings attached — weak-type-correct, shardable, no allocation.

Covers, per (arch × shape) cell:
* ``train``   — (params, opt_state, batch) for ``train_step``;
* ``prefill`` — (params_q, batch, decode_state) for ``model.prefill``;
* ``decode``  — (params_q, tokens, decode_state) for ``model.decode_step``
  (one new token against a ``seq_len`` KV cache — ``serve_step``).

Sharding layout (DESIGN §4): batch over (pod, data); vocab/heads/experts/ffn
over "model"; training params+optimizer FSDP over (pod, data) as well;
serving weights "model"-resident; long_500k shards the KV-cache *sequence*
over (pod, data) since batch=1.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import QuantPolicy
from repro.core.ptq import QuantContext, quantize_model
from repro.core.qtensor import QTensor
from repro.distributed.sharding import named_shardings
from repro.launch.mesh import batch_axes, fsdp_axes
from repro.models import kv_cache as kvc
from repro.models.registry import build_model
from repro.optim.adamw import AdamW


def _fit(dim: int, axes, mesh: Mesh):
    if axes is None or not axes:
        return None
    size = int(np.prod([mesh.shape[a] for a in
                        ((axes,) if isinstance(axes, str) else axes)]))
    return axes if dim % size == 0 else None


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, P(*spec)))


def _attach(tree_abs: Any, shardings: Any) -> Any:
    def go(a, s):
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
    # QTensor nodes appear in both trees with matching structure
    return jax.tree_util.tree_map(go, tree_abs, shardings)


# ---------------------------------------------------------------------------
# parameter trees (abstract)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, *, quantized: bool):
    model = build_model(cfg)
    if quantized:
        policy = QuantPolicy(mode=cfg.quant.mode, act_quant="dynamic",
                             quantize_kv_cache=cfg.quant.quantize_kv_cache)

        def init_q(key):
            return quantize_model(model.init(key), {}, policy)[0]
        return model, jax.eval_shape(init_q, jax.random.PRNGKey(0))
    return model, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def train_batch_axes(mesh: Mesh) -> tuple:
    """Training batch shards over (pod, data); "model" carries TP + the
    Megatron-style sequence sharding of activations between blocks."""
    return batch_axes(mesh)


def train_seq_axes(mesh: Mesh):
    return ("model",)


def train_arg_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    optimizer: AdamW) -> Tuple[Any, Any, Any]:
    """(params, opt_state, batch) abstract+sharded for train_step.

    Training parallelism = FSDP over (pod, data) × TP over "model", with
    sequence-parallel activations (the residual stream is (B@data, S@model,
    D) between blocks, so the 80-layer scan carry stays ~67 MB/device).
    """
    model, p_abs = abstract_params(cfg, quantized=False)
    shardings = named_shardings(p_abs, mesh, tensor="model",
                                fsdp=fsdp_axes(mesh),
                                kv_heads=cfg.n_kv_heads)
    p_sds = _attach(p_abs, shardings)

    o_abs = jax.eval_shape(optimizer.init, p_abs)
    # m/v mirror params; step replicated
    m_shard = shardings
    rep = NamedSharding(mesh, P())
    o_sds = type(o_abs)(
        step=jax.ShapeDtypeStruct(o_abs.step.shape, o_abs.step.dtype,
                                  sharding=rep),
        m=_attach(o_abs.m, m_shard),
        v=_attach(o_abs.v, m_shard),
    )
    batch_sds = batch_input_specs(cfg, shape, mesh, kind="train")
    return p_sds, o_sds, batch_sds


def serve_param_specs(cfg: ModelConfig, mesh: Mesh) -> Tuple[Any, Any, Any]:
    """(model, params_sds, qctx) for prefill/decode lowering (INT8 weights)."""
    model, p_abs = abstract_params(cfg, quantized=True)
    shardings = named_shardings(p_abs, mesh, tensor="model", fsdp=None,
                                kv_heads=cfg.n_kv_heads)
    qctx = QuantContext(
        policy=QuantPolicy(mode=cfg.quant.mode, act_quant="dynamic",
                           quantize_kv_cache=cfg.quant.quantize_kv_cache),
        impl="xla")
    return model, _attach(p_abs, shardings), qctx


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        bax = _fit(B, train_batch_axes(mesh), mesh)
        sax = _fit(S, train_seq_axes(mesh), mesh) \
            if train_seq_axes(mesh) else None
    else:
        bax = _fit(B, batch_axes(mesh), mesh)
        sax = None
    dt = jnp.dtype(cfg.dtype)

    if cfg.enc_dec:
        # backbone shapes: encoder gets the stub frame embeddings at S,
        # decoder trains at S (teacher forcing)
        batch: Dict[str, Any] = {}
        if cfg.input_kind == "embeddings":
            batch["src_embeds"] = _sds((B, S, cfg.d_model), dt, mesh,
                                       (bax, sax, None))
        else:
            batch["src_tokens"] = _sds((B, S), jnp.int32, mesh, (bax, sax))
        batch["src_lengths"] = _sds((B,), jnp.int32, mesh, (bax,))
        if kind == "train":
            batch["tgt_tokens"] = _sds((B, S), jnp.int32, mesh, (bax, sax))
            batch["tgt_lengths"] = _sds((B,), jnp.int32, mesh, (bax,))
        return batch

    if cfg.input_kind == "embeddings":
        batch = {"embeds": _sds((B, S, cfg.d_model), dt, mesh,
                                (bax, sax, None))}
    else:
        batch = {"tokens": _sds((B, S), jnp.int32, mesh, (bax, sax))}
    batch["lengths"] = _sds((B,), jnp.int32, mesh, (bax,))
    if kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32, mesh, (bax, sax))
    return batch


# ---------------------------------------------------------------------------
# decode-state specs
# ---------------------------------------------------------------------------

def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       *, quantized: bool) -> Any:
    """Abstract decode state with shardings for the serve_step lowering.

    decode_32k: batch over (pod,data); heads over model when divisible.
    long_500k (batch=1): cache *sequence* over (pod,data) — context
    parallelism — heads over model.
    """
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    extra = {}
    if cfg.enc_dec:
        extra["enc_len"] = 1536      # whisper stub encoder memory (~1500)
    state_abs = jax.eval_shape(
        lambda: model.init_decode_state(B, S, quantized=quantized, **extra))

    bax = _fit(B, batch_axes(mesh), mesh)
    seq_ax = None
    if bax is None:  # batch unshardable (long_500k) → shard cache sequence
        seq_ax = _fit(S, batch_axes(mesh), mesh)

    def cache_spec(leaf, batch_dim: int):
        """Shard (…, B, S, H[, dh]) cache-like leaves.

        Heads take the model axis when they divide it; otherwise the cache
        *sequence* does (flash-decoding style: per-shard partial softmax,
        XLA inserts the tiny combine all-reduces).  Without this, GQA archs
        with 4–8 kv heads replicate the 32k cache over all 16 model shards.
        """
        nd = leaf.ndim
        spec = [None] * nd
        if batch_dim < nd:
            spec[batch_dim] = bax
        heads_ax = None
        if batch_dim + 2 < nd:       # heads
            heads_ax = _fit(leaf.shape[batch_dim + 2], "model", mesh)
            spec[batch_dim + 2] = heads_ax
        if batch_dim + 1 < nd:
            s_ax = seq_ax
            if heads_ax is None and s_ax is None:
                s_ax = _fit(leaf.shape[batch_dim + 1], "model", mesh)
            spec[batch_dim + 1] = s_ax
        return NamedSharding(mesh, P(*spec))

    rep = NamedSharding(mesh, P())

    def walk(node):
        if isinstance(node, kvc.KVCache):
            return kvc.KVCache(
                k=_with(node.k, cache_spec(node.k, 1)),
                v=_with(node.v, cache_spec(node.v, 1)),
                k_scale=(None if node.k_scale is None
                         else _with(node.k_scale, cache_spec(node.k_scale, 1))),
                v_scale=(None if node.v_scale is None
                         else _with(node.v_scale, cache_spec(node.v_scale, 1))),
                lengths=_with(node.lengths,
                              NamedSharding(mesh, P(bax))),
            )
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if v is None:
                    out[k] = None
                elif k in ("cross_k", "cross_v"):
                    out[k] = _with(v, cache_spec(v, 1))
                elif k in ("src_lengths", "lengths"):
                    out[k] = _with(v, NamedSharding(mesh, P(bax)))
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, jax.ShapeDtypeStruct):
            return _state_leaf(node)
        # NamedTuples (SSMState / MLSTMState / SLSTMState)
        if hasattr(node, "_fields"):
            return type(node)(*[walk(getattr(node, f))
                                for f in node._fields])
        return node

    def _state_leaf(leaf):
        """Recurrent states: (…, B, H, …) — shard batch; try model on the
        widest trailing dim."""
        nd = leaf.ndim
        spec = [None] * nd
        # find the batch dim: the axis whose size == B (first match)
        for i, d in enumerate(leaf.shape):
            if d == B:
                spec[i] = bax
                # widest dim after batch gets the model axis
                rest = [(sz, j) for j, sz in enumerate(leaf.shape)
                        if j > i]
                for sz, j in sorted(rest, reverse=True):
                    if _fit(sz, "model", mesh):
                        spec[j] = "model"
                        break
                break
        return _with(leaf, NamedSharding(mesh, P(*spec)))

    def _with(leaf, sharding):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sharding)

    return walk(state_abs)


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    B = shape.global_batch
    bax = _fit(B, batch_axes(mesh), mesh)
    return _sds((B,), jnp.int32, mesh, (bax,))
