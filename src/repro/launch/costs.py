"""Cost probes for the roofline: exact HLO FLOPs/bytes via layer-diff.

``cost_analysis()`` counts while-loop bodies ONCE (verified empirically;
EXPERIMENTS.md §Methodology), so the scanned production graphs under-report
by the trip count.  The probe instead lowers the model on ONE device with

* ``scan_layers=False`` (python loop over layers) and
* ``unroll=True`` sequence scans (attention chunks, SSD chunks, mLSTM
  chunks become trace-time loops)

at ``L0`` and ``2·L0`` layers, so

    per_layer = cost(2·L0) − cost(L0)
    total     = cost(L0) + (n_layers/L0 − 1) · per_layer

is exact for the homogeneous stack (embedding/head costs live in the L0
term).  Probes use the GLOBAL shapes — results are global FLOPs/bytes; the
roofline divides by chip count (matmul work splits evenly across DP+TP).

Residual under-count: the sLSTM time scan (elementwise ops inside; its
matmuls are outside the scan and counted) — noted per-arch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import QuantPolicy
from repro.core.ptq import FP_CONTEXT, QuantContext, quantize_model
from repro.models.registry import build_model
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step


def _probe_layers(cfg: ModelConfig) -> int:
    """Smallest homogeneous layer block (hybrid: one attn_every group;
    xlstm: one slstm_every group)."""
    if cfg.family == "hybrid":
        return cfg.hybrid.attn_every
    if cfg.family == "ssm" and cfg.xlstm:
        return cfg.xlstm.slstm_every
    return 1


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    kw = dict(n_layers=n_layers, scan_layers=False, remat=False)
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_layers
    if cfg.ssm:  # bigger SSD chunks → fewer unrolled chunk iterations
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=2048)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=2048)
    return dataclasses.replace(cfg, **kw)


def _probe_batch(cfg: ModelConfig, shape: ShapeConfig, kind: str):
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if cfg.enc_dec:
        batch = {}
        if cfg.input_kind == "embeddings":
            batch["src_embeds"] = sds((B, S, cfg.d_model), dt)
        else:
            batch["src_tokens"] = sds((B, S), jnp.int32)
        batch["src_lengths"] = sds((B,), jnp.int32)
        if kind == "train":
            batch["tgt_tokens"] = sds((B, S), jnp.int32)
            batch["tgt_lengths"] = sds((B,), jnp.int32)
        else:
            # enc-dec prefill ≈ encode + cross-KV + a BOS decoder step
            batch["tgt_tokens"] = sds((B, 1), jnp.int32)
        return batch
    if cfg.input_kind == "embeddings":
        batch = {"embeds": sds((B, S, cfg.d_model), dt)}
    else:
        batch = {"tokens": sds((B, S), jnp.int32)}
    if kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    return batch


def _cost_of(cfg: ModelConfig, shape: ShapeConfig, *, quantized: bool
             ) -> Dict[str, float]:
    model = build_model(cfg)
    if quantized:
        policy = QuantPolicy(act_quant="dynamic")
        p_abs = jax.eval_shape(
            lambda k: quantize_model(model.init(k), {}, policy)[0],
            jax.random.PRNGKey(0))
        qctx = QuantContext(policy=policy, impl="xla")
    else:
        p_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        qctx = FP_CONTEXT

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        o_abs = jax.eval_shape(opt.init, p_abs)
        step = make_train_step(model, opt)

        def fn(p, o, b):
            return step(p, o, b)
        # unrolled attention for the cost probe rides on model.forward's
        # `unroll` — reach it through a wrapper loss
        from repro.train.step import softmax_cross_entropy
        from repro.data.synthetic import PAD

        def loss_fn(p, b):
            logits, aux = model.forward(p, b, quant=qctx, unroll=True)
            if "labels" in b:
                labels = b["labels"]
            else:
                labels = jnp.pad(b["tgt_tokens"][:, 1:], ((0, 0), (0, 1)))
            mask = (labels != PAD).astype(jnp.float32)
            return softmax_cross_entropy(logits, labels, mask) + \
                0.01 * aux.get("load_balance_loss", 0.0)

        def train_fn(p, o, b):
            (l, g) = jax.value_and_grad(loss_fn)(p, b)
            return opt.update(g, o, p)

        b_abs = _probe_batch(cfg, shape, "train")
        compiled = jax.jit(train_fn).lower(p_abs, o_abs, b_abs).compile()
    elif shape.kind == "prefill":
        b_abs = _probe_batch(cfg, shape, "prefill")
        fwd = lambda p, b: model.forward(p, b, quant=qctx, unroll=True)[0]
        compiled = jax.jit(fwd).lower(p_abs, b_abs).compile()
    else:  # decode
        B = shape.global_batch
        extra = {"enc_len": 1536} if cfg.enc_dec else {}
        st_abs = jax.eval_shape(
            lambda: model.init_decode_state(B, shape.seq_len,
                                            quantized=quantized and
                                            qctx.quantize_kv, **extra))
        t_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        fn = lambda p, t, s: model.decode_step(p, t, s, quant=qctx)
        compiled = jax.jit(fn).lower(p_abs, t_abs, st_abs).compile()

    ca = compiled.cost_analysis() or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def probe(arch: str, shape_name: str, *, quantized: bool) -> Dict[str, float]:
    """Global HLO FLOPs/bytes for one (arch × shape), layer-diff method."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    L0 = _probe_layers(cfg)
    c1 = _cost_of(_probe_cfg(cfg, L0), shape, quantized=quantized)
    c2 = _cost_of(_probe_cfg(cfg, 2 * L0), shape, quantized=quantized)
    groups = cfg.n_layers // L0
    out = {}
    for k in ("flops", "bytes"):
        per_group = c2[k] - c1[k]
        out[k] = c1[k] + (groups - 1) * per_group
        out[f"{k}_per_group"] = per_group
        out[f"{k}_boundary"] = c1[k] - per_group   # embed/head/loss share
    out["n_groups"] = groups
    return out
