"""Serving driver: the paper's full inference stack on a reduced model.

``python -m repro.launch.serve --arch transformer-base --requests 64
  --quant symmetric --streams 2 --beam 1``

Pipeline (``--mode static``, the paper's): synthetic requests →
token-sorted scheduler → (optional calibrated INT8 PTQ) → parallel stream
workers → throughput report.

``--mode continuous`` swaps the back half for the continuous batching
engine: requests are bin-packed to a token budget (FFD) for admission
order, then stream through ``ServingEngine.serve``'s slot-refill decode
loop, reporting per-request first-token/total latency and decode-grid
utilization.  ``--beam B`` (B > 1) with ``--mode continuous`` serves beam
search through the same engine: each request takes a group of B contiguous
decode rows (`--slots // B` groups), finished groups free all B rows
atomically and are refilled mid-decode.  Admissions ride the burst program
by default (one jitted dispatch per serve round; ``--unfused-admission``
restores the separate-prefill baseline), and ``--burst-len auto`` puts the
burst cap under the adaptive controller.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    Calibrator,
    QuantMode,
    QuantPolicy,
    Taps,
    count_quantized,
    quantize_model,
)
from repro.core.ptq import FP_CONTEXT
from repro.data import corpus_bleu, make_corpus, pack_batches_token_budget
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.serving import ParallelStreams, ReplicaRouter, Request, \
    ServingEngine, TokenSortedScheduler, make_chaos


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="transformer-base")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--quant", default="symmetric",
                    choices=["none", "naive", "symmetric", "independent",
                             "conjugate"])
    ap.add_argument("--streams", type=int, default=2)
    ap.add_argument("--beam", type=int, default=1,
                    help="beam width (1 = greedy); with --mode continuous, "
                         "each request occupies a group of `beam` decode "
                         "rows (--slots // beam groups)")
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--sort", default="tokens",
                    choices=["none", "words", "tokens"])
    ap.add_argument("--mode", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots for --mode continuous")
    ap.add_argument("--token-budget", type=int, default=256,
                    help="FFD bin budget (padded tokens) for admission "
                         "order in --mode continuous")
    ap.add_argument("--burst-len", default="8",
                    help="decode steps fused on device per host round trip "
                         "(1 = per-step loop; larger bursts cut dispatch "
                         "overhead but delay slot refill to burst edges); "
                         "'auto' adapts the cap between bursts from "
                         "measured sync cost vs mid-burst EOS waste")
    ap.add_argument("--unfused-admission", action="store_true",
                    help="serve admissions as separate prefill dispatches "
                         "(the pre-fusion baseline) instead of folding "
                         "them into the burst program")
    ap.add_argument("--paged", action="store_true",
                    help="back the decode KV cache with fixed-size pages + "
                         "block tables: beam reorder becomes a table "
                         "permutation (no slab copy) and admission is "
                         "paced by a page budget instead of contiguous "
                         "row capacity (--mode continuous only)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--paged; must divide the "
                         "engine max_len)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool size (--paged; default: contiguous-"
                         "equivalent capacity)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share encoded cross-K/V across requests with "
                         "identical sources: a radix-tree hit bumps a page "
                         "refcount instead of re-running the encoder "
                         "(--mode continuous only; token-identical output)")
    ap.add_argument("--prefix-pages", type=int, default=256,
                    help="prefix-cache chain-pool size in pages "
                         "(--prefix-cache; LRU-evicted under pressure)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline on the serve clock (--mode "
                         "continuous): the wait queue runs EDF-with-aging "
                         "and provably-unmeetable requests are shed with "
                         "status 'rejected' instead of admitted (note: "
                         "jit compile lands inside the first serve, so "
                         "tight SLOs shed on cold starts)")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="KV page reservation cap as a multiple of the "
                         "physical pool (--paged; >1 admits past worst-"
                         "case reservation, preempt-by-page-spill covers "
                         "the shortfall when budgets actually collide)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="sources longer than this many tokens stage one "
                         "encoder layer per serving round instead of "
                         "blocking an admission round on the full encode "
                         "(--mode continuous with fused admission)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="serving chaos harness: inject a seeded forced-"
                         "preemption schedule at burst edges (--paged); "
                         "output tokens are identical to an uninterrupted "
                         "serve — use to drill spill/restore in situ")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="run the engine tensor-parallel on a (data,model) "
                         "mesh, e.g. '1,4': weights and K/V-pool heads "
                         "split on the model axis, token-identical output "
                         "(--mode continuous; needs that many devices — "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N exposes host devices)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind the "
                         "free-page/queue-depth router (--mode continuous; "
                         "each replica serves its share concurrently)")
    ap.add_argument("--weight-bits", type=int, default=8, choices=(8, 4),
                    help="weight payload precision: 8 = the paper's "
                         "per-channel INT8 everywhere; 4 = decoder FFN and "
                         "attention output projections drop to block-wise "
                         "INT4 (packed nibbles + group scale/min, dequant "
                         "fused into the matmul kernel) while activations, "
                         "attention score paths and the KV cache stay INT8")
    ap.add_argument("--weight-group-size", type=int, default=128,
                    help="rows per INT4 scale/min block along d_in "
                         "(--weight-bits 4; smaller = more accurate, "
                         "larger = fewer metadata bytes)")
    args = ap.parse_args()
    burst_len = args.burst_len if args.burst_len == "auto" \
        else int(args.burst_len)

    cfg = get_config(args.arch).reduced()
    if not cfg.enc_dec:
        raise SystemExit("serve driver expects an enc-dec (NMT) arch")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = make_corpus(args.requests + 64, cfg.vocab, seed=11)
    requests = corpus[:args.requests]

    qctx = FP_CONTEXT
    if args.quant != "none":
        cal = Calibrator()
        for s in corpus[args.requests:args.requests + 32]:
            taps = Taps()
            model.forward(params, {
                "src_tokens": jnp.asarray(s.src[None, :]),
                "tgt_tokens": jnp.asarray(
                    np.concatenate([[1], s.tgt, [2]])[None, :])}, taps=taps)
            cal.observe_taps(taps)
        recs = cal.compute(args.quant)
        params, qctx = quantize_model(
            params, recs, QuantPolicy(mode=QuantMode(args.quant),
                                      act_quant="static"),
            weight_bits=args.weight_bits,
            weight_group_size=args.weight_group_size)
        print(f"quantized with mode={args.quant}: "
              f"{sum(r.quantize for r in recs.values())}/{len(recs)} "
              "calibrated sites quantizable")
        if args.weight_bits == 4:
            stats = count_quantized(params)
            print(f"INT4 weights: {stats['int4_linears']} decoder linears, "
                  f"{stats['int4_bytes']} bytes "
                  f"(group_size={args.weight_group_size}); "
                  f"INT8 elsewhere: {stats['int8_bytes']} bytes")

    if args.mesh and args.mode != "continuous":
        raise SystemExit("--mesh needs --mode continuous")
    if args.replicas > 1 and args.mode != "continuous":
        raise SystemExit("--replicas needs --mode continuous")

    if args.mode == "continuous":
        mesh = None
        if args.mesh:
            try:
                data_ax, model_ax = (int(x) for x in args.mesh.split(","))
            except ValueError:
                raise SystemExit(f"--mesh wants 'DATA,MODEL', "
                                 f"got {args.mesh!r}")
            mesh = make_host_mesh(data=data_ax, model=model_ax)

        def mk_engine():
            return ServingEngine(model, params, quant=qctx, max_len=96,
                                 burst_len=burst_len, paged=args.paged,
                                 page_size=args.page_size,
                                 n_pages=args.n_pages,
                                 prefix_cache=args.prefix_cache,
                                 prefix_pages=args.prefix_pages,
                                 mesh=mesh)

        engine = mk_engine()
        bins = pack_batches_token_budget(requests, args.token_budget)
        order = [i for b in bins for i in b]     # FFD admission order
        beam = args.beam if args.beam > 1 else None
        reqs = [requests[i] for i in order]
        if args.deadline_ms is not None:
            reqs = [Request(req_id=k, src=np.asarray(s.src, np.int32),
                            max_new_tokens=args.max_new_tokens,
                            deadline_s=args.deadline_ms / 1e3)
                    for k, s in enumerate(reqs)]
        chaos = (make_chaos(args.chaos_seed, n_rounds=256, preempt_every=2)
                 if args.chaos_seed is not None else None)
        serve_kw = dict(n_slots=args.slots,
                        max_new_tokens=args.max_new_tokens,
                        beam=beam,
                        fused_admission=not args.unfused_admission,
                        overcommit=args.overcommit,
                        prefill_chunk=args.prefill_chunk,
                        chaos=chaos)
        if args.replicas > 1:
            router = ReplicaRouter(
                [engine] + [mk_engine() for _ in range(args.replicas - 1)])
            rres = router.serve(reqs, **serve_kw)
            print(f"router x{args.replicas}: {len(rres.requests)} requests "
                  f"in {rres.wall_s:.2f}s ({rres.tokens_per_s:.1f} tok/s), "
                  f"per-replica peak_running "
                  f"{rres.peak_running_per_replica}, "
                  f"assignment counts "
                  f"{[rres.assignment.count(i) for i in range(args.replicas)]}")
            for i, r in enumerate(rres.results):
                print(f"  replica {i}: {sum(len(q.tokens) for q in r.requests)}"
                      f" tokens, {r.host_syncs} syncs, "
                      f"utilization {r.utilization:.2f}"
                      + (f", tp={r.tp_degree} mesh={r.mesh_shape}"
                         if r.tp_degree > 1 else ""))
            return
        t0 = time.perf_counter()
        res = engine.serve(reqs, **serve_kw)
        dt = time.perf_counter() - t0
        met = res.metrics()
        print(f"served {args.requests} requests in {dt:.2f}s "
              f"({res.tokens_per_s:.1f} tok/s, "
              f"slot utilization {res.utilization:.2f}, "
              f"{res.prefill_rounds} admission rounds)")
        if res.tp_degree > 1:
            print(f"tensor-parallel: mesh {res.mesh_shape} "
                  f"(tp={res.tp_degree}), predicted "
                  f"{res.collective_bytes_per_step} collective "
                  f"bytes/step/device inside the burst")
        if beam:
            print(f"beam={res.beam}: {res.n_groups} groups of {res.beam} "
                  f"rows in a {res.n_slots}-row grid"
                  + (f" ({args.slots - res.n_slots} rows stranded — "
                     f"beam does not divide --slots)"
                     if res.n_slots != args.slots else ""))
        print(f"burst_len={res.burst_len}"
              + (" (auto)" if res.auto_burst else "")
              + f": {res.host_syncs} host syncs for "
              f"{res.decode_steps} decode steps "
              f"({res.decode_steps_per_s:.0f} steps/s)")
        print(("fused admission" if res.fused_admission
               else "UNFUSED admission")
              + f": {res.prefill_dispatches} prefill dispatches, "
              f"{res.encoder_tokens} encoder row-tokens")
        if res.paged:
            print(f"paged KV: page_size={res.page_size}, "
                  f"peak {res.page_hwm} pages "
                  f"({res.page_hwm * res.page_size} tokens), "
                  f"{res.pages_in_use} leaked, "
                  f"beam-reorder bytes {res.reorder_bytes}")
        if res.prefix_cache:
            print(f"prefix cache: {res.prefix_hits} hits / "
                  f"{res.prefix_hits + res.prefix_misses} admissions "
                  f"(hit rate {met['prefix_hit_rate']:.2f}), "
                  f"{res.prefix_hit_pages} chain pages reused, "
                  f"{res.prefix_pages_allocated} allocated, "
                  f"{res.prefix_evictions} evicted, "
                  f"{res.prefix_chains} chains resident")
        if (res.preemptions or res.chunked_admissions or res.rejected
                or res.overcommit != 1.0 or chaos is not None
                or args.deadline_ms is not None):
            print(f"overload: overcommit={res.overcommit} "
                  f"peak_running={res.peak_running}, "
                  f"{res.preemptions} preemptions "
                  f"({res.spill_events} spills / {res.restore_events} "
                  f"restores, {res.spilled_bytes / 1024:.1f} KiB to host), "
                  f"free_lwm={res.free_lwm}")
            print(f"         {res.chunked_admissions} chunked admissions "
                  f"({res.chunk_rounds} staged encoder rounds), "
                  f"{res.rejected} shed, "
                  f"{res.deadline_misses} deadline misses, "
                  f"{res.straggler_rounds} straggler rounds")
        print(f"latency: first-token mean "
              f"{met['first_token_latency_mean_s']:.3f}s "
              f"p95 {met['first_token_latency_p95_s']:.3f}s; total mean "
              f"{met['total_latency_mean_s']:.3f}s "
              f"p95 {met['total_latency_p95_s']:.3f}s")
        return

    engines = [ServingEngine(model, params, quant=qctx, max_len=96)
               for _ in range(args.streams)]
    sched = TokenSortedScheduler(batch_size=args.batch_size,
                                 sort_mode=args.sort)
    items = sched.plan(requests)
    print(f"{len(items)} batches; padding stats: {sched.stats(requests)}")

    def run_batch(sid: int, item) -> int:
        eng = engines[sid]
        if args.beam > 1:
            res = eng.generate_beam(item.batch, beam=args.beam,
                                    max_new_tokens=args.max_new_tokens)
        else:
            res = eng.generate(item.batch,
                               max_new_tokens=args.max_new_tokens)
        return res.n_tokens

    streams = ParallelStreams(run_batch, n_streams=args.streams)
    t0 = time.perf_counter()
    out = streams.run(items)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({args.requests / dt:.2f} sentences/s, "
          f"{out['throughput_tok_s']:.1f} tok/s, "
          f"stream utilization {out['utilization']:.2f})")


if __name__ == "__main__":
    main()
