import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512
placeholder CPU devices so the production meshes can build.

Per cell this script:
  1. builds abstract, sharded inputs (``launch/specs.py`` —
     ShapeDtypeStruct only, no allocation),
  2. ``jax.jit(step).lower(...).compile()`` under the production mesh,
  3. records ``memory_analysis()`` (proves the per-device footprint fits),
     ``cost_analysis()`` (FLOPs/bytes for §Roofline), and the parsed
     collective schedule (``hlo_analysis.py``),
  4. writes ``experiments/dryrun/<cell>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--fp-baseline]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.core.ptq import FP_CONTEXT
from repro.distributed.context import activation_sharding
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models.registry import build_model
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step

V5E = {"bf16_flops": 197e12, "int8_ops": 394e12, "hbm_gbps": 819e9,
       "ici_gbps": 50e9}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               quantized: bool = True, accum: int = 0):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    bax = batch_axes(mesh)

    with mesh:
        if shape.kind == "train":
            opt = AdamW(lr=1e-4)
            p_sds, o_sds, b_sds = S.train_arg_specs(cfg, shape, mesh, opt)
            model = build_model(cfg)
            # accum=1 default: sequence-sharded activations keep the layer
            # carry small, and each extra microbatch repeats the FSDP grad
            # reduce-scatter (params-sized wire traffic).
            accum = accum or 1
            grad_shardings = jax.tree_util.tree_map(
                lambda s: s.sharding, p_sds,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            step = make_train_step(
                model, opt, accum_steps=accum,
                grad_shardings=grad_shardings,
                mixed_precision=os.environ.get(
                    "REPRO_MIXED_PRECISION", "0") == "1")
            act_spec = P(S.train_batch_axes(mesh), "model", None)
            with activation_sharding(act_spec):
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    p_sds, o_sds, b_sds)
        elif shape.kind == "prefill":
            model, p_sds, qctx = S.serve_param_specs(cfg, mesh)
            if not quantized:
                _, p_abs = S.abstract_params(cfg, quantized=False)
                from repro.distributed.sharding import named_shardings
                p_sds = S._attach(p_abs,
                                  named_shardings(p_abs, mesh,
                                                  tensor="model", fsdp=None,
                                                  kv_heads=cfg.n_kv_heads))
                qctx = FP_CONTEXT
            b_sds = S.batch_input_specs(cfg, shape, mesh, kind="prefill")
            st_sds = S.decode_state_specs(cfg, shape, mesh,
                                          quantized=quantized and
                                          qctx.quantize_kv)
            fn = lambda p, b, s: model.prefill(p, b, s, quant=qctx)
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                p_sds, b_sds, st_sds)
        else:  # decode — serve_step
            model, p_sds, qctx = S.serve_param_specs(cfg, mesh)
            if not quantized:
                _, p_abs = S.abstract_params(cfg, quantized=False)
                from repro.distributed.sharding import named_shardings
                p_sds = S._attach(p_abs,
                                  named_shardings(p_abs, mesh,
                                                  tensor="model", fsdp=None,
                                                  kv_heads=cfg.n_kv_heads))
                qctx = FP_CONTEXT
            t_sds = S.decode_token_specs(cfg, shape, mesh)
            st_sds = S.decode_state_specs(cfg, shape, mesh,
                                          quantized=quantized and
                                          qctx.quantize_kv)
            fn = lambda p, t, s: model.decode_step(p, t, s, quant=qctx)
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                p_sds, t_sds, st_sds)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = analyze_collectives(hlo)

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "quantized": quantized,
        "kind": shape.kind,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes +
                 mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "cost_analysis": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_per_device": cost.get("bytes accessed", 0.0),
        },
        "collectives": coll,
        "model_params": get_config(arch).n_params,
        "model_active_params": get_config(arch).n_active_params,
    }


def cell_name(arch, shape, multi_pod, quantized):
    tag = "2pod" if multi_pod else "1pod"
    q = "int8" if quantized else "bf16"
    return f"{arch}__{shape}__{tag}__{q}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fp-baseline", action="store_true",
                    help="also lower the bf16 (unquantized) serving variant")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    archs = [a for a in archs if a != "transformer-base"]  # paper model: not a cell

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        for shape, skip in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for mp in meshes:
                for q in ([True, False] if args.fp_baseline and
                          shape.kind != "train" else [True]):
                    name = cell_name(arch, shape.name, mp, q)
                    path = os.path.join(args.out, name + ".json")
                    if args.skip_existing and os.path.exists(path):
                        print(f"SKIP (cached) {name}")
                        continue
                    if skip is not None:
                        rec = {"arch": arch, "shape": shape.name,
                               "mesh": "2x16x16" if mp else "16x16",
                               "skipped": skip}
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=2)
                        print(f"SKIP {name}: {skip}")
                        continue
                    print(f"RUN  {name} ...", flush=True)
                    try:
                        rec = lower_cell(arch, shape.name, multi_pod=mp,
                                         quantized=q)
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=2)
                        print(f"  OK mem={rec['memory']['peak_per_device_gib']}GiB "
                              f"compile={rec['compile_s']}s "
                              f"coll={rec['collectives']['total_bytes']/2**30:.2f}GiB",
                              flush=True)
                        results.append(rec)
                    except Exception as e:
                        failures.append((name, repr(e)))
                        print(f"  FAIL {name}: {e}")
                        traceback.print_exc()
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    for name, err in failures:
        print(" FAILED:", name, err[:200])
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
