"""Launchers: mesh.py (production meshes), dryrun.py (multi-pod dry-run),
train.py / serve.py (drivers), specs.py (abstract sharded inputs),
hlo_analysis.py (collective accounting)."""
