"""Training driver: ``python -m repro.launch.train --arch yi-9b --steps 100``.

Runs a REDUCED config end-to-end on local devices (this container: 1 CPU
core) with the full production substrate: checkpointed loop, watchdog,
restart wrapper, resumable data iterator.  On a real pod the same driver
runs the full config under ``make_production_mesh()`` with the sharded
specs from ``launch/specs.py`` (see ``--production`` which lowers but does
not execute here).
"""

from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import LMBatches, TranslationBatches, make_corpus
from repro.distributed.fault import StepWatchdog, run_with_restarts
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import make_train_step, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="transformer-base")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, accum_steps=args.accum))

    if cfg.enc_dec:
        corpus = make_corpus(800, cfg.vocab, seed=0)
        data = TranslationBatches(corpus, args.batch_size,
                                  sort_mode="tokens")
    else:
        data = LMBatches(cfg.vocab, args.batch_size, args.seq_len)

    ck = Checkpointer(args.ckpt_dir, keep=2) if args.ckpt_dir else None

    def job():
        out = train_loop(train_step=step, params=params,
                         opt_state=opt_state, batches=data,
                         steps=args.steps, checkpointer=ck,
                         save_every=args.save_every,
                         watchdog=StepWatchdog())
        hist = out["history"]
        print(f"final loss: {hist[-1]['loss']:.4f} "
              f"(first logged: {hist[0]['loss']:.4f})")
        print("watchdog:", out["watchdog"])

    run_with_restarts(job, max_restarts=args.max_restarts)


if __name__ == "__main__":
    main()
