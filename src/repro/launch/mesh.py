"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick and for tests that must see
one device.
"""

from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def _require_devices(shape, axes) -> list:
    """The first ``prod(shape)`` devices, or a clear error.

    ``jax.devices()[:n]`` silently under-fills when fewer devices exist and
    ``make_mesh`` then fails with an opaque reshape error — raise here with
    the fix spelled out instead.
    """
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        req = "×".join(f"{a}={s}" for a, s in zip(axes, shape))
        raise ValueError(
            f"mesh ({req}) needs {n} devices but jax.devices() provides "
            f"{len(devices)}; shrink the mesh or launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(set before jax imports)")
    return devices[:n]


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 = 256 chips; multi-pod adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = _require_devices(shape, axes)  # dry-run exposes 512 host devs
    return make_mesh(shape, axes, devices=devices)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices — tests/examples/sharded serving."""
    axes = ("data", "model")
    devices = _require_devices((data, model), axes)
    return make_mesh((data, model), axes, devices=devices)


def batch_axes(mesh) -> tuple:
    """Axes a global batch shards over (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def fsdp_axes(mesh) -> tuple:
    """Axes FSDP parameter sharding uses at training time."""
    return batch_axes(mesh)
