"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick and for tests that must see
one device.
"""

from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 = 256 chips; multi-pod adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]     # dry-run exposes 512 host devices
    return make_mesh(shape, axes, devices=devices)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes a global batch shards over (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def fsdp_axes(mesh) -> tuple:
    """Axes FSDP parameter sharding uses at training time."""
    return batch_axes(mesh)
