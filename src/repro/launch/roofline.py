"""Roofline assembly (deliverable g): three terms per (arch × shape × mesh).

    compute_s    = HLO_FLOPs_global / (chips × peak)      [probe, layer-diff]
    memory_s     = per-device HBM bytes / HBM_bw
    collective_s = per-device collective wire bytes / link_bw   [HLO-parsed]

Sources:
* FLOPs: ``launch/costs.probe`` — single-device unrolled layer-diff
  lowering (exact; the scanned SPMD module's cost_analysis counts loop
  bodies once).
* memory bytes: decode steps stream their arguments once per token —
  ``memory_analysis().argument_size_in_bytes`` of the compiled SPMD cell is
  per-device weights+cache, the dominant traffic; prefill/train use the
  probe's global bytes / chips (activation-dominated).
* collective bytes: ``hlo_analysis.analyze_collectives`` over the compiled
  SPMD module with while-loop trip-count multipliers (per-device wire
  bytes for ring implementations).

Hardware (TPU v5e): 197 TFLOP/s bf16 (394 TOPS int8), 819 GB/s HBM,
~50 GB/s/link ICI.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --all
  PYTHONPATH=src python -m repro.launch.roofline --table   # markdown
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

from repro.configs import SHAPES, get_config, shapes_for

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = "experiments/dryrun"
OUT_DIR = "experiments/roofline"


def _dryrun_record(arch: str, shape: str, mesh_tag: str, q: str
                   ) -> Optional[Dict]:
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh_tag}__{q}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: per emitted token


def decode_collective_bytes(*, n_layers: int, d_model: int, rows: int,
                            tp: int, act_bytes: int = 4,
                            vocab: int = 0) -> int:
    """Per-device wire bytes of ONE tensor-parallel decode step (analytic).

    With weights split on the "model" axis each decoder layer partial-sums
    three row-parallel projections — self-attention out, cross-attention
    out, FFN down — each an all-reduce of the ``(rows, d_model)``
    activation; a ring all-reduce of ``b`` bytes moves ``2·b·(g-1)/g``
    per device.  The vocab-parallel unembedding adds one logits
    all-gather (``b·(g-1)/g`` of ``(rows, vocab)`` float32).  ``tp <= 1``
    → 0 (no collectives compile).

    This is the roofline's *prediction*; ``hlo_analysis.analyze_collectives``
    over the compiled SPMD module is the measurement it is checked
    against (``benchmarks/bench_sharded_serve.py``).
    """
    if tp <= 1:
        return 0
    act = rows * d_model * act_bytes
    all_reduce = 2 * act * (tp - 1) // tp
    total = n_layers * 3 * all_reduce
    if vocab:
        total += rows * vocab * 4 * (tp - 1) // tp
    return int(total)


def weight_stream_bytes(n_params: int, *, quantized: bool = True,
                        act_bytes: int = 4, weight_bits: int = 8,
                        group_size: int = 128, scale_bytes: int = 2,
                        int4_fraction: float = 1.0) -> int:
    """Weight bytes one decode step streams from HBM.

    * FP: ``n · act_bytes``.
    * INT8: ``n`` (1 byte/weight; the per-channel scale is O(1/d_in),
      ignored, matching the pre-INT4 term).
    * INT4 (``weight_bits=4``): the eligible ``int4_fraction`` of weights
      streams a nibble plus the block metadata — two ``scale_bytes``-wide
      values (scale, min) per ``group_size`` weights per column — i.e.
      ``bits/8 + 2·scale_bytes/group_size`` bytes/weight; the rest stays
      INT8.  Serving benches compute the true fraction from
      ``core.ptq.count_quantized``.
    """
    if not quantized:
        return int(n_params * act_bytes)
    if weight_bits == 8:
        return int(n_params)
    if weight_bits != 4:
        raise ValueError(f"weight_bits must be 8 or 4, got {weight_bits}")
    per_w = weight_bits / 8.0 + 2.0 * scale_bytes / group_size
    return int(n_params * ((1.0 - int4_fraction) + int4_fraction * per_w))


def sharded_decode_cell(cfg, *, rows: int, tp: int, quantized: bool = True,
                        kv_bytes_per_step: int = 0, weight_bits: int = 8,
                        weight_group_size: int = 128,
                        int4_fraction: float = 1.0) -> Dict:
    """Analytic roofline for one serving decode step on a ``tp``-wide mesh.

    Unlike :func:`build_cell` (which reads dry-run records) this assembles
    the three terms from the config alone, so the serving benches can
    compare a *measured* per-step time against it on any mesh:

        compute_s    = 2·n_active_params·rows / (tp × peak)
        memory_s     = (weight_bytes/tp + kv_bytes_per_step) / HBM_bw
        collective_s = decode_collective_bytes(...) / ICI_bw

    ``weight_bits=4`` shrinks the memory term via
    :func:`weight_stream_bytes` — on the memory-bound decode roofline that
    is the predicted INT4 speedup; compute stays on the INT8 MXU peak
    because the kernel dequantizes nibbles into s8×s8 MXU dots.
    """
    n = cfg.n_active_params
    act_bytes = int(cfg.activation_dtype.itemsize)
    weight_bytes = weight_stream_bytes(
        n, quantized=quantized, act_bytes=act_bytes, weight_bits=weight_bits,
        group_size=weight_group_size, int4_fraction=int4_fraction)
    peak = PEAK_INT8 if quantized else PEAK_BF16
    coll = decode_collective_bytes(
        n_layers=cfg.n_layers, d_model=cfg.d_model, rows=rows, tp=tp,
        act_bytes=act_bytes, vocab=cfg.vocab)
    terms = {
        "compute_s": 2.0 * n * rows / (max(tp, 1) * peak),
        "memory_s": (weight_bytes / max(tp, 1) + kv_bytes_per_step) / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    return {
        "rows": rows, "tp": tp, "quantized": quantized,
        "weight_bits": weight_bits if quantized else 8 * act_bytes,
        "weight_bytes_per_step": weight_bytes,
        "collective_bytes_per_device": coll,
        "terms_s": terms,
        "dominant": dominant,
        "step_time_bound_s": max(terms.values()),
    }


def build_cell(arch: str, shape_name: str, *, quantized: bool = True,
               multi_pod: bool = False, probe_cache: Dict = None) -> Dict:
    from repro.launch.costs import probe

    shape = SHAPES[shape_name]
    mesh_tag = "2pod" if multi_pod else "1pod"
    q = "int8" if (quantized and shape.kind != "train") else \
        ("int8" if shape.kind == "train" else "bf16")
    dr = _dryrun_record(arch, shape_name, mesh_tag,
                        "int8" if shape.kind == "train" or quantized
                        else "bf16")
    if dr is None or "skipped" in (dr or {}):
        return {"arch": arch, "shape": shape_name,
                "skipped": (dr or {}).get("skipped", "no dry-run record")}

    chips = dr["n_devices"]
    key = (arch, shape_name, quantized and shape.kind != "train")
    if probe_cache is not None and key in probe_cache:
        pr = probe_cache[key]
    else:
        pr = probe(arch, shape_name,
                   quantized=quantized and shape.kind != "train")
        if probe_cache is not None:
            probe_cache[key] = pr

    flops_global = pr["flops"]
    peak = PEAK_INT8 if (quantized and shape.kind != "train") else PEAK_BF16
    compute_s = flops_global / chips / peak

    if shape.kind == "decode":
        # per-token traffic = per-device weights + cache (+ scales): exactly
        # the compiled cell's argument bytes
        mem_bytes_dev = dr["memory"]["argument_bytes"]
    else:
        mem_bytes_dev = pr["bytes"] / chips
    memory_s = mem_bytes_dev / HBM_BW

    coll_bytes_dev = dr["collectives"]["total_bytes"]
    collective_s = coll_bytes_dev / ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(arch, shape_name)

    levers = {
        "compute_s": "raise MXU utilization (larger per-chip tiles, int8 "
                     "MXU rate already engaged)" if quantized else
                     "quantize matmuls to int8 (2x MXU rate)",
        "memory_s": "shrink streamed bytes: int8 weights/KV (done), "
                    "fuse dequant into matmul epilogue (Pallas kernel), "
                    "shard cache/weights over more axes",
        "collective_s": "re-shard to cut wire bytes (bf16 gathers, "
                        "reduce-scatter grads, EP dispatch locality) and "
                        "overlap collectives with compute",
    }

    return {
        "arch": arch, "shape": shape_name, "mesh": dr["mesh"],
        "chips": chips, "quantized": quantized,
        "flops_global": flops_global,
        "mem_bytes_per_device": mem_bytes_dev,
        "collective_bytes_per_device": coll_bytes_dev,
        "terms_s": terms,
        "dominant": dominant,
        "step_time_bound_s": step_s,
        "roofline_fraction": compute_s / step_s if step_s else 0.0,
        "model_flops": mf,
        "useful_compute_ratio": mf / flops_global if flops_global else 0.0,
        "peak_memory_gib": dr["memory"]["peak_per_device_gib"],
        "lever": levers[dominant],
    }


def render_table(records) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bound | roofline frac | MODEL/HLO flops |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in records:
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skip | — | — |")
            continue
        t = r["terms_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {r['dominant'].split('_')[0]} "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['useful_compute_ratio']:.2f} |")
    return hdr + "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--table", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    if args.table:
        records = []
        for name in sorted(os.listdir(OUT_DIR)):
            with open(os.path.join(OUT_DIR, name)) as f:
                records.append(json.load(f))
        print(render_table(records))
        return

    from repro.configs import list_archs
    archs = ([args.arch] if args.arch else
             [a for a in list_archs() if a != "transformer-base"])
    cache: Dict = {}
    records = []
    for arch in archs:
        cfg = get_config(arch)
        for shape, skip in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            if skip:
                rec = {"arch": arch, "shape": shape.name, "skipped": skip}
            else:
                print(f"roofline {arch} × {shape.name} ...", flush=True)
                try:
                    rec = build_cell(arch, shape.name, probe_cache=cache)
                except Exception as e:   # pragma: no cover
                    rec = {"arch": arch, "shape": shape.name,
                           "skipped": f"probe failed: {e!r}"}
                    print("  FAILED:", e)
            records.append(rec)
            with open(os.path.join(OUT_DIR,
                                   f"{arch}__{shape.name}.json"), "w") as f:
                json.dump(rec, f, indent=2)
    print(render_table(records))


if __name__ == "__main__":
    main()
