from repro.train.loop import train_loop  # noqa: F401
from repro.train.step import make_loss_fn, make_train_step, softmax_cross_entropy  # noqa: F401
