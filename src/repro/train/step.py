"""Training step: loss, grad accumulation, mixed precision, remat.

``make_train_step`` builds the jit-able step for any model in the zoo:

    step = make_train_step(model, optimizer, accum_steps=4)
    (params, opt_state), metrics = step(params, opt_state, batch)

Gradient accumulation runs as a ``lax.scan`` over microbatches (keeps the
HLO compact), with grads in f32.  Params stay f32; activations run in the
config's dtype (bf16 on TPU).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.data.synthetic import PAD
from repro.distributed.context import constrain, constrain_logits
from repro.optim.adamw import AdamW, AdamWState, global_norm


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array) -> jax.Array:
    """Mean CE over mask; logits f32 (B, S, V); labels (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)


def _lm_loss(model, params, batch, quant) -> Tuple[jax.Array, Dict]:
    logits, aux = model.forward(params, batch, quant=quant)
    # (B, S, V@model): vocab-shard the f32 logits so the CE pass never
    # materializes an unsharded (B, S, V) tensor (33 GiB/device at 128k vocab)
    logits = constrain_logits(logits)
    if "labels" in batch:
        labels = batch["labels"]
        mask = (labels != PAD).astype(jnp.float32)
    else:
        # enc-dec teacher forcing: predict tgt[t+1].  Shift the *labels*
        # (small) rather than slicing the logits — slicing the seq-sharded
        # (B, S@model, V) tensor forces an all-gather of the full logits.
        labels = jnp.pad(batch["tgt_tokens"][:, 1:], ((0, 0), (0, 1)))
        mask = (labels != PAD).astype(jnp.float32)
    loss = softmax_cross_entropy(logits, labels, mask)
    lb = aux.get("load_balance_loss", jnp.float32(0.0))
    total = loss + 0.01 * lb
    return total, {"ce_loss": loss, "load_balance_loss": lb}


def make_loss_fn(model, quant=None) -> Callable:
    from repro.core.ptq import FP_CONTEXT
    quant = quant or FP_CONTEXT

    def loss_fn(params, batch):
        return _lm_loss(model, params, batch, quant)

    return loss_fn


def make_train_step(model, optimizer: AdamW, *, accum_steps: int = 1,
                    quant=None, grad_shardings=None,
                    mixed_precision: bool = False) -> Callable:
    """``grad_shardings``: optional tree of NamedSharding matching params.
    Constraining gradients to the FSDP parameter layout makes XLA emit
    reduce-scatters for the weight-grad reductions instead of full
    all-reduce + slice (≈2× wire traffic; see EXPERIMENTS.md §Perf).

    ``mixed_precision``: compute with bf16 weight copies (f32 master stays
    in the optimizer path) — halves the FSDP all-gather and grad-reduce
    wire bytes (§Perf iteration B2)."""
    base_loss = make_loss_fn(model, quant)
    if mixed_precision:
        def loss_fn(params, batch):
            cast = jax.tree_util.tree_map(
                lambda a: a.astype(jnp.bfloat16)
                if (hasattr(a, "dtype") and a.dtype == jnp.float32
                    and getattr(a, "ndim", 0) >= 2) else a, params)
            return base_loss(cast, batch)
    else:
        loss_fn = base_loss
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_shardings)

    def train_step(params, opt_state: AdamWState, batch
                   ) -> Tuple[Tuple[Any, AdamWState], Dict[str, jax.Array]]:
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda a: a.reshape(accum_steps, a.shape[0] // accum_steps,
                                    *a.shape[1:]), batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g = constrain_grads(g)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(acc, (g0, jnp.float32(0.0)),
                                             micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = jax.tree_util.tree_map(jnp.mean, ms)

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = global_norm(grads)
        metrics["lr"] = optimizer._lr(new_opt.step)
        return (new_params, new_opt), metrics

    return train_step
