"""Checkpointed, watchdogged training loop (fault-tolerant driver).

Restores from the latest checkpoint on entry (so ``run_with_restarts`` can
re-invoke it after a failure), saves every ``save_every`` steps including
the data-iterator state, and tracks per-step wall-clock for straggler
accounting.
"""

from __future__ import annotations

import logging
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.distributed.fault import StepWatchdog

log = logging.getLogger("repro.train")


def train_loop(
    *,
    train_step: Callable,
    params: Any,
    opt_state: Any,
    batches,                        # object with next_batch()/state_dict()
    steps: int,
    checkpointer: Optional[Checkpointer] = None,
    save_every: int = 100,
    log_every: int = 10,
    watchdog: Optional[StepWatchdog] = None,
    metrics_cb: Optional[Callable[[int, Dict], None]] = None,
) -> Dict[str, Any]:
    start = 0
    if checkpointer is not None and checkpointer.latest_step() is not None:
        meta = checkpointer.read_meta()
        start = int(meta["step"])
        state = checkpointer.restore((params, opt_state))
        params, opt_state = state
        if "data_state" in meta.get("extra", {}):
            batches.load_state_dict(meta["extra"]["data_state"])
        log.info("restored checkpoint at step %d", start)

    watchdog = watchdog or StepWatchdog()
    history = []
    for step in range(start, steps):
        batch = batches.next_batch()
        batch = jax.tree_util.tree_map(jax.numpy.asarray, batch)
        watchdog.start()
        (params, opt_state), metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        watchdog.stop()

        if (step + 1) % log_every == 0 or step == start:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            history.append({"step": step + 1, **m})
            log.info("step %d: %s", step + 1,
                     {k: round(v, 4) for k, v in m.items()})
            if metrics_cb is not None:
                metrics_cb(step + 1, m)

        if checkpointer is not None and (step + 1) % save_every == 0:
            checkpointer.save(step + 1, (params, opt_state),
                              extra={"data_state": batches.state_dict()})

    if checkpointer is not None:
        checkpointer.save(steps, (params, opt_state),
                          extra={"data_state": batches.state_dict()})
        checkpointer.wait()
    return {"params": params, "opt_state": opt_state,
            "history": history, "watchdog": watchdog.summary()}
