from repro.distributed.compat import make_mesh, shard_map  # noqa: F401
from repro.distributed.compression import (  # noqa: F401
    ef_compressed_mean,
    init_error_state,
    tree_ef_compressed_mean,
    wire_bytes_fp32_allreduce,
    wire_bytes_int8_gather,
)
from repro.distributed.fault import StepWatchdog, run_with_restarts  # noqa: F401
from repro.distributed.sharding import (  # noqa: F401
    abstract_with_sharding,
    batch_specs,
    named_shardings,
    param_specs,
)
