"""Activation-sharding context.

The launcher sets a PartitionSpec for inter-block activations (e.g.
``P(("pod","data"), "model", None)`` = batch-DP + sequence parallelism over
the tensor axis); models call :func:`constrain` on the residual stream at
every block boundary.  Outside a mesh/launcher context this is a no-op, so
model code never depends on distribution state.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec

_state = threading.local()


def current_spec() -> Optional[PartitionSpec]:
    return getattr(_state, "spec", None)


@contextlib.contextmanager
def activation_sharding(spec: Optional[PartitionSpec]):
    prev = current_spec()
    _state.spec = spec
    try:
        yield
    finally:
        _state.spec = prev


def constrain(x: jax.Array) -> jax.Array:
    """Apply the context's activation sharding to a (B, S, D) tensor."""
    spec = current_spec()
    if spec is None:
        return x
    if x.ndim != len(spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@contextlib.contextmanager
def block_grad_specs(specs):
    """Per-block parameter PartitionSpec tree (leading layer dim dropped).

    When set, models tag each scanned block's params with a custom_vjp that
    constrains the incoming weight gradients to the FSDP layout *inside*
    the backward loop — turning XLA's full all-reduce + slice of every
    layer's dW into reduce-scatters (≈2× wire; §Perf iteration B3)."""
    prev = getattr(_state, "block_specs", None)
    _state.block_specs = specs
    try:
        yield
    finally:
        _state.block_specs = prev


def current_block_specs():
    return getattr(_state, "block_specs", None)


def _tag_fwd(params):
    return params, None


def _tag_bwd(specs, _, g):
    if specs is not None:
        def apply(gg, s):
            try:
                return jax.lax.with_sharding_constraint(gg, s)
            except Exception:
                return gg
        g = jax.tree_util.tree_map(apply, g, specs)
    return (g,)


@contextlib.contextmanager
def _noop():
    yield


def tag_block_grads(params):
    specs = current_block_specs()
    if specs is None:
        return params

    @jax.custom_vjp
    def tag(p):
        return p

    tag.defvjp(_tag_fwd,
               lambda res, g: _tag_bwd(specs, res, g))
    return tag(params)


def constrain_logits(x: jax.Array) -> jax.Array:
    """Vocab-shard (B, S, V) logits on the tensor axis: the CE pass works on
    V-sharded f32 tensors and the unembed never gathers the full table."""
    spec = current_spec()
    if spec is None or x.ndim != 3 or len(spec) < 2:
        return x
    return jax.lax.with_sharding_constraint(
        x, PartitionSpec(spec[0], None, spec[1]))
