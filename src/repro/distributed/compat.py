"""Version-compat wrappers for jax sharding APIs.

The repo targets current jax, but the hermetic containers pin older
releases (0.4.x) where ``jax.shard_map`` still lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``) and ``jax.make_mesh`` has no ``axis_types``/
``jax.sharding.AxisType``.  Everything that touches those APIs goes
through here so the rest of the codebase can be written against the
modern surface.
"""

from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None, explicit=False):
    """``jax.make_mesh`` with ``axis_types`` when the install supports it."""
    kw = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kind = axis_type.Explicit if explicit else axis_type.Auto
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(kind,) * len(axis_names), **kw)
        except TypeError:  # make_mesh predates axis_types
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across the 0.4 → 0.7 API renames."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check)
        except TypeError:  # pre-rename: check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
