"""Fault tolerance & straggler mitigation plumbing.

On a real pod this framework relies on three layers (all exercised here at
single-host scale, the mechanisms being host-count independent):

1. **Checkpoint/restart** — atomic checkpoints + exact data-iterator state
   (``checkpoint/``); the train loop restores and continues on any failure.
2. **Step watchdog** — per-step wall-clock tracking; a step slower than
   ``threshold × rolling_median`` flags a straggler (on multi-host: the flag
   feeds the scheduler's drain-and-replace flow; here: logged + counted).
3. **Retry wrapper** — transient failures (preemption, OOM-retry) re-enter
   from the last checkpoint with bounded attempts.
"""

from __future__ import annotations

import logging
import time
from typing import Callable, List, Optional

log = logging.getLogger("repro.fault")


class StepWatchdog:
    def __init__(self, threshold: float = 2.5, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.durations: List[float] = []
        self.straggler_steps: List[int] = []
        self._t0: Optional[float] = None
        self.step = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record the step; returns True if it was a straggler."""
        if self._t0 is None:
            # raised, not asserted: the pairing invariant must hold under
            # ``python -O`` too (same convention as the PageAllocator)
            raise RuntimeError("watchdog.stop() without start()")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        """Record a step of ``dt`` seconds against the rolling median;
        returns True if it was a straggler.  Split out of :meth:`stop` so
        fault injectors (serving chaos harness) can feed synthetic slow
        rounds without faking wall clocks."""
        self.step += 1
        hist = self.durations[-self.window:]
        self.durations.append(dt)
        if len(hist) >= 5:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                self.straggler_steps.append(self.step)
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            self.step, dt, med)
                return True
        return False

    def summary(self) -> dict:
        if not self.durations:
            return {"steps": 0}
        d = sorted(self.durations)
        return {
            "steps": len(d),
            "median_s": d[len(d) // 2],
            "p95_s": d[int(len(d) * 0.95)],
            "stragglers": len(self.straggler_steps),
        }


def run_with_restarts(
    fn: Callable[[], None],
    *,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
    retry_on: tuple = (RuntimeError, OSError),
) -> None:
    """Run ``fn`` (a restartable training loop that restores from its own
    checkpoints) retrying on transient failures."""
    attempt = 0
    while True:
        try:
            fn()
            return
        except retry_on as e:  # pragma: no cover - exercised in tests
            attempt += 1
            if attempt > max_restarts:
                raise
            log.warning("restart %d/%d after %r", attempt, max_restarts, e)
            if on_restart is not None:
                on_restart(attempt, e)
