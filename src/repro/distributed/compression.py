"""INT8 gradient compression with error feedback (beyond-paper distributed
trick, same spirit as the paper's INT8 insight applied to the wire).

All-reduce is realized as *all-gather of int8 shards + local int32
reduction*: the bytes on the ICI links are 1/4 of an fp32 ring all-reduce
(1/2 of bf16).  Error feedback keeps the quantization noise unbiased across
steps (Karimireddy et al., 2019): the residual of each local compression is
added to the next step's gradient before compressing.

Used by ``train/step.py``'s ``dp_compressed`` mode inside ``shard_map`` over
the data axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def compress(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX
                    ).astype(jnp.int8)


def ef_compressed_mean(
    g: jax.Array,
    err: jax.Array,
    axis_name: str,
    n_shards: int,
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 mean-all-reduce of one gradient leaf.

    Must run inside shard_map/pmap with ``axis_name`` bound.
    Returns (mean gradient f32, new error-feedback state).
    """
    c = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(c))
    amax = jax.lax.pmax(amax, axis_name)                  # shared scale
    scale = jnp.maximum(amax, 1e-12) / INT8_MAX
    q = compress(c, scale)                                # int8 on the wire
    local_dq = q.astype(jnp.float32) * scale
    new_err = c - local_dq                                # residual memory
    total = jax.lax.all_gather(q, axis_name).astype(jnp.int32)
    mean = jnp.sum(total, axis=0).astype(jnp.float32) * scale / n_shards
    return mean, new_err


def tree_ef_compressed_mean(grads: Any, err_state: Any, axis_name: str,
                            n_shards: int) -> Tuple[Any, Any]:
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = ef_compressed_mean(g, e, axis_name, n_shards)
        out_g.append(mg)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_state(grads_abstract: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_abstract)


def wire_bytes_fp32_allreduce(n_params: int, n_shards: int) -> int:
    """Ring all-reduce: 2·(n-1)/n · N · 4 bytes."""
    return int(2 * (n_shards - 1) / n_shards * n_params * 4)


def wire_bytes_int8_gather(n_params: int, n_shards: int) -> int:
    """All-gather of int8: (n-1)/n · N · 1 byte (each shard sends its copy)."""
    return int((n_shards - 1) / n_shards * n_params * 1)
