"""Parameter/activation sharding rules: param-tree paths → PartitionSpec.

Layout (production mesh, DESIGN §4):

* ``tensor`` axis = "model": output features of in-projections, input
  features of out-projections, vocab, experts, attention heads.
* ``fsdp`` axes = "data" (+ "pod" for training): the other weight dim —
  ZeRO-3 parameter/optimizer/grad sharding.  For inference the fsdp axes
  are dropped (weights resident, batch data-parallel).

Divisibility guard: an axis is applied only when the dim divides evenly
(e.g. granite-moe's vocab 49155 and whisper's 51865 don't split 16 ways →
replicated there; noted per-arch in EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.qtensor import BlockQTensor, QTensor

IN_PROJ = {"q_proj", "k_proj", "v_proj", "gate", "up", "in", "in_proj",
           "up_proj", "gate_ssm_if"}
OUT_PROJ = {"o_proj", "down", "out", "out_proj", "down_proj"}
ROUTER = {"router"}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(dim: int, axes, mesh: Mesh):
    """Return ``axes`` if ``dim`` divides the axis product, else None."""
    if axes is None:
        return None
    return axes if dim % _axis_size(mesh, axes) == 0 else None


def _base_spec(node_name: str, path: Tuple[str, ...], leaf_name: str,
               shape: Tuple[int, ...], mesh: Mesh, tensor, fsdp,
               kv_heads: int = 0) -> P:
    """Spec for one leaf of a linear/embedding node."""
    is_expert = "experts" in path
    rank = len(shape)

    # GQA: when kv heads don't divide the tensor axis, sharding the flat
    # (HKV·dh) projection splits heads across shards and every attention
    # einsum reshards — replicate the (small) K/V projections instead.
    if node_name in ("k_proj", "v_proj") and tensor is not None and \
            kv_heads and kv_heads % _axis_size(mesh, tensor) != 0:
        tensor = None

    if leaf_name == "table":                       # embedding (V, D)
        if tensor is not None:
            # vocab-parallel only: sharding D over fsdp as well makes the
            # unembed contraction gather the full f32 table (B@data vs
            # D@data conflict).  V/16 per device is already ZeRO-enough.
            return P(_fit(shape[0], tensor, mesh), None)
        return P(_fit(shape[0], fsdp, mesh), None)

    if node_name in ROUTER:
        core = 2
        if leaf_name == "b":
            return P(*([None] * rank))
        specs = [_fit(shape[-2], fsdp, mesh), None]
    elif node_name in IN_PROJ:
        core = 2
        if leaf_name == "b":
            return P(*([None] * (rank - 1)), _fit(shape[-1], tensor, mesh))
        specs = [_fit(shape[-2], fsdp, mesh), _fit(shape[-1], tensor, mesh)]
    elif node_name in OUT_PROJ:
        core = 2
        if leaf_name == "b":
            return P(*([None] * rank))
        specs = [_fit(shape[-2], tensor, mesh), _fit(shape[-1], fsdp, mesh)]
    else:
        return P(*([None] * rank))

    lead_rank = rank - core
    lead: list = [None] * lead_rank
    if is_expert and lead_rank >= 1:
        # trailing stack dim right before the core dims is the expert dim;
        # expert parallelism claims the tensor axis, so the feature dims
        # must not reuse it (a spec may name each mesh axis once).
        e_fit = _fit(shape[lead_rank - 1], tensor, mesh)
        lead[-1] = e_fit
        if e_fit is not None:
            specs = [None if s == tensor else s for s in specs]
    return P(*lead, *specs)


def _qtensor_scale_spec(w_spec: P, scale_shape) -> P:
    """Scale has the weight's shape with the contraction dim = 1."""
    parts = list(w_spec) + [None] * (len(scale_shape) - len(w_spec))
    parts = parts[:len(scale_shape)]
    out = [None if scale_shape[i] == 1 else parts[i]
           for i in range(len(scale_shape))]
    return P(*out)


def param_specs(params: Any, mesh: Mesh, *, tensor="model",
                fsdp: Optional[Any] = "data", kv_heads: int = 0) -> Any:
    """Tree of PartitionSpec matching ``params`` (works on abstract trees)."""

    def walk(node, path: Tuple[str, ...]):
        if isinstance(node, BlockQTensor):
            # INT4 block layout: packed nibble rows and scale/min group rows
            # both live on the reduction axis — splitting them would cut
            # nibble pairs / scale blocks across shards, so the row dim
            # replicates (the GQA-fallback precedent) and only the output
            # column dim shards, following the weight spec's last entry.
            node_name = path[-2] if len(path) >= 2 and path[-1] == "w" \
                else (path[-1] if path else "")
            w_spec = _base_spec(node_name, path, "w", node.data.shape, mesh,
                                tensor, fsdp, kv_heads)
            col = list(w_spec)[-1] if len(w_spec) else None
            col = _fit(node.data.shape[-1], col, mesh)
            rank = node.data.ndim
            col_spec = P(*([None] * (rank - 1)), col)
            return BlockQTensor(data=col_spec, scale=col_spec, vmin=col_spec,
                                group_size=node.group_size, k_dim=node.k_dim)
        if isinstance(node, QTensor):
            # path ends with the leaf key ("w"); the linear's name is above it
            node_name = path[-2] if len(path) >= 2 and path[-1] == "w" \
                else (path[-1] if path else "")
            w_spec = _base_spec(node_name, path, "w", node.data.shape, mesh,
                                tensor, fsdp, kv_heads)
            return QTensor(
                data=w_spec,
                scale=_qtensor_scale_spec(w_spec, node.scale.shape),
                zero_point=P(*([None] * getattr(node.zero_point, "ndim", 0))),
                axis=node.axis,
            )
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("w", "b", "table", "scale", "bias") and not \
                        isinstance(v, (dict, QTensor, BlockQTensor)):
                    node_name = path[-1] if path else ""
                    if k in ("scale", "bias") and node_name not in IN_PROJ \
                            and node_name not in OUT_PROJ:
                        out[k] = P(*([None] * v.ndim))       # norm params
                    else:
                        out[k] = _base_spec(node_name, path, k, v.shape,
                                            mesh, tensor, fsdp, kv_heads)
                elif isinstance(v, (dict, QTensor, BlockQTensor)):
                    out[k] = walk(v, path + (k,))
                else:
                    # bare array leaf (conv weights, A_log, r_weight, …)
                    out[k] = _leaf_spec(k, v, mesh, tensor)
            return out
        return node

    def _leaf_spec(name: str, v, mesh, tensor) -> P:
        shape = v.shape
        if name == "conv_w" and len(shape) >= 2:
            return P(*([None] * (len(shape) - 1)),
                     _fit(shape[-1], tensor, mesh))
        if name == "conv_b":
            return P(*([None] * (len(shape) - 1)),
                     _fit(shape[-1], tensor, mesh))
        if name == "r_weight" and len(shape) >= 3:
            return P(*([None] * (len(shape) - 3)),
                     _fit(shape[-3], tensor, mesh), None, None)
        return P(*([None] * len(shape)))

    return walk(params, ())


def named_shardings(params: Any, mesh: Mesh, **kw) -> Any:
    specs = param_specs(params, mesh, **kw)
    to_ns = lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s

    def walk(node):
        if isinstance(node, QTensor):
            return QTensor(data=to_ns(node.data), scale=to_ns(node.scale),
                           zero_point=to_ns(node.zero_point), axis=node.axis)
        if isinstance(node, BlockQTensor):
            return BlockQTensor(data=to_ns(node.data), scale=to_ns(node.scale),
                                vmin=to_ns(node.vmin),
                                group_size=node.group_size, k_dim=node.k_dim)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return to_ns(node)

    return walk(specs)


def abstract_with_sharding(abstract: Any, shardings: Any) -> Any:
    """Attach shardings onto a ShapeDtypeStruct tree (for jit.lower)."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_specs(batch: Any, mesh: Mesh, batch_axes) -> Any:
    """Shard the leading (batch) dim of every batch leaf over ``batch_axes``."""
    def spec(a):
        first = _fit(a.shape[0], batch_axes, mesh) if a.ndim >= 1 else None
        return NamedSharding(mesh, P(first, *([None] * (a.ndim - 1))))
    return jax.tree_util.tree_map(spec, batch)
