"""Synthetic translation corpus (laptop-scale stand-in for WMT En→De).

The paper evaluates on newstest2014 (3003 sentences).  We generate a
deterministic "translation" task a transformer-base-family model can learn
in a few hundred steps, so the Table-1 accuracy experiments (BLEU drop per
quantization mode) are reproducible end-to-end on CPU:

* source sentences are sequences of *words*; each word is 1–3 subword
  *tokens* (so word-count and token-count sorting — paper §5.4 — genuinely
  differ; words are metadata only);
* the target maps every source token through a fixed affine permutation of
  the vocabulary (order preserved) — a deterministic cross-attention
  copy+substitute task a small model learns in a few hundred steps, so the
  Table-1 BLEU-drop experiments run end-to-end on CPU.

Special tokens: PAD=0, BOS=1, EOS=2; content ids start at 3.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2
SPECIALS = 3


@dataclasses.dataclass(frozen=True)
class Sentence:
    src: np.ndarray            # (S,) int32 source tokens (no BOS/EOS)
    tgt: np.ndarray            # (T,) int32 target tokens
    n_words: int

    @property
    def n_tokens(self) -> int:
        return int(len(self.src))


def _map_token(tok: np.ndarray, vocab: int) -> np.ndarray:
    content = vocab - SPECIALS
    return (tok - SPECIALS) * 7 % content + SPECIALS  # 7 coprime w/ content


def make_corpus(
    n_sentences: int,
    vocab: int,
    *,
    min_words: int = 2,
    max_words: int = 24,
    seed: int = 0,
) -> List[Sentence]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_sentences):
        n_words = int(rng.integers(min_words, max_words + 1))
        words = []
        for _ in range(n_words):
            w_len = int(rng.integers(1, 4))
            words.append(rng.integers(SPECIALS, vocab, size=w_len,
                                      dtype=np.int64))
        src = np.concatenate(words).astype(np.int32)
        tgt = _map_token(src, vocab).astype(np.int32)
        out.append(Sentence(src=src, tgt=tgt, n_words=n_words))
    return out


def reference_translation(src: np.ndarray, vocab: int) -> np.ndarray:
    return _map_token(np.asarray(src), vocab).astype(np.int32)


def pad_batch(seqs: List[np.ndarray], *, add_bos: bool = False,
              add_eos: bool = False, length: int | None = None
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Right-pad to the batch max (or ``length``). Returns (tokens, lengths)."""
    extra = int(add_bos) + int(add_eos)
    lens = np.asarray([len(s) + extra for s in seqs], np.int32)
    L = int(length if length is not None else lens.max())
    out = np.full((len(seqs), L), PAD, np.int32)
    for i, s in enumerate(seqs):
        row = list(s)
        if add_bos:
            row = [BOS] + row
        if add_eos:
            row = row + [EOS]
        out[i, :len(row)] = row
    return out, lens
