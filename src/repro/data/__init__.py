"""Data substrate: synthetic corpus, ordering (paper §5.4), pipeline, BLEU."""

from repro.data.metrics import corpus_bleu  # noqa: F401
from repro.data.pipeline import LMBatches, Prefetcher, TranslationBatches  # noqa: F401
from repro.data.sorting import (  # noqa: F401
    make_batches,
    next_pow2,
    order_indices,
    pack_batches_token_budget,
    padding_stats,
)
from repro.data.synthetic import (  # noqa: F401
    BOS,
    EOS,
    PAD,
    Sentence,
    make_corpus,
    pad_batch,
)
