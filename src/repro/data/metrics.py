"""Corpus BLEU (the paper's accuracy metric, Table 1)."""

from __future__ import annotations

import math
from collections import Counter
from typing import List, Sequence

import numpy as np


def _ngrams(seq: Sequence[int], n: int) -> Counter:
    return Counter(tuple(seq[i:i + n]) for i in range(len(seq) - n + 1))


def corpus_bleu(hypotheses: List[Sequence[int]],
                references: List[Sequence[int]], max_n: int = 4) -> float:
    """Standard corpus BLEU-4 with brevity penalty, on token ids."""
    assert len(hypotheses) == len(references)
    clipped = [0] * max_n
    totals = [0] * max_n
    hyp_len = ref_len = 0
    for hyp, ref in zip(hypotheses, references):
        hyp, ref = list(hyp), list(ref)
        hyp_len += len(hyp)
        ref_len += len(ref)
        for n in range(1, max_n + 1):
            h = _ngrams(hyp, n)
            r = _ngrams(ref, n)
            totals[n - 1] += max(len(hyp) - n + 1, 0)
            clipped[n - 1] += sum(min(c, r[g]) for g, c in h.items())
    if min(totals) == 0 or min(clipped) == 0:
        return 0.0
    log_p = sum(math.log(clipped[i] / totals[i]) for i in range(max_n)) / max_n
    bp = 1.0 if hyp_len > ref_len else math.exp(1.0 - ref_len / max(hyp_len, 1))
    return 100.0 * bp * math.exp(log_p)
