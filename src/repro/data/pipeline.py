"""Training/serving data pipeline: batching, padding, background prefetch,
and checkpointable iterator state (exact restart — fault tolerance).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.data.sorting import make_batches
from repro.data.synthetic import Sentence, pad_batch


class TranslationBatches:
    """Deterministic, resumable batch stream over a sentence corpus.

    State = (epoch, cursor); serializes into the training checkpoint so a
    restarted job continues on the exact next batch.
    """

    def __init__(self, sentences: Sequence[Sentence], batch_size: int,
                 *, sort_mode: str = "tokens", seed: int = 0,
                 pad_to_multiple: int = 8):
        self.sentences = list(sentences)
        self.batch_size = batch_size
        self.sort_mode = sort_mode
        self.seed = seed
        self.pad_to_multiple = pad_to_multiple
        self.epoch = 0
        self.cursor = 0
        self._plan: List[List[int]] = []
        self._replan()

    def _replan(self) -> None:
        rng = np.random.default_rng(self.seed + self.epoch)
        order = rng.permutation(len(self.sentences))
        shuffled = [self.sentences[i] for i in order]
        batches = make_batches(shuffled, self.batch_size, self.sort_mode)
        self._plan = [[int(order[j]) for j in b] for b in batches]

    # -- checkpointable state --------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "cursor": self.cursor,
                "seed": self.seed}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.seed = int(state["seed"])
        self.epoch = int(state["epoch"])
        self.cursor = int(state["cursor"])
        self._replan()

    # -- iteration ----------------------------------------------------------
    def _round(self, n: int) -> int:
        m = self.pad_to_multiple
        return ((n + m - 1) // m) * m

    def next_batch(self) -> Dict[str, np.ndarray]:
        if self.cursor >= len(self._plan):
            self.epoch += 1
            self.cursor = 0
            self._replan()
        idx = self._plan[self.cursor]
        self.cursor += 1
        sents = [self.sentences[i] for i in idx]
        src_len = self._round(max(s.n_tokens for s in sents))
        tgt_len = self._round(max(len(s.tgt) for s in sents) + 2)
        src, src_lens = pad_batch([s.src for s in sents], length=src_len)
        tgt, tgt_lens = pad_batch([s.tgt for s in sents], add_bos=True,
                                  add_eos=True, length=tgt_len)
        return {
            "src_tokens": src, "src_lengths": src_lens,
            "tgt_tokens": tgt, "tgt_lengths": tgt_lens,
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


class LMBatches:
    """Next-token-prediction stream for decoder-only archs (smoke training)."""

    def __init__(self, vocab: int, batch_size: int, seq_len: int,
                 seed: int = 0):
        self.vocab, self.B, self.S = vocab, batch_size, seq_len
        self.seed = seed
        self.step = 0

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, s):
        self.seed, self.step = int(s["seed"]), int(s["step"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 1_000_003 + self.step)
        self.step += 1
        # a learnable sequence task: tokens follow a noisy affine recurrence
        x = np.zeros((self.B, self.S + 1), np.int32)
        x[:, 0] = rng.integers(3, self.vocab, self.B)
        noise = rng.random((self.B, self.S)) < 0.1
        nxt = rng.integers(3, self.vocab, (self.B, self.S))
        for t in range(self.S):
            det = (x[:, t] * 5 + 7) % (self.vocab - 3) + 3
            x[:, t + 1] = np.where(noise[:, t], nxt[:, t], det)
        return {"tokens": x[:, :-1], "labels": x[:, 1:]}

    def __iter__(self):
        while True:
            yield self.next_batch()


class Prefetcher:
    """Background-thread prefetch so input never stalls the step (one of the
    straggler-mitigation pieces: host input jitter is hidden)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
