"""Input-pipeline sentence ordering + bin packing (paper §5.4–§5.6).

The paper: batching unsorted variable-length sentences wastes compute on pad
tokens; sorting by **token** count beats sorting by **word** count by 28%
throughput.  This module implements all three orders, the padding-waste
accounting that ``benchmarks/bench_batching.py`` reports, and the
**token-budget bin-packer** behind the continuous batching engine
(``serving/engine.py``): instead of a fixed row count, batches are packed
first-fit-decreasing so every bin's *padded token grid* (rows × padded
length) stays under a budget — short sentences pack many-to-a-bin, long
ones few-to-a-bin, and the per-step compute cost of every bin is roughly
equal, which is what keeps the parallel streams saturated.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.data.synthetic import Sentence


def next_pow2(n: int) -> int:
    """Smallest power of two ≥ ``n`` (``n ≤ 1`` → 1).

    Shared bucketing helper: the serving engine pads prefill side-batches
    to power-of-two widths and buckets decode-burst lengths to power-of-two
    compiled widths, so the number of distinct XLA programs stays
    O(log n) regardless of the request mix.
    """
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def order_indices(sentences: Sequence[Sentence], mode: str) -> np.ndarray:
    """mode: 'none' | 'words' | 'tokens' (descending, stable)."""
    n = len(sentences)
    if mode == "none":
        return np.arange(n)
    if mode == "words":
        keys = np.asarray([s.n_words for s in sentences])
    elif mode == "tokens":
        keys = np.asarray([s.n_tokens for s in sentences])
    else:
        raise ValueError(f"unknown sort mode {mode}")
    return np.argsort(-keys, kind="stable")


def make_batches(sentences: Sequence[Sentence], batch_size: int,
                 mode: str = "tokens") -> List[List[int]]:
    """Greedy fixed-size batches over the chosen ordering."""
    idx = order_indices(sentences, mode)
    return [list(idx[i:i + batch_size])
            for i in range(0, len(idx), batch_size)]


def pack_batches_token_budget(
    sentences: Sequence[Sentence],
    token_budget: int,
    *,
    max_rows: int | None = None,
) -> List[List[int]]:
    """First-fit-decreasing bin packing to a padded-token budget.

    A bin holding rows of token lengths ``lens`` costs
    ``max(lens) * len(lens)`` padded tokens (the grid the hardware actually
    computes).  Sentences are placed longest-first into the first bin whose
    grid stays ≤ ``token_budget`` (and, optionally, whose row count stays
    ≤ ``max_rows``).  Because placement is in decreasing length order, a
    bin's padded length is fixed by its first element, so adding a row
    never re-inflates earlier decisions.

    A sentence longer than the whole budget still gets its own bin (it has
    to run *somewhere*); every index appears in exactly one bin.
    """
    if token_budget <= 0:
        raise ValueError(f"token_budget must be positive, got {token_budget}")
    order = order_indices(sentences, "tokens")
    bins: List[List[int]] = []
    bin_max: List[int] = []
    for i in order:
        t = sentences[i].n_tokens
        for b in range(len(bins)):
            mx = max(bin_max[b], t)
            if mx * (len(bins[b]) + 1) <= token_budget and (
                    max_rows is None or len(bins[b]) < max_rows):
                bins[b].append(int(i))
                bin_max[b] = mx
                break
        else:
            bins.append([int(i)])
            bin_max.append(t)
    return bins


def padding_stats(sentences: Sequence[Sentence],
                  batches: List[List[int]]) -> dict:
    """Fraction of the padded token grid wasted on PAD (lower = better)."""
    total_padded = 0
    total_real = 0
    per_batch_max = []
    for b in batches:
        lens = [sentences[i].n_tokens for i in b]
        mx = max(lens)
        per_batch_max.append(mx)
        total_padded += mx * len(b)
        total_real += sum(lens)
    waste = 1.0 - total_real / max(total_padded, 1)
    return {
        "padded_tokens": total_padded,
        "real_tokens": total_real,
        "pad_waste": waste,
        "mean_batch_len": float(np.mean(per_batch_max)),
    }
