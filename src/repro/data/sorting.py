"""Input-pipeline sentence ordering (paper §5.4).

The paper: batching unsorted variable-length sentences wastes compute on pad
tokens; sorting by **token** count beats sorting by **word** count by 28%
throughput.  This module implements all three orders and the padding-waste
accounting that ``benchmarks/bench_batching.py`` reports.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.data.synthetic import Sentence


def order_indices(sentences: Sequence[Sentence], mode: str) -> np.ndarray:
    """mode: 'none' | 'words' | 'tokens' (descending, stable)."""
    n = len(sentences)
    if mode == "none":
        return np.arange(n)
    if mode == "words":
        keys = np.asarray([s.n_words for s in sentences])
    elif mode == "tokens":
        keys = np.asarray([s.n_tokens for s in sentences])
    else:
        raise ValueError(f"unknown sort mode {mode}")
    return np.argsort(-keys, kind="stable")


def make_batches(sentences: Sequence[Sentence], batch_size: int,
                 mode: str = "tokens") -> List[List[int]]:
    """Greedy fixed-size batches over the chosen ordering."""
    idx = order_indices(sentences, mode)
    return [list(idx[i:i + batch_size])
            for i in range(0, len(idx), batch_size)]


def padding_stats(sentences: Sequence[Sentence],
                  batches: List[List[int]]) -> dict:
    """Fraction of the padded token grid wasted on PAD (lower = better)."""
    total_padded = 0
    total_real = 0
    per_batch_max = []
    for b in batches:
        lens = [sentences[i].n_tokens for i in b]
        mx = max(lens)
        per_batch_max.append(mx)
        total_padded += mx * len(b)
        total_real += sum(lens)
    waste = 1.0 - total_real / max(total_padded, 1)
    return {
        "padded_tokens": total_padded,
        "real_tokens": total_real,
        "pad_waste": waste,
        "mean_batch_len": float(np.mean(per_batch_max)),
    }
