"""Config dataclasses + the architecture/shape registry.

Every assigned architecture registers a :class:`ModelConfig` here (one file
per arch under ``repro/configs/``), selectable via ``--arch <id>`` in the
launchers.  Shapes are the four assigned input-shape cells; per-arch
applicability (e.g. ``long_500k`` only for sub-quadratic families) is
encoded in :func:`shapes_for`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 1024          # GShard-style dispatch group


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 64                 # N — SSM state size
    conv_width: int = 4
    expand: int = 2                 # d_inner = expand * d_model
    head_dim: int = 64              # P — channels per SSM head
    chunk: int = 256                # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6             # shared attention block cadence (zamba2)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8            # 1-in-8 blocks are sLSTM (xLSTM [7:1])
    chunk: int = 256                # mLSTM chunked-parallel length


@dataclasses.dataclass(frozen=True)
class QuantSettings:
    """Arch-level defaults for the paper's technique (overridable via CLI)."""

    mode: str = "symmetric"         # none|naive|symmetric|independent|conjugate
    act_quant: str = "dynamic"      # static (calibrated) | dynamic
    quantize_kv_cache: bool = True


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    ffn: str = "swiglu"             # swiglu | gelu | none
    rope_theta: float = 10000.0
    max_seq: int = 32768
    tie_embeddings: bool = False
    attn_bias: bool = False
    logits_softcap: Optional[float] = None

    # family-specific
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    input_kind: str = "tokens"      # tokens | embeddings (vlm/audio stubs)

    # execution
    scan_layers: bool = True
    remat: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    quant: QuantSettings = QuantSettings()

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm"):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
            if self.moe:
                ffn = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
            else:
                ffn = 3 * d * self.d_ff if self.ffn == "swiglu" else 2 * d * self.d_ff
            per_layer = attn + ffn
        elif self.family == "ssm":  # xlstm
            d_in = d * 2
            per_layer = d * d_in * 4 + d_in * d  # qkv+gates up/down approx
        elif self.family == "hybrid":
            s = self.ssm or SSMConfig()
            d_inner = s.expand * d
            mamba = d * (2 * d_inner + 2 * s.state + d_inner // s.head_dim) + d_inner * d
            n_attn = self.n_layers // (self.hybrid.attn_every if self.hybrid else 6)
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
            ffn = 2 * d * self.d_ff
            per_layer = mamba + (attn + ffn) * max(n_attn, 1) / max(self.n_layers, 1)
        elif self.family == "audio":
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
            ffn = 2 * d * self.d_ff
            per_layer = 2 * attn + ffn  # decoder has self+cross attention
        total = emb + (self.n_layers + self.n_enc_layers) * per_layer
        return int(total)

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params
        d = self.d_model
        dense_ffn = self.moe.n_experts * 3 * d * self.d_ff
        active_ffn = self.moe.top_k * 3 * d * self.d_ff
        return int(self.n_params - self.n_layers * (dense_ffn - active_ffn))

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test configuration of the same family (CPU-runnable)."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128 if self.d_ff else 0,
            vocab=128,
            head_dim=16,
            max_seq=128,
            scan_layers=False,
            remat=False,
            dtype="float32",
        )
        if self.moe:
            small["moe"] = MoEConfig(n_experts=4, top_k=2, group_size=32)
        if self.ssm:
            small["ssm"] = SSMConfig(state=8, head_dim=8, expand=2, chunk=16)
        if self.hybrid:
            small["hybrid"] = HybridConfig(attn_every=2)
        if self.xlstm:
            small["xlstm"] = XLSTMConfig(slstm_every=2, chunk=16)
        if self.enc_dec:
            small["n_enc_layers"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Shapes (assigned cells)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Families with a sub-quadratic sequence path (may run long_500k).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig) -> List[Tuple[ShapeConfig, Optional[str]]]:
    """All four assigned shapes with a skip reason where applicable."""
    out = []
    for shape in SHAPES.values():
        skip = None
        if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
            skip = ("pure full-attention arch: no sub-quadratic path at 524k "
                    "context (skip noted in DESIGN.md §Arch-applicability)")
        out.append((shape, skip))
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
