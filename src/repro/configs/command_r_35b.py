"""command-r-35b — 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]
"""

from repro.configs.base import ModelConfig, register


@register("command-r-35b")
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab=256000,
        norm="layernorm",
        attn_bias=False,
        tie_embeddings=True,
        rope_theta=8_000_000.0,
    )
