"""zamba2-2.7b — 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64. Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

Hybrid family → runs the ``long_500k`` cell (SSM state is O(1) in sequence;
only the shared-attention KV cache scales with context and it is
sequence-sharded there).
"""

from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        ffn="gelu",
        ssm=SSMConfig(state=64, head_dim=64, expand=2),
        hybrid=HybridConfig(attn_every=6),
    )
