"""transformer-base — the paper's own model (Vaswani et al. 2017, base).

6L encoder + 6L decoder, d_model=512, 8 heads, d_ff=2048, shared vocab
37000 (the paper's retrained En→De WMT model, BLEU 27.68 starting point).
This is the model every Table-1 / Figure-3 reproduction benchmark uses
(at reduced scale where the experiment trains from scratch).
"""

from repro.configs.base import ModelConfig, register


@register("transformer-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="transformer-base",
        family="audio",          # enc-dec builder (token inputs)
        n_layers=6,
        n_enc_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=37000,
        norm="layernorm",
        ffn="gelu",
        enc_dec=True,
        attn_bias=True,
        input_kind="tokens",
        tie_embeddings=True,
    )
