"""Architecture registry — one module per assigned arch (+ the paper's own).

``get_config("<arch-id>")`` returns the exact published configuration;
``cfg.reduced()`` returns the same-family smoke-test configuration.
"""

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    SSMConfig,
    HybridConfig,
    XLSTMConfig,
    QuantSettings,
    ShapeConfig,
    SHAPES,
    get_config,
    list_archs,
    register,
    shapes_for,
)

# Import every arch module so @register runs.
from repro.configs import (  # noqa: F401
    command_r_35b,
    granite_8b,
    granite_moe_1b_a400m,
    internvl2_76b,
    mistral_nemo_12b,
    qwen3_moe_30b_a3b,
    transformer_base,
    whisper_base,
    xlstm_1_3b,
    yi_9b,
    zamba2_2_7b,
)
