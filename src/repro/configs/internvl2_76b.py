"""internvl2-76b — 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
InternViT + InternLM2 backbone. [arXiv:2404.16821]

VLM entry: this config specifies the transformer BACKBONE only; the vision
frontend is a stub — ``input_specs()`` supplies precomputed patch embeddings
(B, S, d_model), so ``input_kind="embeddings"``.
"""

from repro.configs.base import ModelConfig, register


@register("internvl2-76b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        input_kind="embeddings",
    )
