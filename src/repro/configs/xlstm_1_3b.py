"""xlstm-1.3b — 48L d_model=2048 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks (the block's own up/down projections replace the FFN,
hence d_ff=0). [arXiv:2405.04517]

SSM family → runs the ``long_500k`` cell (recurrent state is O(1) in
sequence length).
"""

from repro.configs.base import ModelConfig, XLSTMConfig, register


@register("xlstm-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        ffn="none",
        norm="layernorm",
        xlstm=XLSTMConfig(slstm_every=8),
    )
