"""whisper-base — 6L d_model=512 8H d_ff=2048 vocab=51865, enc-dec.
[arXiv:2212.04356]

Audio entry: the conv frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model) for the encoder;
the decoder consumes tokens.  Decode shapes lower the decoder ``serve_step``
with a self-attention cache of the given length + cross-attention onto the
stub encoder memory.
"""

from repro.configs.base import ModelConfig, register


@register("whisper-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,              # decoder layers
        n_enc_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51865,
        norm="layernorm",
        ffn="gelu",
        enc_dec=True,
        attn_bias=True,
        input_kind="embeddings",
    )
