"""LR schedules: transformer inverse-sqrt (Vaswani) and warmup-cosine."""

from __future__ import annotations

import jax.numpy as jnp


def inverse_sqrt(d_model: int, warmup: int = 4000):
    """The paper's model's original schedule."""
    def lr(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return d_model ** -0.5 * jnp.minimum(s ** -0.5, s * warmup ** -1.5)
    return lr


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup, warm, cos)
    return lr
