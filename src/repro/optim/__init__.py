from repro.optim.adamw import AdamW, AdamWState, global_norm  # noqa: F401
from repro.optim.schedule import inverse_sqrt, warmup_cosine  # noqa: F401
