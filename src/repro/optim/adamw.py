"""AdamW with global-norm clipping — pytree-native, shardable.

Optimizer state mirrors the parameter tree (m, v per leaf) so the FSDP
parameter sharding specs apply verbatim to the optimizer state (ZeRO-3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def _lr(self, step) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * (g * g), state.v, grads)
        mh_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        vh_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))
        lr = self._lr(step)

        def upd(p, m_, v_):
            u = (m_ * mh_scale) / (jnp.sqrt(v_ * vh_scale) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                u = u + self.weight_decay * p
            return p - lr * u

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
