"""Multi-head / grouped-query attention with prefill + decode paths.

Prefill/train uses a chunked (flash-style) attention written in pure jnp —
`lax.scan` over query chunks with f32 accumulation — so 32k-context graphs
never materialize the full (S×S) score tensor.  Decode reads the (optionally
INT8) KV cache through ``kernels.ops.decode_attention``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.kernels import ops
from repro.models import kv_cache as kvc
from repro.models.layers import apply_rope, dense, dense_init

NEG_INF = -1e30


def attention_init(key, cfg, *, stack: tuple = (), dtype=jnp.float32,
                   cross: bool = False):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    keys = jax.random.split(key, 4)
    return {
        "q_proj": dense_init(keys[0], d, h * hd, bias=cfg.attn_bias,
                             dtype=dtype, stack=stack),
        "k_proj": dense_init(keys[1], d, hkv * hd, bias=cfg.attn_bias,
                             dtype=dtype, stack=stack),
        "v_proj": dense_init(keys[2], d, hkv * hd, bias=cfg.attn_bias,
                             dtype=dtype, stack=stack),
        "o_proj": dense_init(keys[3], h * hd, d, bias=cfg.attn_bias,
                             dtype=dtype, stack=stack),
    }


# ---------------------------------------------------------------------------
# chunked full attention (train / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,                 # (B, Sq, H, dh)
    k: jax.Array,                 # (B, Sk, HKV, dh)
    v: jax.Array,                 # (B, Sk, HKV, dh)
    *,
    causal: bool,
    q_positions: Optional[jax.Array] = None,   # (B, Sq) global positions
    kv_lengths: Optional[jax.Array] = None,    # (B,) valid kv length
    q_chunk: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    """``unroll=True`` replaces the chunk scan with a trace-time loop —
    used by the roofline cost extraction, where while-loop bodies would be
    counted once by ``cost_analysis`` (see EXPERIMENTS.md §Roofline)."""
    B, Sq, H, dh = q.shape
    _, Sk, HKV, _ = k.shape
    G = H // HKV
    sm_scale = 1.0 / math.sqrt(dh)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32),
                                       (B, Sq))
    k_positions = jnp.arange(Sk, dtype=jnp.int32)

    C = min(q_chunk, Sq)
    pad = (-Sq) % C
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    n_chunks = (Sq + pad) // C

    # GQA: broadcast KV to the full head count rather than splitting q heads
    # into (HKV, G) — the flat H dim shards over "model" (HKV and G alone
    # often don't divide the axis; H does).  KV bytes grow G× but score
    # memory — the prefill bottleneck — shards 16-way.
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    qg = q.reshape(B, n_chunks, C, H, dh)
    pg = q_positions.reshape(B, n_chunks, C)

    def one_chunk(carry, xs, k=k, v=v, k_positions=k_positions):
        q_c, pos_c = xs                          # (B, C, H, dh), (B, C)
        Sk_c = k.shape[1]
        # bf16 operands, f32 accumulation (MXU-native): keeping K/V in the
        # activation dtype halves their HBM/ICI traffic vs upcasting before
        # the scan (§Perf iteration B4)
        scores = jnp.einsum("bchd,bshd->bhcs", q_c, k,
                            preferred_element_type=jnp.float32) * sm_scale
        mask = jnp.ones((B, C, Sk_c), bool)
        if causal:
            mask &= pos_c[:, :, None] >= k_positions[None, None, :]
        if kv_lengths is not None:
            mask &= k_positions[None, None, :] < kv_lengths[:, None, None]
        scores = jnp.where(mask[:, None], scores, NEG_INF)   # (B,1,C,Sk)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhcs,bshd->bchd", probs.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
        return carry, out.astype(q.dtype)

    xs = (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(pg, 1, 0))
    if unroll:
        # static chunk index → causal BLOCK SKIPPING: chunk i only attends
        # keys [0, (i+1)·C) — halves attention FLOPs at long context
        # (§Perf iteration C2; the Pallas flash kernel does the same on TPU)
        outs = []
        for i in range(n_chunks):
            hi = min((i + 1) * C, Sk) if causal else Sk
            _, o = one_chunk(None, (xs[0][i], xs[1][i]),
                             k=k[:, :hi], v=v[:, :hi],
                             k_positions=k_positions[:hi])
            outs.append(o)
        out = jnp.stack(outs, axis=0)
    else:
        # remat each chunk: recompute the f32 scores/probs in backward
        # instead of saving (chunks × B × H × C × S f32 would dominate the
        # training working set — flash-attention's usual trade).
        _, out = jax.lax.scan(jax.checkpoint(one_chunk), None, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * C, H, dh)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def attention(
    params,
    x: jax.Array,                     # (B, S, D)
    *,
    cfg,
    site: str,
    quant: QuantContext = FP_CONTEXT,
    taps: Optional[Taps] = None,
    positions: Optional[jax.Array] = None,      # (B, S)
    kv_lengths: Optional[jax.Array] = None,
    causal: bool = True,
    rope: bool = True,
    cache: Optional[kvc.LayerCacheView] = None,
    memory: Optional[Tuple[jax.Array, jax.Array]] = None,   # cross-attn (k, v)
    memory_lengths: Optional[jax.Array] = None,
    unroll: bool = False,
    per_query: bool = False,
) -> Tuple[jax.Array, Optional[Tuple]]:
    """Returns (output, new_cache_entries).

    Modes:
    * ``cache is None and memory is None`` — train/prefill self-attention.
    * ``cache is not None`` — decode against the cache: S == 1 is the
      classic single-token step; S > 1 is the speculative *verify* step
      (S consecutive positions appended at the cursor, each query causally
      masked to its own prefix).
    * ``memory is not None`` — cross-attention onto precomputed (k, v).
    """
    B, S, D = x.shape
    H, HKV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype

    q = dense(params["q_proj"], x, site=f"{site}/q_proj", quant=quant,
              taps=taps).reshape(B, S, H, dh)

    if memory is not None:
        k, v = memory
        if per_query and S > 1:
            # decode-side cross-attention over S drafted positions: run the
            # S == 1 shape per query so every position reduces in exactly
            # the order the sequential decode path uses (XLA re-tiles the
            # softmax·V contraction for wider Sq, which costs bit-identity)
            out = jnp.concatenate(
                [chunked_attention(q[:, j:j + 1], k, v, causal=False,
                                   kv_lengths=memory_lengths, unroll=unroll)
                 for j in range(S)], axis=1)
        else:
            out = chunked_attention(q, k, v, causal=False,
                                    kv_lengths=memory_lengths, unroll=unroll)
        out = out.reshape(B, S, H * dh)
        y = dense(params["o_proj"], out, site=f"{site}/o_proj", quant=quant,
                  taps=taps)
        return y, None

    k = dense(params["k_proj"], x, site=f"{site}/k_proj", quant=quant,
              taps=taps).reshape(B, S, HKV, dh)
    v = dense(params["v_proj"], x, site=f"{site}/v_proj", quant=quant,
              taps=taps).reshape(B, S, HKV, dh)

    if positions is None:
        if cache is not None:
            positions = (cache.lengths[:, None]         # (B, S) from cursor
                         + jnp.arange(S, dtype=jnp.int32)[None, :])
        else:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, S))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_entries = (k, v)

    if cache is not None:
        # ---- decode: append at each sequence's cursor, then attend ----
        paged = cache.block_tables is not None
        if S == 1:
            if paged:
                k_c, v_c, ks_c, vs_c = kvc.append_token_paged(
                    cache.k, cache.v, cache.k_scale, cache.v_scale,
                    cache.block_tables, k, v, cache.lengths)
            else:
                k_c, v_c, ks_c, vs_c = kvc.append_token(
                    cache.k, cache.v, cache.k_scale, cache.v_scale, k, v,
                    cache.lengths)
        else:
            # speculative verify: append all S drafted positions at once
            if paged:
                k_c, v_c, ks_c, vs_c = kvc.append_tokens_paged(
                    cache.k, cache.v, cache.k_scale, cache.v_scale,
                    cache.block_tables, k, v, cache.lengths)
            else:
                k_c, v_c, ks_c, vs_c = kvc.append_tokens(
                    cache.k, cache.v, cache.k_scale, cache.v_scale, k, v,
                    cache.lengths)
        sm_scale = 1.0 / math.sqrt(dh)
        if ks_c is None and paged:
            # FP paged FALLBACK: linearize the pool through the table and
            # reuse the contiguous math — it materializes a gathered copy
            # per step, so it trades the beam-reorder slab gather for an
            # attention-side one (a wash at worst; the cross-K/V gather
            # still disappears).  The deployment path is the INT8 cache,
            # whose Pallas kernel walks the table in place with no copy.
            k_lin = kvc.linearize_pages(k_c, cache.block_tables)
            v_lin = kvc.linearize_pages(v_c, cache.block_tables)
        # Each query position j attends its own causal prefix by running
        # the SAME single-query kernel with cursor lengths + j + 1 — for
        # S == 1 this is literally the pre-speculation decode step, and for
        # S > 1 it makes the verify pass bit-identical to sequential decode
        # by construction (identical kernel, shapes, and masked lengths).
        outs = []
        for j in range(S):
            q1 = q[:, j].reshape(B, H, dh)
            lengths = cache.lengths + (j + 1)
            if ks_c is not None and paged:
                o = ops.decode_attention_paged(
                    q1, k_c, ks_c, v_c, vs_c, cache.block_tables, lengths,
                    sm_scale=sm_scale, impl=quant.impl)
            elif ks_c is not None:
                o = ops.decode_attention(q1, k_c, ks_c, v_c, vs_c, lengths,
                                         sm_scale=sm_scale, impl=quant.impl)
            elif paged:
                o = _fp_decode_attention(q1, k_lin, v_lin, lengths, sm_scale)
            else:
                o = _fp_decode_attention(q1, k_c, v_c, lengths, sm_scale)
            outs.append(o)
        out = jnp.stack(outs, axis=1).reshape(B, S, H * dh)
        y = dense(params["o_proj"], out, site=f"{site}/o_proj", quant=quant,
                  taps=taps)
        return y, (k_c, v_c, ks_c, vs_c)

    # ---- train / prefill ----
    out = chunked_attention(q, k, v, causal=causal, q_positions=positions,
                            kv_lengths=kv_lengths, unroll=unroll)
    out = out.reshape(B, S, H * dh)
    y = dense(params["o_proj"], out, site=f"{site}/o_proj", quant=quant,
              taps=taps)
    return y, new_entries


def _fp_decode_attention(q, k, v, lengths, sm_scale):
    """bf16 cache decode path (baseline without the paper's technique)."""
    B, H, dh = q.shape
    _, Sk, HKV, _ = k.shape
    G = H // HKV
    qf = q.astype(jnp.float32).reshape(B, HKV, G, dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    scores = scores * sm_scale
    mask = jnp.arange(Sk)[None, :] < lengths[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, dh).astype(q.dtype)
