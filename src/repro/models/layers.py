"""Shared model layers.  ``dense`` is the quantization integration point.

Conventions (repo-wide):
* every linear is a dict node ``{"w": (…, d_in, d_out)[, "b": (d_out,)]}``;
* quantized weights are :class:`QTensor` with pre-broadcast (keepdims)
  per-output-channel scales, so stacked layers slice cleanly in `lax.scan`;
* each linear has a *site* name (its params path); calibration taps record
  the matmul input under that name and the QuantContext resolves activation
  thresholds / policy by it.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps, record
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.core.qtensor import BlockQTensor, QTensor
from repro.core.quantize import quantize_with_thresholds
from repro.kernels import ops


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, stack: tuple = ()) -> Dict[str, Any]:
    scale = 1.0 / math.sqrt(d_in)
    node = {"w": jax.random.uniform(key, (*stack, d_in, d_out), dtype,
                                    -scale, scale)}
    if bias:
        node["b"] = jnp.zeros((*stack, d_out), dtype)
    return node


def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def norm_init(d: int, kind: str, *, stack: tuple = (), dtype=jnp.float32):
    node = {"scale": jnp.ones((*stack, d), dtype)}
    if kind == "layernorm":
        node["bias"] = jnp.zeros((*stack, d), dtype)
    return node


# ---------------------------------------------------------------------------
# forward ops
# ---------------------------------------------------------------------------

def dense(
    node: Dict[str, Any],
    x: jax.Array,
    *,
    site: str,
    quant: QuantContext = FP_CONTEXT,
    taps: Optional[Taps] = None,
) -> jax.Array:
    """Linear layer: fp einsum, or the paper's INT8 path when ``w`` is a QTensor.

    INT8 path: activation is quantized with the calibrated static threshold
    (KL-search constant — paper §5.5 removed the runtime Min/Max for exactly
    this case) or dynamically per-row as fallback; the matmul runs s8·s8→s32
    on the MXU with the dequant epilogue fused (``kernels/int8_matmul``).
    """
    w = node["w"]
    b = node.get("b")
    record(taps, site, x)

    if isinstance(w, (QTensor, BlockQTensor)):
        # Activations always quantize to INT8 (the paper's sensitivity
        # result): only the *weight* payload drops to 4 bits.
        thr = quant.activation_thresholds(site)
        if thr is None:
            xq = ops.quantize_rowwise(x, impl=quant.impl)
        elif thr.symmetric:
            xq = ops.quantize_static(x, thr.t_max, impl=quant.impl)
        else:
            # independent mode: affine activation quantization; the
            # zero-point correction folds into the matmul epilogue.
            xq = quantize_with_thresholds(x, thr)
        bias = None if b is None else b.astype(jnp.float32)
        if isinstance(w, BlockQTensor):
            # block-wise INT4 weights: dequant fused into the Pallas kernel
            return ops.int4_matmul(xq, w, bias, out_dtype=x.dtype,
                                   impl=quant.impl)
        w_scale = w.scale.reshape(1, w.data.shape[-1])
        w2 = QTensor(w.data, w_scale, jnp.zeros((), jnp.float32), None)
        y = ops.int8_matmul(xq, w2, bias, out_dtype=x.dtype, impl=quant.impl)
        return y

    y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def embed(node, ids: jax.Array, dtype) -> jax.Array:
    return node["table"].astype(dtype)[ids]


def unembed(node, x: jax.Array) -> jax.Array:
    """Logits head via tied embedding transpose (f32 accumulation)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      node["table"].astype(jnp.float32))


def rmsnorm(node, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * node["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm(node, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * node["scale"].astype(jnp.float32)
    if "bias" in node:
        y = y + node["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm(node, x: jax.Array, kind: str) -> jax.Array:
    return layernorm(node, x) if kind == "layernorm" else rmsnorm(node, x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
