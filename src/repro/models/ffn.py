"""Feed-forward blocks: SwiGLU (llama family) and GELU MLP (enc-dec family).

All matmuls route through :func:`repro.models.layers.dense`, so every FFN in
the zoo picks up the paper's INT8 path when its weights are quantized.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.models.layers import dense, dense_init


def ffn_init(key, cfg, *, stack: tuple = (), dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.ffn == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "gate": dense_init(k1, d, f, dtype=dtype, stack=stack),
            "up": dense_init(k2, d, f, dtype=dtype, stack=stack),
            "down": dense_init(k3, f, d, dtype=dtype, stack=stack),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "in": dense_init(k1, d, f, bias=cfg.attn_bias, dtype=dtype, stack=stack),
        "out": dense_init(k2, f, d, bias=cfg.attn_bias, dtype=dtype, stack=stack),
    }


def ffn(params, x: jax.Array, *, cfg, site: str,
        quant: QuantContext = FP_CONTEXT,
        taps: Optional[Taps] = None) -> jax.Array:
    if cfg.ffn == "swiglu":
        g = dense(params["gate"], x, site=f"{site}/gate", quant=quant, taps=taps)
        u = dense(params["up"], x, site=f"{site}/up", quant=quant, taps=taps)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return dense(params["down"], h, site=f"{site}/down", quant=quant,
                     taps=taps)
    h = dense(params["in"], x, site=f"{site}/in", quant=quant, taps=taps)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(params["out"], h, site=f"{site}/out", quant=quant, taps=taps)
