"""Encoder-decoder transformer — whisper-base backbone and the paper's own
Transformer NMT model (Vaswani base).

Structure mirrors the paper's workload: encoder (bidirectional self-attn),
auto-regressive decoder (causal self-attn + cross-attn), the decoder
while-loop being where the paper's GatherNd/batching optimizations live.

Cross-attention K/V are computed once from the encoder memory and cached —
with INT8 cache quantization they are quantized *once* per request
(the cheapest possible activation quantization site).

Inputs: ``src_tokens`` (B, S_enc) or ``src_embeds`` (B, S_enc, D) for the
audio stub; ``tgt_tokens`` (B, S_dec) for teacher forcing.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.distributed.context import constrain
from repro.models import kv_cache as kvc
from repro.models.attention import attention, attention_init
from repro.models.ffn import ffn, ffn_init
from repro.models.layers import embed, embedding_init, norm, norm_init, unembed


def sinusoidal_positions(S: int, D: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / D)
    pe = jnp.zeros((S, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe.astype(dtype)


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _enc_block_init(self, key, stack=()):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
            "attn": attention_init(k1, cfg, stack=stack),
            "ffn_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
            "ffn": ffn_init(k2, cfg, stack=stack),
        }

    def _dec_block_init(self, key, stack=()):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "self_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
            "self_attn": attention_init(k1, cfg, stack=stack),
            "cross_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
            "cross_attn": attention_init(k2, cfg, stack=stack),
            "ffn_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
            "ffn": ffn_init(k3, cfg, stack=stack),
        }

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        n_enc, n_dec = cfg.n_enc_layers, cfg.n_layers
        keys = jax.random.split(key, n_enc + n_dec + 3)
        params: Dict[str, Any] = {
            "embed": embedding_init(keys[0], cfg.vocab, cfg.d_model),
            "enc_final_norm": norm_init(cfg.d_model, cfg.norm),
            "dec_final_norm": norm_init(cfg.d_model, cfg.norm),
        }
        if cfg.scan_layers:
            params["enc_blocks"] = self._enc_block_init(keys[1],
                                                        stack=(n_enc,))
            params["dec_blocks"] = self._dec_block_init(keys[2],
                                                        stack=(n_dec,))
        else:
            for i in range(n_enc):
                params[f"enc_blocks.{i}"] = self._enc_block_init(keys[1 + i])
            for i in range(n_dec):
                params[f"dec_blocks.{i}"] = self._dec_block_init(
                    keys[1 + n_enc + i])
        return params

    # ---------------------------------------------------------------- encode
    def encode(self, params, batch, *, quant: QuantContext = FP_CONTEXT,
               taps: Optional[Taps] = None, unroll: bool = False) -> jax.Array:
        cfg = self.cfg
        dt = cfg.activation_dtype
        if "src_embeds" in batch:
            x = batch["src_embeds"].astype(dt)
        else:
            x = embed(params["embed"], batch["src_tokens"], dt)
            x = x * math.sqrt(cfg.d_model)
        B, S, D = x.shape
        x = x + sinusoidal_positions(S, D, dt)[None]
        lengths = batch.get("src_lengths")

        def block(x, bparams, site):
            h = norm(bparams["attn_norm"], x, cfg.norm)
            a, _ = attention(bparams["attn"], h, cfg=cfg, site=f"{site}/attn",
                             quant=quant, taps=taps, causal=False, rope=False,
                             kv_lengths=lengths, unroll=unroll)
            x = x + a
            h = norm(bparams["ffn_norm"], x, cfg.norm)
            return x + ffn(bparams["ffn"], h, cfg=cfg, site=f"{site}/ffn",
                           quant=quant, taps=taps)

        if cfg.scan_layers:
            def layer(x, bp):
                f = lambda xx: block(xx, bp, "enc_blocks.*")
                if cfg.remat:
                    f = jax.checkpoint(f)
                return f(constrain(x)), None
            x, _ = jax.lax.scan(layer, x, params["enc_blocks"])
        else:
            for i in range(cfg.n_enc_layers):
                x = block(x, params[f"enc_blocks.{i}"], f"enc_blocks.{i}")
        return norm(params["enc_final_norm"], x, cfg.norm)

    # ---------------------------------------------------------------- decode
    def _dec_block(self, bparams, x, memory, *, site, quant, taps, positions,
                   kv_lengths, memory_lengths, unroll, cache_view=None):
        cfg = self.cfg
        h = norm(bparams["self_norm"], x, cfg.norm)
        a, entries = attention(
            bparams["self_attn"], h, cfg=cfg, site=f"{site}/self_attn",
            quant=quant, taps=taps, positions=positions,
            kv_lengths=kv_lengths, cache=cache_view, rope=False,
            unroll=unroll)
        x = x + a
        h = norm(bparams["cross_norm"], x, cfg.norm)
        c, _ = attention(
            bparams["cross_attn"], h, cfg=cfg, site=f"{site}/cross_attn",
            quant=quant, taps=taps, memory=memory,
            memory_lengths=memory_lengths, unroll=unroll,
            per_query=cache_view is not None)
        x = x + c
        h = norm(bparams["ffn_norm"], x, cfg.norm)
        f = ffn(bparams["ffn"], h, cfg=cfg, site=f"{site}/ffn", quant=quant,
                taps=taps)
        return x + f, entries

    def _cross_kv(self, bparams, memory, *, site, quant, taps):
        """Project encoder memory to this layer's cross K/V (done once)."""
        cfg = self.cfg
        B, S, _ = memory.shape
        from repro.models.layers import dense  # local import to avoid cycle
        k = dense(bparams["cross_attn"]["k_proj"], memory,
                  site=f"{site}/cross_attn/k_proj", quant=quant,
                  taps=taps).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        v = dense(bparams["cross_attn"]["v_proj"], memory,
                  site=f"{site}/cross_attn/v_proj", quant=quant,
                  taps=taps).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        return k, v

    def forward(self, params, batch, *, quant: QuantContext = FP_CONTEXT,
                taps: Optional[Taps] = None, unroll: bool = False
                ) -> Tuple[jax.Array, Dict]:
        """Teacher-forced training forward: returns decoder logits."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        memory = self.encode(params, batch, quant=quant, taps=taps,
                             unroll=unroll)
        mem_lengths = batch.get("src_lengths")

        x = embed(params["embed"], batch["tgt_tokens"], dt)
        x = x * math.sqrt(cfg.d_model)
        B, S, D = x.shape
        x = x + sinusoidal_positions(S, D, dt)[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        tgt_lengths = batch.get("tgt_lengths")

        def block(x, bparams, site):
            kv = self._cross_kv(bparams, memory, site=site, quant=quant,
                                taps=taps)
            y, _ = self._dec_block(bparams, x, kv, site=site, quant=quant,
                                   taps=taps, positions=positions,
                                   kv_lengths=tgt_lengths,
                                   memory_lengths=mem_lengths, unroll=unroll)
            return y

        if cfg.scan_layers:
            def layer(x, bp):
                f = lambda xx: block(xx, bp, "dec_blocks.*")
                if cfg.remat:
                    f = jax.checkpoint(f)
                return f(constrain(x)), None
            x, _ = jax.lax.scan(layer, x, params["dec_blocks"])
        else:
            for i in range(cfg.n_layers):
                x = block(x, params[f"dec_blocks.{i}"], f"dec_blocks.{i}")

        x = norm(params["dec_final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x)
        return logits, {}

    # ------------------------------------------------------- serving states
    def init_decode_state(self, batch: int, max_len: int, *,
                          quantized: bool,
                          enc_len: Optional[int] = None,
                          paged: bool = False,
                          page_size: int = 16,
                          n_pages: Optional[int] = None) -> Dict[str, Any]:
        """``enc_len``: pre-allocate cross K/V buffers of that length (used
        by the dry-run to lower serve_step without running prefill).

        ``paged=True`` backs the self-attention cache with a page pool +
        block tables (``kv_cache.PagedKVCache``) instead of contiguous
        rows; rows own no pages until :meth:`splice_prefill` assigns a
        reservation.  ``n_pages`` bounds the pool (default: contiguous-
        equivalent capacity).
        """
        cfg = self.cfg
        if paged:
            cache = kvc.init_paged_cache(
                cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd,
                page_size=page_size, n_pages=n_pages, quantized=quantized,
                dtype=cfg.activation_dtype)
        else:
            cache = kvc.init_cache(cfg.n_layers, batch, max_len,
                                   cfg.n_kv_heads, cfg.hd,
                                   quantized=quantized,
                                   dtype=cfg.activation_dtype)
        state: Dict[str, Any] = {
            "cache": cache,
            "cross_k": None, "cross_v": None, "src_lengths": None,
        }
        if enc_len is not None:
            shape = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.hd)
            state["cross_k"] = jnp.zeros(shape, cfg.activation_dtype)
            state["cross_v"] = jnp.zeros(shape, cfg.activation_dtype)
            state["src_lengths"] = jnp.full((batch,), enc_len, jnp.int32)
        return state

    def encode_cross_kv(self, params, batch, *,
                        quant: QuantContext = FP_CONTEXT
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Encode-once front half of :meth:`prefill`.

        Runs the encoder and projects every decoder layer's cross K/V from
        the memory — the part of prefill whose cost scales with the source
        length.  Returns ``(cross_k, cross_v, src_lengths)`` with
        ``cross_k``/``cross_v`` layer-major ``(L, B, S_enc, HKV, dh)``.

        Split out so the continuous-serving engine can (a) call it *inside*
        the fused decode-burst program (admissions ride the burst dispatch)
        and (b) encode each admitted source exactly once, broadcasting the
        result across a beam group's rows via :meth:`splice_prefill`
        instead of paying ``beam×`` encoder FLOPs on tiled inputs.
        """
        cfg = self.cfg
        memory = self.encode(params, batch, quant=quant)
        B = memory.shape[0]
        src_lengths = batch.get(
            "src_lengths", jnp.full((B,), memory.shape[1], jnp.int32))

        if cfg.scan_layers:
            def layer(_, bp):
                k, v = self._cross_kv(bp, memory, site="dec_blocks.*",
                                      quant=quant, taps=None)
                return None, (k, v)
            _, (ck, cv) = jax.lax.scan(layer, None, params["dec_blocks"])
        else:
            ks, vs = [], []
            for i in range(cfg.n_layers):
                k, v = self._cross_kv(params[f"dec_blocks.{i}"], memory,
                                      site=f"dec_blocks.{i}", quant=quant,
                                      taps=None)
                ks.append(k); vs.append(v)
            ck, cv = jnp.stack(ks), jnp.stack(vs)
        return ck, cv, src_lengths

    # ----------------------------------------------- staged (chunked) encode
    # The encoder is bidirectional (every layer attends over the full
    # source), so a long source cannot be prefilled token-chunk by
    # token-chunk the way a causal decoder stack can.  What *can* be
    # split across serving rounds is depth: embed once, then run one
    # encoder layer per round, then project cross K/V and splice.  Each
    # stage is a small dispatch riding alongside the decode burst, so a
    # long source adds at most one layer of encoder work per round
    # instead of monopolizing a whole fused-admission round.  The three
    # functions below are exact restatements of :meth:`encode` +
    # :meth:`encode_cross_kv` (same op sequence, same quant sites), so a
    # staged prefill is bit-identical to the monolithic one.

    def encode_staged_begin(self, params, batch) -> jax.Array:
        """Embedding + position half of :meth:`encode`; returns ``x``."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        if "src_embeds" in batch:
            x = batch["src_embeds"].astype(dt)
        else:
            x = embed(params["embed"], batch["src_tokens"], dt)
            x = x * math.sqrt(cfg.d_model)
        B, S, D = x.shape
        return x + sinusoidal_positions(S, D, dt)[None]

    def encode_staged_layer(self, params, x: jax.Array, layer_idx: int, *,
                            src_lengths: Optional[jax.Array] = None,
                            quant: QuantContext = FP_CONTEXT) -> jax.Array:
        """One encoder layer of :meth:`encode` (``layer_idx`` static)."""
        cfg = self.cfg
        if cfg.scan_layers:
            bparams = jax.tree_util.tree_map(lambda p: p[layer_idx],
                                             params["enc_blocks"])
            site = "enc_blocks.*"
        else:
            bparams = params[f"enc_blocks.{layer_idx}"]
            site = f"enc_blocks.{layer_idx}"
        h = norm(bparams["attn_norm"], x, cfg.norm)
        a, _ = attention(bparams["attn"], h, cfg=cfg, site=f"{site}/attn",
                         quant=quant, taps=None, causal=False, rope=False,
                         kv_lengths=src_lengths, unroll=False)
        x = x + a
        h = norm(bparams["ffn_norm"], x, cfg.norm)
        return x + ffn(bparams["ffn"], h, cfg=cfg, site=f"{site}/ffn",
                       quant=quant, taps=None)

    def encode_staged_finish(self, params, x: jax.Array, *,
                             src_lengths: Optional[jax.Array] = None,
                             quant: QuantContext = FP_CONTEXT
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Final norm + cross-K/V projections (back half of
        :meth:`encode_cross_kv`); returns ``(ck, cv, src_lengths)``."""
        cfg = self.cfg
        memory = norm(params["enc_final_norm"], x, cfg.norm)
        B = memory.shape[0]
        if src_lengths is None:
            src_lengths = jnp.full((B,), memory.shape[1], jnp.int32)
        if cfg.scan_layers:
            def layer(_, bp):
                k, v = self._cross_kv(bp, memory, site="dec_blocks.*",
                                      quant=quant, taps=None)
                return None, (k, v)
            _, (ck, cv) = jax.lax.scan(layer, None, params["dec_blocks"])
        else:
            ks, vs = [], []
            for i in range(cfg.n_layers):
                k, v = self._cross_kv(params[f"dec_blocks.{i}"], memory,
                                      site=f"dec_blocks.{i}", quant=quant,
                                      taps=None)
                ks.append(k); vs.append(v)
            ck, cv = jnp.stack(ks), jnp.stack(vs)
        return ck, cv, jnp.asarray(src_lengths, jnp.int32)

    def splice_prefill(self, state: Dict[str, Any], cross_k: jax.Array,
                       cross_v: jax.Array, src_lengths: jax.Array,
                       base_rows: jax.Array, *, group: int = 1,
                       pages: Optional[jax.Array] = None) -> Dict[str, Any]:
        """Broadcast-splice an :meth:`encode_cross_kv` result into decode
        state rows — jit-callable, so the serving engine can run it inside
        the fused burst program.

        ``base_rows``: (B_sub,) destination rows, one per encoded source;
        with ``group > 1`` each source is broadcast to ``group`` contiguous
        rows ``[base, base + group)`` (a beam group shares one encoded
        memory).  Out-of-range bases are padding and dropped whole-group by
        jax scatter semantics.  The self-attention KV rows are *not*
        copied: their cursors are reset to 0, which masks every stale
        position exactly (attention masks with a hard ``where``), so the
        next decode step on a spliced row is bit-identical to a step on a
        freshly initialised side batch.

        Paged cache: ``pages`` (len(rows), maxP) carries each spliced
        row's page reservation (sentinel-padded); the rows' block tables
        and ``own_pages`` are installed alongside the cursor reset
        (``kv_cache.assign_pages``) — still no payload copy.
        """
        rows = kvc.group_rows(jnp.asarray(base_rows, jnp.int32), group)
        if group > 1:
            cross_k = jnp.repeat(cross_k, group, axis=1)
            cross_v = jnp.repeat(cross_v, group, axis=1)
            src_lengths = jnp.repeat(src_lengths, group, axis=0)
        out = dict(state)
        out["cross_k"] = state["cross_k"].at[:, rows].set(
            cross_k.astype(state["cross_k"].dtype), mode="drop")
        out["cross_v"] = state["cross_v"].at[:, rows].set(
            cross_v.astype(state["cross_v"].dtype), mode="drop")
        out["src_lengths"] = state["src_lengths"].at[rows].set(
            src_lengths.astype(jnp.int32), mode="drop")
        cache = state["cache"]
        if isinstance(cache, kvc.PagedKVCache):
            if pages is None:
                raise ValueError("paged splice_prefill needs the spliced "
                                 "rows' page reservations")
            out["cache"] = kvc.assign_pages(cache, rows, pages)
        else:
            out["cache"] = kvc.KVCache(
                k=cache.k, v=cache.v, k_scale=cache.k_scale,
                v_scale=cache.v_scale,
                lengths=cache.lengths.at[rows].set(0, mode="drop"))
        return out

    def prefill(self, params, batch, state, *,
                quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        """Encode source; compute+cache per-layer cross K/V; emit BOS logits.

        Composition of :meth:`encode_cross_kv` and the BOS decode step —
        the fused-admission serving path calls the two halves itself (with
        :meth:`splice_prefill` in between) inside its burst program.
        """
        ck, cv, src_lengths = self.encode_cross_kv(params, batch,
                                                   quant=quant)
        state = dict(state)
        state["cross_k"], state["cross_v"] = ck, cv
        state["src_lengths"] = src_lengths
        bos = jnp.zeros((ck.shape[1],), jnp.int32)
        return self.decode_step(params, bos, state, quant=quant)

    def decode_step(self, params, tokens, state, *,
                    quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        """Single-token decode: ``tokens`` (B,) → (logits (B, V), state)."""
        logits, state = self.decode_step_multi(params, tokens[:, None], state,
                                               quant=quant)
        return logits[:, 0], state

    def decode_step_multi(self, params, tokens, state, *,
                          quant: QuantContext = FP_CONTEXT
                          ) -> Tuple[jax.Array, Dict]:
        """Decode ``T`` consecutive positions per row in one pass.

        ``tokens``: (B, T) — position t of row b is embedded at cursor
        ``lengths[b] + t`` and causally masked to its own prefix, so the
        returned logits (B, T, V) match T sequential :meth:`decode_step`
        calls bit-for-bit (same kernels per query — see ``attention``).
        The cache advances by T.  This is the speculative-decoding verify
        primitive; ``decode_step`` is the T == 1 wrapper.
        """
        cfg = self.cfg
        dt = cfg.activation_dtype
        cache = state["cache"]
        B, T = tokens.shape
        x = embed(params["embed"], tokens, dt) * math.sqrt(cfg.d_model)
        pe = sinusoidal_positions(cache.capacity, cfg.d_model, dt)
        # clamp explicitly: inside a decode burst (lax.while_loop in the
        # serving engine) finished rows keep stepping past their cursor;
        # their reads must stay in bounds (outputs are EOS-masked anyway)
        pos = jnp.minimum(cache.lengths[:, None]
                          + jnp.arange(T, dtype=jnp.int32)[None, :],
                          cache.capacity - 1)
        x = x + pe[pos]

        paged = isinstance(cache, kvc.PagedKVCache)
        tables = cache.block_tables if paged else None

        def block_with_cache(x, bparams, kl, vl, ksl, vsl, ck, cv, site):
            view = kvc.LayerCacheView(k=kl, v=vl, k_scale=ksl, v_scale=vsl,
                                      lengths=cache.lengths,
                                      block_tables=tables)
            y, entries = self._dec_block(
                bparams, x, (ck, cv), site=site, quant=quant, taps=None,
                positions=None, kv_lengths=None,
                memory_lengths=state["src_lengths"], unroll=False,
                cache_view=view)
            return y, entries

        if cfg.scan_layers:
            # full self-cache in the scan carry (single live copy — see
            # transformer.py); cross K/V are read-only xs.
            idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
            quantized = cache.quantized

            def layer(carry, xs):
                x, kc, vc, ksc, vsc = carry
                bp, ck, cv, li = xs
                kl = jax.lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
                vl = jax.lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
                ksl = (jax.lax.dynamic_index_in_dim(ksc, li, 0,
                                                    keepdims=False)
                       if quantized else None)
                vsl = (jax.lax.dynamic_index_in_dim(vsc, li, 0,
                                                    keepdims=False)
                       if quantized else None)
                x, e = block_with_cache(x, bp, kl, vl, ksl, vsl, ck, cv,
                                        "dec_blocks.*")
                kc = jax.lax.dynamic_update_index_in_dim(kc, e[0], li, 0)
                vc = jax.lax.dynamic_update_index_in_dim(vc, e[1], li, 0)
                if quantized:
                    ksc = jax.lax.dynamic_update_index_in_dim(ksc, e[2],
                                                              li, 0)
                    vsc = jax.lax.dynamic_update_index_in_dim(vsc, e[3],
                                                              li, 0)
                return (x, kc, vc, ksc, vsc), None

            init = (x, cache.k, cache.v,
                    cache.k_scale if quantized else jnp.zeros((), x.dtype),
                    cache.v_scale if quantized else jnp.zeros((), x.dtype))
            (x, k_c, v_c, ks_c, vs_c), _ = jax.lax.scan(
                layer, init,
                (params["dec_blocks"], state["cross_k"], state["cross_v"],
                 idx))
            if not quantized:
                ks_c = vs_c = None
        else:
            kL, vL, ksL, vsL = [], [], [], []
            for i in range(cfg.n_layers):
                ksl = cache.k_scale[i] if cache.quantized else None
                vsl = cache.v_scale[i] if cache.quantized else None
                x, e = block_with_cache(
                    x, params[f"dec_blocks.{i}"], cache.k[i], cache.v[i],
                    ksl, vsl, state["cross_k"][i], state["cross_v"][i],
                    f"dec_blocks.{i}")
                kL.append(e[0]); vL.append(e[1])
                ksL.append(e[2]); vsL.append(e[3])
            k_c, v_c = jnp.stack(kL), jnp.stack(vL)
            ks_c = jnp.stack(ksL) if cache.quantized else None
            vs_c = jnp.stack(vsL) if cache.quantized else None

        state = dict(state)
        if paged:
            state["cache"] = kvc.PagedKVCache(
                k=k_c, v=v_c, k_scale=ks_c, v_scale=vs_c,
                block_tables=cache.block_tables, own_pages=cache.own_pages,
                lengths=cache.lengths + T)
        else:
            state["cache"] = kvc.KVCache(k=k_c, v=v_c, k_scale=ks_c,
                                         v_scale=vs_c,
                                         lengths=cache.lengths + T)
        x = norm(params["dec_final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x)
        return logits, state
