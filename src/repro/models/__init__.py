"""Model zoo: composable JAX model definitions for the assigned archs."""

from repro.models.registry import build_model  # noqa: F401
from repro.models import kv_cache  # noqa: F401
