"""Mixture-of-Experts FFN with GShard-style grouped einsum dispatch.

Routing/dispatch design (SPMD-friendly; experts shard over the "model" mesh
axis, token groups over "data"):

* tokens are split into fixed groups of ``group_size`` — capacity is
  per-group (``C = ceil(group_size·top_k/E · capacity_factor)``), which keeps
  the dispatch one-hot at a bounded (G, S_g, E, C) instead of cubic in total
  tokens;
* dispatch/combine are einsums against that one-hot (the battle-tested
  GShard lowering — XLA partitions it into all-to-all-equivalent collective
  matmuls);
* expert FFNs are *grouped matmuls* — per-expert batched s8·s8→s32 through
  ``kernels.ops.int8_matmul_batched`` when quantized.

The router linear is deny-listed from quantization by default
(``core.policy.DEFAULT_DENY``): its logits feed a softmax/top-k, the class of
op the paper keeps in FP32.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps, record
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.core.qtensor import QTensor
from repro.kernels import ops
from repro.models.layers import dense, dense_init


def moe_init(key, cfg, *, stack: tuple = (), dtype=jnp.float32):
    d, f, m = cfg.d_model, cfg.d_ff, cfg.moe
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d, m.n_experts, dtype=dtype, stack=stack),
        "experts": {
            "gate": dense_init(kg, d, f, dtype=dtype,
                               stack=(*stack, m.n_experts)),
            "up": dense_init(ku, d, f, dtype=dtype,
                             stack=(*stack, m.n_experts)),
            "down": dense_init(kd, f, d, dtype=dtype,
                               stack=(*stack, m.n_experts)),
        },
    }


def _expert_dense(node, x: jax.Array, *, site: str, quant: QuantContext,
                  taps: Optional[Taps]) -> jax.Array:
    """Batched per-expert linear: x (E, M, K) @ w (E, K, N)."""
    w = node["w"]
    record(taps, site, x)
    if isinstance(w, QTensor):
        thr = quant.activation_thresholds(site)
        if thr is not None and thr.symmetric:
            scale = jnp.float32(thr.t_max) / 127.0
            q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
            xq = QTensor(q.astype(jnp.int8), scale, jnp.zeros(()), None)
        else:
            E, M, K = x.shape
            amax = jnp.maximum(
                jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                        keepdims=True), 1e-12)
            q = jnp.clip(jnp.round(x.astype(jnp.float32) / (amax / 127.0)),
                         -127, 127)
            xq = QTensor(q.astype(jnp.int8), amax / 127.0, jnp.zeros(()), None)
        w_scale = w.scale.reshape(w.data.shape[0], 1, w.data.shape[-1])
        wq = QTensor(w.data, w_scale, jnp.zeros(()), None)
        return ops.int8_matmul_batched(xq, wq, out_dtype=x.dtype,
                                       impl=quant.impl)
    return jnp.einsum("emk,ekn->emn", x, w.astype(x.dtype))


def moe_ffn(
    params,
    x: jax.Array,                 # (B, S, D)
    *,
    cfg,
    site: str,
    quant: QuantContext = FP_CONTEXT,
    taps: Optional[Taps] = None,
):
    """Returns (output (B,S,D), aux) where aux carries load-balance stats."""
    B, S, D = x.shape
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    dt = x.dtype

    tokens = B * S
    g_sz = min(m.group_size, tokens)
    # pad token count to a whole number of groups
    pad = (-tokens) % g_sz
    x_flat = x.reshape(tokens, D)
    if pad:
        x_flat = jnp.pad(x_flat, ((0, pad), (0, 0)))
    G = (tokens + pad) // g_sz
    xg = x_flat.reshape(G, g_sz, D)

    # ---- routing (kept fp32: softmax/top-k — paper §3 rule) ----
    logits = dense(params["router"], xg, site=f"{site}/router", quant=quant,
                   taps=taps).astype(jnp.float32)            # (G, Sg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (G, Sg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(int(math.ceil(g_sz * K / E * m.capacity_factor)), 4)

    # position of each (token, choice) within its expert queue
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (G,Sg,K,E)
    flat = onehot_e.reshape(G, g_sz * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (G,Sg*K,E)
    pos = jnp.sum(pos.reshape(G, g_sz, K, E) * onehot_e, axis=-1)  # (G,Sg,K)
    keep = pos < capacity

    onehot_c = jax.nn.one_hot(pos, capacity, dtype=dt)         # (G,Sg,K,C)
    onehot_c = onehot_c * keep[..., None].astype(dt)
    oh_e = onehot_e.astype(dt)
    dispatch = jnp.einsum("gske,gskc->gsec", oh_e, onehot_c)   # (G,Sg,E,C)
    combine = jnp.einsum("gske,gskc,gsk->gsec", oh_e, onehot_c,
                         gate_vals.astype(dt))

    # ---- dispatch → expert FFN (grouped) → combine ----
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)            # (E,G,C,D)
    xe = xe.reshape(E, G * capacity, D)
    g = _expert_dense(params["experts"]["gate"], xe,
                      site=f"{site}/experts/gate", quant=quant, taps=taps)
    u = _expert_dense(params["experts"]["up"], xe,
                      site=f"{site}/experts/up", quant=quant, taps=taps)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    y_e = _expert_dense(params["experts"]["down"], h,
                        site=f"{site}/experts/down", quant=quant, taps=taps)
    y_e = y_e.reshape(E, G, capacity, D)
    y = jnp.einsum("egcd,gsec->gsd", y_e, combine)             # (G,Sg,D)

    y = y.reshape(-1, D)
    if pad:
        y = y[:tokens]
    y = y.reshape(B, S, D)

    # load-balance aux loss terms (Switch-style)
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0].reshape(-1), E, dtype=jnp.float32),
        axis=0)
    aux = {"load_balance_loss": E * jnp.sum(me * ce),
           "dropped_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y, aux
