"""zamba2-style hybrid: Mamba2 backbone + a SHARED attention block applied
every ``attn_every`` layers.

The shared block has ONE set of parameters reused at each application
(zamba2's signature trick), but each application needs its own KV cache at
decode time — caches are stacked (n_apps, B, S, H, dh).

long_500k runs through this model: the Mamba2 state is O(1) in context, and
only the 9 shared-attention caches scale with sequence (sharded over the
"data" mesh axis there).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.distributed.context import constrain
from repro.models import kv_cache as kvc
from repro.models.attention import attention, attention_init
from repro.models.ffn import ffn, ffn_init
from repro.models.layers import embed, embedding_init, norm, norm_init, unembed
from repro.models.ssm import SSMState, ssm_block, ssm_decode_step, ssm_init


class HybridLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.n_apps = cfg.n_layers // cfg.hybrid.attn_every

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_e, k_m, k_a, k_f = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": embedding_init(k_e, cfg.vocab, cfg.d_model),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
            "shared": {
                "attn_norm": norm_init(cfg.d_model, cfg.norm),
                "attn": attention_init(k_a, cfg),
                "ffn_norm": norm_init(cfg.d_model, cfg.norm),
                "ffn": ffn_init(k_f, cfg),
            },
        }
        if cfg.scan_layers:
            params["mamba"] = ssm_init(k_m, cfg, stack=(cfg.n_layers,))
        else:
            keys = jax.random.split(k_m, cfg.n_layers)
            for i in range(cfg.n_layers):
                params[f"mamba.{i}"] = ssm_init(keys[i], cfg)
        return params

    def _shared_block(self, params, x, *, quant, taps, positions, kv_lengths,
                      unroll, cache_view=None):
        cfg = self.cfg
        sp = params["shared"]
        h = norm(sp["attn_norm"], x, cfg.norm)
        a, entries = attention(sp["attn"], h, cfg=cfg, site="shared/attn",
                               quant=quant, taps=taps, positions=positions,
                               kv_lengths=kv_lengths, cache=cache_view,
                               unroll=unroll)
        x = x + a
        h = norm(sp["ffn_norm"], x, cfg.norm)
        x = x + ffn(sp["ffn"], h, cfg=cfg, site="shared/ffn", quant=quant,
                    taps=taps)
        return x, entries

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, *, quant: QuantContext = FP_CONTEXT,
                taps: Optional[Taps] = None, unroll: bool = False
                ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        every = cfg.hybrid.attn_every
        x = embed(params["embed"], batch["tokens"], cfg.activation_dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        lengths = batch.get("lengths")

        if cfg.scan_layers:
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape(self.n_apps, every, *a.shape[1:]),
                params["mamba"])

            def group_fn(x, gparams):
                def inner(x, bp):
                    f = lambda xx: xx + ssm_block(bp, xx, cfg=cfg,
                                                  site="blocks.*/mamba",
                                                  quant=quant, taps=taps,
                                                  unroll=unroll)[0]
                    if cfg.remat:
                        f = jax.checkpoint(f)
                    return f(constrain(x)), None
                x, _ = jax.lax.scan(inner, x, gparams)
                g = lambda xx: self._shared_block(
                    params, xx, quant=quant, taps=taps, positions=positions,
                    kv_lengths=lengths, unroll=unroll)[0]
                if cfg.remat:
                    g = jax.checkpoint(g)
                return g(x), None

            x, _ = jax.lax.scan(group_fn, x, grouped)
        else:
            for i in range(cfg.n_layers):
                y, _ = ssm_block(params[f"mamba.{i}"], x, cfg=cfg,
                                 site=f"blocks.{i}/mamba", quant=quant,
                                 taps=taps, unroll=unroll)
                x = x + y
                if (i + 1) % every == 0:
                    x, _ = self._shared_block(params, x, quant=quant,
                                              taps=taps, positions=positions,
                                              kv_lengths=lengths,
                                              unroll=unroll)

        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["embed"], x), {}

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, max_len: int, *,
                          quantized: bool) -> Dict[str, Any]:
        cfg = self.cfg
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        ssm_states = SSMState(
            h=jnp.zeros((cfg.n_layers, batch, H, s.state, s.head_dim),
                        jnp.float32),
            conv=jnp.zeros((cfg.n_layers, batch, s.conv_width - 1, d_inner),
                           cfg.activation_dtype),
        )
        cache = kvc.init_cache(self.n_apps, batch, max_len, cfg.n_kv_heads,
                               cfg.hd, quantized=quantized,
                               dtype=cfg.activation_dtype)
        return {"ssm": ssm_states, "cache": cache}

    def prefill(self, params, batch, state, *,
                quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        every = cfg.hybrid.attn_every
        x = embed(params["embed"], batch["tokens"], cfg.activation_dtype)
        B, S, _ = x.shape
        lengths = batch.get("lengths",
                            jnp.full((B,), S, jnp.int32))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        cache = state["cache"]
        quantized = cache.quantized

        def entries_out(entries):
            k, v = entries
            if quantized:
                kq, kss_ = kvc.quantize_kv(k)
                vq, vss_ = kvc.quantize_kv(v)
                return kq, vq, kss_, vss_
            return (k.astype(cache.k.dtype), v.astype(cache.v.dtype),
                    jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

        if cfg.scan_layers:
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape(self.n_apps, every, *a.shape[1:]),
                params["mamba"])

            def group(x, gparams):
                def inner(x, bp):
                    y, st = ssm_block(bp, x, cfg=cfg, site="blocks.*/mamba",
                                      quant=quant, taps=None,
                                      return_state=True)
                    return x + y, st
                x, states = jax.lax.scan(inner, x, gparams)
                x, entries = self._shared_block(
                    params, x, quant=quant, taps=None, positions=positions,
                    kv_lengths=lengths, unroll=False)
                return x, (states, *entries_out(entries))

            x, (states, ks, vs, kss, vss) = jax.lax.scan(group, x, grouped)
            new_ssm = jax.tree_util.tree_map(
                lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), states)
        else:
            h_list, conv_list = [], []
            k_list, v_list, ks_list, vs_list = [], [], [], []
            for i in range(cfg.n_layers):
                y, st = ssm_block(params[f"mamba.{i}"], x, cfg=cfg,
                                  site=f"blocks.{i}/mamba", quant=quant,
                                  taps=None, return_state=True)
                x = x + y
                h_list.append(st.h)
                conv_list.append(st.conv)
                if (i + 1) % every == 0:
                    x, entries = self._shared_block(
                        params, x, quant=quant, taps=None,
                        positions=positions, kv_lengths=lengths,
                        unroll=False)
                    o = entries_out(entries)
                    k_list.append(o[0]); v_list.append(o[1])
                    ks_list.append(o[2]); vs_list.append(o[3])
            ks, vs = jnp.stack(k_list), jnp.stack(v_list)
            kss, vss = jnp.stack(ks_list), jnp.stack(vs_list)
            new_ssm = SSMState(h=jnp.stack(h_list),
                               conv=jnp.stack(conv_list))
        dus = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(
            buf, new, 0, 2)
        k_c, v_c = dus(cache.k, ks), dus(cache.v, vs)
        if quantized:
            ks_c, vs_c = dus(cache.k_scale, kss), dus(cache.v_scale, vss)
        else:
            ks_c = vs_c = None

        state = dict(state)
        state["cache"] = kvc.KVCache(k=k_c, v=v_c, k_scale=ks_c,
                                     v_scale=vs_c, lengths=lengths)
        state["ssm"] = new_ssm

        x = norm(params["final_norm"], x, cfg.norm)
        idx = jnp.maximum(lengths - 1, 0)
        x_last = x[jnp.arange(B), idx]
        return unembed(params["embed"], x_last[:, None, :])[:, 0], state

    def decode_step(self, params, tokens, state, *,
                    quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        every = cfg.hybrid.attn_every
        cache = state["cache"]
        ssm = state["ssm"]
        x = embed(params["embed"], tokens[:, None], cfg.activation_dtype)

        if cfg.scan_layers:
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape(self.n_apps, every, *a.shape[1:]),
                params["mamba"])
            quantized = cache.quantized
            gidx = jnp.arange(self.n_apps, dtype=jnp.int32)

            def group(carry, xs):
                x, h_all, conv_all, kc, vc, ksc, vsc = carry
                gparams, gi = xs

                def inner(icarry, ys):
                    x, h_all, conv_all = icarry
                    bp, j = ys
                    li = gi * every + j
                    st = SSMState(
                        h=jax.lax.dynamic_index_in_dim(h_all, li, 0, False),
                        conv=jax.lax.dynamic_index_in_dim(conv_all, li, 0,
                                                          False))
                    y, st2 = ssm_decode_step(bp, x, st, cfg=cfg,
                                             site="blocks.*/mamba",
                                             quant=quant)
                    h_all = jax.lax.dynamic_update_index_in_dim(
                        h_all, st2.h, li, 0)
                    conv_all = jax.lax.dynamic_update_index_in_dim(
                        conv_all, st2.conv, li, 0)
                    return (x + y, h_all, conv_all), None

                (x, h_all, conv_all), _ = jax.lax.scan(
                    inner, (x, h_all, conv_all),
                    (gparams, jnp.arange(every, dtype=jnp.int32)))

                kl = jax.lax.dynamic_index_in_dim(kc, gi, 0, keepdims=False)
                vl = jax.lax.dynamic_index_in_dim(vc, gi, 0, keepdims=False)
                ksl = (jax.lax.dynamic_index_in_dim(ksc, gi, 0, False)
                       if quantized else None)
                vsl = (jax.lax.dynamic_index_in_dim(vsc, gi, 0, False)
                       if quantized else None)
                view = kvc.LayerCacheView(k=kl, v=vl, k_scale=ksl,
                                          v_scale=vsl, lengths=cache.lengths)
                x, e = self._shared_block(
                    params, x, quant=quant, taps=None, positions=None,
                    kv_lengths=None, unroll=False, cache_view=view)
                kc = jax.lax.dynamic_update_index_in_dim(kc, e[0], gi, 0)
                vc = jax.lax.dynamic_update_index_in_dim(vc, e[1], gi, 0)
                if quantized:
                    ksc = jax.lax.dynamic_update_index_in_dim(ksc, e[2],
                                                              gi, 0)
                    vsc = jax.lax.dynamic_update_index_in_dim(vsc, e[3],
                                                              gi, 0)
                return (x, h_all, conv_all, kc, vc, ksc, vsc), None

            zero = jnp.zeros((), x.dtype)
            init = (x, ssm.h, ssm.conv, cache.k, cache.v,
                    cache.k_scale if quantized else zero,
                    cache.v_scale if quantized else zero)
            (x, h_all, conv_all, k_c, v_c, ks_c, vs_c), _ = jax.lax.scan(
                group, init, (grouped, gidx))
            new_ssm = SSMState(h=h_all, conv=conv_all)
            if not quantized:
                ks_c = vs_c = None
        else:
            h_list, conv_list = [], []
            kL, vL, ksL, vsL = [], [], [], []
            app = 0
            for i in range(cfg.n_layers):
                st = SSMState(h=ssm.h[i], conv=ssm.conv[i])
                y, st2 = ssm_decode_step(params[f"mamba.{i}"], x, st, cfg=cfg,
                                         site=f"blocks.{i}/mamba", quant=quant)
                x = x + y
                h_list.append(st2.h); conv_list.append(st2.conv)
                if (i + 1) % every == 0:
                    ksl = cache.k_scale[app] if cache.quantized else None
                    vsl = cache.v_scale[app] if cache.quantized else None
                    view = kvc.LayerCacheView(
                        k=cache.k[app], v=cache.v[app], k_scale=ksl,
                        v_scale=vsl, lengths=cache.lengths)
                    x, e = self._shared_block(
                        params, x, quant=quant, taps=None, positions=None,
                        kv_lengths=None, unroll=False, cache_view=view)
                    kL.append(e[0]); vL.append(e[1])
                    ksL.append(e[2]); vsL.append(e[3])
                    app += 1
            new_ssm = SSMState(h=jnp.stack(h_list), conv=jnp.stack(conv_list))
            k_c, v_c = jnp.stack(kL), jnp.stack(vL)
            ks_c = jnp.stack(ksL) if cache.quantized else None
            vs_c = jnp.stack(vsL) if cache.quantized else None

        state = dict(state)
        state["ssm"] = new_ssm
        state["cache"] = kvc.KVCache(k=k_c, v=v_c, k_scale=ks_c,
                                     v_scale=vs_c, lengths=cache.lengths + 1)
        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["embed"], x)[:, 0], state
