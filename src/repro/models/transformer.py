"""Decoder-only transformer LM (dense / MoE / VLM-backbone families).

Layer stacking:
* ``cfg.scan_layers=True`` — parameters stacked (L, …), applied with
  ``lax.scan`` (+ per-layer remat) so the HLO is depth-independent; sites
  use the layer-agnostic ``blocks.*`` names.
* ``cfg.scan_layers=False`` — per-layer dicts ``blocks.{i}`` and a python
  loop; used by calibration (per-site taps) and the smoke tests.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.distributed.context import constrain, tag_block_grads
from repro.models import kv_cache as kvc
from repro.models.attention import attention, attention_init
from repro.models.ffn import ffn, ffn_init
from repro.models.layers import embed, embedding_init, norm, norm_init, unembed
from repro.models.moe import moe_ffn, moe_init


class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _block_init(self, key, *, stack: tuple = ()):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        block = {
            "attn_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
            "attn": attention_init(k1, cfg, stack=stack),
            "ffn_norm": norm_init(cfg.d_model, cfg.norm, stack=stack),
        }
        if cfg.moe is not None:
            block["moe"] = moe_init(k2, cfg, stack=stack)
        else:
            block["ffn"] = ffn_init(k2, cfg, stack=stack)
        return block

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 2)
        params: Dict[str, Any] = {
            "embed": embedding_init(keys[0], cfg.vocab, cfg.d_model),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
        }
        if cfg.scan_layers:
            params["blocks"] = self._block_init(keys[1],
                                                stack=(cfg.n_layers,))
        else:
            for i in range(cfg.n_layers):
                params[f"blocks.{i}"] = self._block_init(keys[i + 1])
        return params

    # --------------------------------------------------------------- forward
    def _block_apply(self, bparams, x, *, site, quant, taps, positions,
                     kv_lengths, unroll, cache_view=None):
        cfg = self.cfg
        h = norm(bparams["attn_norm"], x, cfg.norm)
        a, entries = attention(
            bparams["attn"], h, cfg=cfg, site=f"{site}/attn", quant=quant,
            taps=taps, positions=positions, kv_lengths=kv_lengths,
            cache=cache_view, unroll=unroll)
        x = x + a
        h = norm(bparams["ffn_norm"], x, cfg.norm)
        if cfg.moe is not None:
            f, aux = moe_ffn(bparams["moe"], h, cfg=cfg, site=f"{site}/moe",
                             quant=quant, taps=taps)
        else:
            f = ffn(bparams["ffn"], h, cfg=cfg, site=f"{site}/ffn",
                    quant=quant, taps=taps)
            aux = {}
        return x + f, entries, aux

    def _inputs(self, params, batch):
        cfg = self.cfg
        dt = cfg.activation_dtype
        if "embeds" in batch:
            return batch["embeds"].astype(dt)
        return embed(params["embed"], batch["tokens"], dt)

    def forward(self, params, batch, *, quant: QuantContext = FP_CONTEXT,
                taps: Optional[Taps] = None, unroll: bool = False,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Full-sequence forward (train / prefill-style). Returns (logits, aux)."""
        cfg = self.cfg
        x = self._inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        kv_lengths = batch.get("lengths")
        aux_total = {"load_balance_loss": jnp.float32(0.0)}

        if cfg.scan_layers:
            def layer(x, bparams):
                bparams = tag_block_grads(bparams)
                f = lambda xx: self._block_apply(
                    bparams, xx, site="blocks.*", quant=quant, taps=taps,
                    positions=positions, kv_lengths=kv_lengths, unroll=unroll)
                if cfg.remat:
                    f = jax.checkpoint(f)
                # barrier: keeps XLA from batching the per-layer f32
                # upcast of every saved carry into one (L,B,S,D) f32 blob
                x, _, aux = f(jax.lax.optimization_barrier(constrain(x)))
                return x, aux.get("load_balance_loss", jnp.float32(0.0))

            x, lb = jax.lax.scan(layer, x, params["blocks"])
            aux_total["load_balance_loss"] = jnp.sum(lb)
        else:
            for i in range(cfg.n_layers):
                x, _, aux = self._block_apply(
                    params[f"blocks.{i}"], x, site=f"blocks.{i}", quant=quant,
                    taps=taps, positions=positions, kv_lengths=kv_lengths,
                    unroll=unroll)
                if "load_balance_loss" in aux:
                    aux_total["load_balance_loss"] += aux["load_balance_loss"]

        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x)
        return logits, aux_total

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, max_len: int, *,
                          quantized: bool) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "cache": kvc.init_cache(cfg.n_layers, batch, max_len,
                                    cfg.n_kv_heads, cfg.hd,
                                    quantized=quantized,
                                    dtype=cfg.activation_dtype),
        }

    def prefill(self, params, batch, state, *,
                quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        """Run the prompt, fill the cache, return last-position logits."""
        cfg = self.cfg
        x = self._inputs(params, batch)
        B, S, _ = x.shape
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        cache = state["cache"]
        quantized = cache.quantized

        def entries_out(entries):
            """Quantize K/V inside the layer loop so the stacked per-layer
            outputs are int8 (4× smaller transients than bf16)."""
            k, v = entries
            if quantized:
                kq, ks = kvc.quantize_kv(k)
                vq, vs = kvc.quantize_kv(v)
                return kq, vq, ks, vs
            return (k.astype(cache.k.dtype), v.astype(cache.v.dtype),
                    jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

        if cfg.scan_layers:
            def layer(x, bparams):
                x, entries, _ = self._block_apply(
                    bparams, x, site="blocks.*", quant=quant, taps=None,
                    positions=positions, kv_lengths=lengths, unroll=False)
                return x, entries_out(entries)

            x, (ks, vs, kss, vss) = jax.lax.scan(layer, x, params["blocks"])
        else:
            outs = []
            for i in range(cfg.n_layers):
                x, entries, _ = self._block_apply(
                    params[f"blocks.{i}"], x, site=f"blocks.{i}", quant=quant,
                    taps=None, positions=positions, kv_lengths=lengths,
                    unroll=False)
                outs.append(entries_out(entries))
            ks = jnp.stack([o[0] for o in outs])
            vs = jnp.stack([o[1] for o in outs])
            kss = jnp.stack([o[2] for o in outs])
            vss = jnp.stack([o[3] for o in outs])

        # write into the (donated) cache buffers at positions [0, S)
        dus = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(
            buf, new, 0, 2)
        k_c, v_c = dus(cache.k, ks), dus(cache.v, vs)
        if quantized:
            ks_c, vs_c = dus(cache.k_scale, kss), dus(cache.v_scale, vss)
        else:
            ks_c = vs_c = None
        state = dict(state)
        state["cache"] = kvc.KVCache(k=k_c, v=v_c, k_scale=ks_c,
                                     v_scale=vs_c, lengths=lengths)

        x = norm(params["final_norm"], x, cfg.norm)
        # logits at each sequence's last valid position
        idx = jnp.maximum(lengths - 1, 0)
        x_last = x[jnp.arange(B), idx]
        logits = unembed(params["embed"], x_last[:, None, :])[:, 0]
        return logits, state

    def decode_step(self, params, tokens_or_embeds, state, *,
                    quant: QuantContext = FP_CONTEXT
                    ) -> Tuple[jax.Array, Dict]:
        """One decode step. tokens: (B,) int32 (or (B,1,D) embeds)."""
        cfg = self.cfg
        cache = state["cache"]
        if tokens_or_embeds.ndim == 1:
            x = embed(params["embed"], tokens_or_embeds[:, None],
                      cfg.activation_dtype)
        else:
            x = tokens_or_embeds.astype(cfg.activation_dtype)
        B = x.shape[0]

        def block_with_cache(x, bparams, kl, vl, ksl, vsl, site):
            view = kvc.LayerCacheView(k=kl, v=vl, k_scale=ksl, v_scale=vsl,
                                      lengths=cache.lengths)
            x, entries, _ = self._block_apply(
                bparams, x, site=site, quant=quant, taps=None,
                positions=None, kv_lengths=None, unroll=False,
                cache_view=view)
            return x, entries

        if cfg.scan_layers:
            # The full cache rides in the scan CARRY (sliced/written per
            # layer with dynamic_update_index) so exactly one copy lives —
            # xs/ys would keep input and output caches alive simultaneously
            # (2× HBM for the dominant decode buffer).
            idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
            quantized = cache.quantized

            def layer(carry, xs):
                x, kc, vc, ksc, vsc = carry
                bparams, li = xs
                kl = jax.lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
                vl = jax.lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
                ksl = (jax.lax.dynamic_index_in_dim(ksc, li, 0,
                                                    keepdims=False)
                       if quantized else None)
                vsl = (jax.lax.dynamic_index_in_dim(vsc, li, 0,
                                                    keepdims=False)
                       if quantized else None)
                x, (k2, v2, ks2, vs2) = block_with_cache(
                    x, bparams, kl, vl, ksl, vsl, "blocks.*")
                kc = jax.lax.dynamic_update_index_in_dim(kc, k2, li, 0)
                vc = jax.lax.dynamic_update_index_in_dim(vc, v2, li, 0)
                if quantized:
                    ksc = jax.lax.dynamic_update_index_in_dim(ksc, ks2, li, 0)
                    vsc = jax.lax.dynamic_update_index_in_dim(vsc, vs2, li, 0)
                return (x, kc, vc, ksc, vsc), None

            init = (x, cache.k, cache.v,
                    cache.k_scale if quantized else jnp.zeros((), x.dtype),
                    cache.v_scale if quantized else jnp.zeros((), x.dtype))
            (x, k_c, v_c, ks_c, vs_c), _ = jax.lax.scan(
                layer, init, (params["blocks"], idx))
            if not quantized:
                ks_c = vs_c = None
        else:
            k_list, v_list, ks_list, vs_list = [], [], [], []
            for i in range(cfg.n_layers):
                ksl = cache.k_scale[i] if cache.quantized else None
                vsl = cache.v_scale[i] if cache.quantized else None
                x, (k2, v2, ks2, vs2) = block_with_cache(
                    x, params[f"blocks.{i}"], cache.k[i], cache.v[i],
                    ksl, vsl, f"blocks.{i}")
                k_list.append(k2); v_list.append(v2)
                ks_list.append(ks2); vs_list.append(vs2)
            k_c = jnp.stack(k_list); v_c = jnp.stack(v_list)
            ks_c = jnp.stack(ks_list) if cache.quantized else None
            vs_c = jnp.stack(vs_list) if cache.quantized else None

        state = dict(state)
        state["cache"] = kvc.KVCache(k=k_c, v=v_c, k_scale=ks_c,
                                     v_scale=vs_c, lengths=cache.lengths + 1)
        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x)[:, 0]
        return logits, state
