"""xLSTM blocks: mLSTM (matrix memory) + sLSTM (scalar memory).

* **mLSTM** is linear-attention-like: state ``C (dk, dv)`` with exponential
  input gate and sigmoid-in-log-space forget gate, stabilized by a running
  log-max ``m``.  Sequence processing runs as an exact per-timestep
  ``lax.scan`` (recurrence in f32 — the paper's "keep
  exponential/normalizing math in FP32" rule); the surrounding q/k/v/up/down
  projections are batched matmuls and carry the INT8 quantized path.
* **sLSTM** has per-channel scalar state and head-block recurrent weights —
  inherently sequential, ``lax.scan`` over time.

Both decode steps are O(1)-state updates; ``long_500k`` for xlstm-1.3b runs
entirely through them.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.models.layers import dense, dense_init, layernorm, norm_init


class MLSTMState(NamedTuple):
    C: jax.Array       # (B, H, dk, dv) f32
    n: jax.Array       # (B, H, dk) f32
    m: jax.Array       # (B, H) f32 — log stabilizer


class SLSTMState(NamedTuple):
    c: jax.Array       # (B, d_inner) f32
    n: jax.Array
    h: jax.Array
    m: jax.Array


def _dims(cfg):
    d_inner = 2 * cfg.d_model
    H = cfg.n_heads
    dh = d_inner // H
    return d_inner, H, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, *, stack: tuple = (), dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, dh = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d, 2 * d_inner, dtype=dtype, stack=stack),
        "q_proj": dense_init(ks[1], d_inner, d_inner, dtype=dtype, stack=stack),
        "k_proj": dense_init(ks[2], d_inner, d_inner, dtype=dtype, stack=stack),
        "v_proj": dense_init(ks[3], d_inner, d_inner, dtype=dtype, stack=stack),
        "gate_ssm_if": dense_init(ks[4], d_inner, 2 * H, bias=True,
                                  dtype=dtype, stack=stack),
        "down_proj": dense_init(ks[5], d_inner, d, dtype=dtype, stack=stack),
        "norm": norm_init(d_inner, "layernorm", stack=stack, dtype=dtype),
    }


def _mlstm_qkvg(params, x, *, site, quant, taps, cfg):
    d_inner, H, dh = _dims(cfg)
    B, S, _ = x.shape
    up = dense(params["up_proj"], x, site=f"{site}/up_proj", quant=quant,
               taps=taps)
    xi, z = jnp.split(up, 2, axis=-1)
    q = dense(params["q_proj"], xi, site=f"{site}/q_proj", quant=quant,
              taps=taps).reshape(B, S, H, dh)
    k = dense(params["k_proj"], xi, site=f"{site}/k_proj", quant=quant,
              taps=taps).reshape(B, S, H, dh) / jnp.sqrt(float(dh))
    v = dense(params["v_proj"], xi, site=f"{site}/v_proj", quant=quant,
              taps=taps).reshape(B, S, H, dh)
    gates = dense(params["gate_ssm_if"], xi, site=f"{site}/gate_ssm_if",
                  quant=quant, taps=taps).astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)       # (B, S, H)
    return q, k, v, i_raw, f_raw, z


def _mlstm_step(state: MLSTMState, q, k, v, i_raw, f_raw):
    """One stabilized recurrence step.  All f32. Shapes: (B,H,dh) / (B,H)."""
    log_f = -jax.nn.softplus(-f_raw)                 # log σ(f̃)
    log_i = i_raw
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_s = jnp.exp(log_f + state.m - m_new)[..., None]
    i_s = jnp.exp(log_i - m_new)[..., None]
    C = state.C * f_s[..., None] + i_s[..., None] * (k[..., :, None]
                                                     * v[..., None, :])
    n = state.n * f_s + i_s * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return MLSTMState(C=C, n=n, m=m_new), h


def mlstm_block_sequential(params, x, *, cfg, site,
                           quant: QuantContext = FP_CONTEXT,
                           taps: Optional[Taps] = None,
                           state: Optional[MLSTMState] = None,
                           return_state: bool = False
                           ) -> Tuple[jax.Array, Optional[MLSTMState]]:
    """Per-timestep reference (exact oracle for the chunked form; O(S) scan
    steps and O(S·dk·dv) backward residuals — tests only, never training)."""
    d_inner, H, dh = _dims(cfg)
    B, S, _ = x.shape
    dt = x.dtype
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(params, x, site=site, quant=quant,
                                           taps=taps, cfg=cfg)
    if state is None:
        state = _init_mlstm_state(B, H, dh)

    def step(s, xs):
        q_t, k_t, v_t, i_t, f_t = xs
        s2, h = _mlstm_step(s, q_t.astype(jnp.float32),
                            k_t.astype(jnp.float32),
                            v_t.astype(jnp.float32), i_t, f_t)
        return s2, h

    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(i_raw, 1, 0),
          jnp.moveaxis(f_raw, 1, 0))
    final, hs = jax.lax.scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_inner).astype(dt)
    return _mlstm_out(params, h, z, site=site, quant=quant, taps=taps), \
        (final if return_state else None)


def _init_mlstm_state(B, H, dh):
    return MLSTMState(
        C=jnp.zeros((B, H, dh, dh), jnp.float32),
        n=jnp.zeros((B, H, dh), jnp.float32),
        m=jnp.full((B, H), -1e30, jnp.float32),
    )


def _mlstm_out(params, h, z, *, site, quant, taps):
    dt = h.dtype
    h = layernorm(params["norm"], h)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    return dense(params["down_proj"], h, site=f"{site}/down_proj",
                 quant=quant, taps=taps)


def mlstm_block(params, x, *, cfg, site, quant: QuantContext = FP_CONTEXT,
                taps: Optional[Taps] = None, state: Optional[MLSTMState] = None,
                return_state: bool = False, unroll: bool = False
                ) -> Tuple[jax.Array, Optional[MLSTMState]]:
    """Chunked-parallel mLSTM (exact, log-space stabilized).

    Within a chunk the recurrence is an attention-like einsum against a
    decay matrix; a ``lax.scan`` over chunks carries (C, n, m) — so training
    saves O(S/Lc) states instead of O(S) (the per-timestep form would need
    a (S, B, H, dk, dv) backward residual stack).
    """
    d_inner, H, dh = _dims(cfg)
    B, S, _ = x.shape
    dt = x.dtype
    Lc = min(cfg.xlstm.chunk if cfg.xlstm else 256, S)
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(params, x, site=site, quant=quant,
                                           taps=taps, cfg=cfg)
    if state is None:
        state = _init_mlstm_state(B, H, dh)

    pad = (-S) % Lc
    if pad:
        padfn = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                                  (a.ndim - 2))
        q, k, v, i_raw, f_raw = map(padfn, (q, k, v, i_raw, f_raw))
    Sp = S + pad
    Nc = Sp // Lc
    qc = q.astype(jnp.float32).reshape(B, Nc, Lc, H, dh)
    kc = k.astype(jnp.float32).reshape(B, Nc, Lc, H, dh)
    vc = v.astype(jnp.float32).reshape(B, Nc, Lc, H, dh)
    log_f = -jax.nn.softplus(-f_raw.reshape(B, Nc, Lc, H))   # log σ(f̃)
    log_i = i_raw.reshape(B, Nc, Lc, H)
    if pad:  # padded steps: forget=1 (log 0), input=-inf (no contribution)
        pos = jnp.arange(Sp).reshape(Nc, Lc)
        valid = (pos < S)[None, :, :, None]
        log_f = jnp.where(valid, log_f, 0.0)
        log_i = jnp.where(valid, log_i, -1e30)
    cum = jnp.cumsum(log_f, axis=2)                          # (B,Nc,Lc,H)
    a = log_i - cum                                          # log i_j - cum_j

    tril = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(carry, xs):
        C_hat, n_hat, m = carry              # (B,H,dk,dv),(B,H,dk),(B,H)
        q_c, k_c, v_c, cum_c, a_c = xs       # (B,Lc,H,·)
        # per-position stabilizer: b_i = max(m, cummax_j<=i a_j)
        b = jnp.maximum(m[:, None, :],
                        jax.lax.cummax(a_c, axis=1))         # (B,Lc,H)
        scores = jnp.einsum("bihd,bjhd->bijh", q_c, k_c)     # (B,i,j,H)
        W = jnp.exp(a_c[:, None, :, :] - b[:, :, None, :])
        W = jnp.where(tril[None, :, :, None], W, 0.0)
        sw = scores * W
        num = jnp.einsum("bijh,bjhv->bihv", sw, v_c)
        den = jnp.sum(sw, axis=2)                            # (B,i,H)
        inter = jnp.exp(m[:, None, :] - b)                   # (B,Lc,H)
        num = num + jnp.einsum("bihd,bhdv->bihv", q_c, C_hat) \
            * inter[..., None]
        den = den + jnp.einsum("bihd,bhd->bih", q_c, n_hat) * inter
        m_i = cum_c + b                                      # full exponent
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

        # chunk-end state
        F = cum_c[:, -1, :]                                  # (B,H)
        b_L = jnp.maximum(m, jnp.max(a_c, axis=1))           # (B,H)
        w_j = jnp.exp(a_c - b_L[:, None, :])                 # (B,Lc,H)
        decay = jnp.exp(m - b_L)
        C_new = C_hat * decay[..., None, None] + jnp.einsum(
            "bjhd,bjh,bjhv->bhdv", k_c, w_j, v_c)
        n_new = n_hat * decay[..., None] + jnp.einsum(
            "bjhd,bjh->bhd", k_c, w_j)
        m_new = F + b_L
        return (C_new, n_new, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0)
               for t in (qc, kc, vc, cum, a))
    if unroll:  # roofline cost extraction (trace-time loop)
        carry, hs_list = tuple(state), []
        for i in range(Nc):
            carry, h_i = chunk_step(carry, tuple(t[i] for t in xs))
            hs_list.append(h_i)
        (C_f, n_f, m_f), hs = carry, jnp.stack(hs_list)
    else:
        (C_f, n_f, m_f), hs = jax.lax.scan(jax.checkpoint(chunk_step),
                                           tuple(state), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H, dh)[:, :S]
    h = h.reshape(B, S, d_inner).astype(dt)
    out = _mlstm_out(params, h, z, site=site, quant=quant, taps=taps)
    final = MLSTMState(C=C_f, n=n_f, m=m_f) if return_state else None
    return out, final


def mlstm_decode_step(params, x, state: MLSTMState, *, cfg, site,
                      quant: QuantContext = FP_CONTEXT
                      ) -> Tuple[jax.Array, MLSTMState]:
    d_inner, H, dh = _dims(cfg)
    B = x.shape[0]
    dt = x.dtype
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(params, x, site=site, quant=quant,
                                           taps=None, cfg=cfg)
    s2, h = _mlstm_step(state, q[:, 0].astype(jnp.float32),
                        k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32),
                        i_raw[:, 0], f_raw[:, 0])
    h = h.reshape(B, 1, d_inner).astype(dt)
    h = layernorm(params["norm"], h)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    out = dense(params["down_proj"], h, site=f"{site}/down_proj", quant=quant)
    return out, s2


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, *, stack: tuple = (), dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, dh = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 4 * d_inner, bias=True, dtype=dtype,
                              stack=stack),
        # recurrent weights, block-diagonal per head: (H, dh, 4*dh)
        "r_weight": jax.random.normal(ks[1], (*stack, H, dh, 4 * dh),
                                      dtype) * 0.05,
        "down_proj": dense_init(ks[2], d_inner, d, dtype=dtype, stack=stack),
        "norm": norm_init(d_inner, "layernorm", stack=stack, dtype=dtype),
    }


def _slstm_step(s: SLSTMState, wx_t, r_w, H, dh):
    """wx_t: (B, 4*d_inner) input contribution; r_w: (H, dh, 4*dh)."""
    B = wx_t.shape[0]
    h_heads = s.h.reshape(B, H, dh)
    rh = jnp.einsum("bhd,hde->bhe", h_heads, r_w).reshape(B, -1)
    raw = (wx_t + rh).reshape(B, H, 4, dh)
    z_r, i_r, f_r, o_r = raw[:, :, 0], raw[:, :, 1], raw[:, :, 2], raw[:, :, 3]
    z_r, i_r, f_r, o_r = (a.reshape(B, -1) for a in (z_r, i_r, f_r, o_r))

    log_f = -jax.nn.softplus(-f_r)
    m_new = jnp.maximum(log_f + s.m, i_r)
    f_s = jnp.exp(log_f + s.m - m_new)
    i_s = jnp.exp(i_r - m_new)
    c = f_s * s.c + i_s * jnp.tanh(z_r)
    n = f_s * s.n + i_s
    h = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_block(params, x, *, cfg, site, quant: QuantContext = FP_CONTEXT,
                taps: Optional[Taps] = None, state: Optional[SLSTMState] = None,
                return_state: bool = False
                ) -> Tuple[jax.Array, Optional[SLSTMState]]:
    d_inner, H, dh = _dims(cfg)
    B, S, _ = x.shape
    dt = x.dtype
    wx = dense(params["in_proj"], x, site=f"{site}/in_proj", quant=quant,
               taps=taps).astype(jnp.float32)               # (B, S, 4*d_inner)
    if state is None:
        z = jnp.zeros((B, d_inner), jnp.float32)
        state = SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))

    r_w = params["r_weight"].astype(jnp.float32)

    def step(s, wx_t):
        s2 = _slstm_step(s, wx_t, r_w, H, dh)
        return s2, s2.h

    final, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(dt)                   # (B, S, d_inner)
    h = layernorm(params["norm"], h)
    out = dense(params["down_proj"], h, site=f"{site}/down_proj", quant=quant,
                taps=taps)
    return out, (final if return_state else None)


def slstm_decode_step(params, x, state: SLSTMState, *, cfg, site,
                      quant: QuantContext = FP_CONTEXT
                      ) -> Tuple[jax.Array, SLSTMState]:
    d_inner, H, dh = _dims(cfg)
    B = x.shape[0]
    dt = x.dtype
    wx = dense(params["in_proj"], x, site=f"{site}/in_proj", quant=quant
               ).astype(jnp.float32)[:, 0]
    s2 = _slstm_step(state, wx, params["r_weight"].astype(jnp.float32), H, dh)
    h = s2.h.reshape(B, 1, d_inner).astype(dt)
    h = layernorm(params["norm"], h)
    out = dense(params["down_proj"], h, site=f"{site}/down_proj", quant=quant)
    return out, s2
