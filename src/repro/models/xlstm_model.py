"""xLSTM LM (ssm family): pre-norm residual stack of mLSTM blocks with a
sLSTM block every ``slstm_every`` layers (the xLSTM paper's [7:1] mix).

Scan path groups layers as (slstm_every-1 mLSTM + 1 sLSTM) so parameters of
each kind stack homogeneously.  All recurrent state is O(1) in sequence
length → this arch runs the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.distributed.context import constrain
from repro.models.layers import embed, embedding_init, norm, norm_init, unembed
from repro.models.xlstm import (
    MLSTMState,
    SLSTMState,
    mlstm_block,
    mlstm_decode_step,
    mlstm_init,
    slstm_block,
    slstm_decode_step,
    slstm_init,
)


class XLSTMLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.every = cfg.xlstm.slstm_every
        assert cfg.n_layers % self.every == 0, \
            "n_layers must divide by slstm_every for the scan path"
        self.n_groups = cfg.n_layers // self.every
        self.m_per_group = self.every - 1

    def _is_slstm(self, i: int) -> bool:
        return (i + 1) % self.every == 0

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_e, k_m, k_s = jax.random.split(key, 3)
        params: Dict[str, Any] = {
            "embed": embedding_init(k_e, cfg.vocab, cfg.d_model),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
        }
        if cfg.scan_layers:
            params["mlstm"] = {
                "pre_norm": norm_init(cfg.d_model, cfg.norm,
                                      stack=(self.n_groups,
                                             self.m_per_group)),
                **mlstm_init(k_m, cfg, stack=(self.n_groups,
                                              self.m_per_group)),
            }
            params["slstm"] = {
                "pre_norm": norm_init(cfg.d_model, cfg.norm,
                                      stack=(self.n_groups,)),
                **slstm_init(k_s, cfg, stack=(self.n_groups,)),
            }
        else:
            km = jax.random.split(k_m, cfg.n_layers)
            for i in range(cfg.n_layers):
                if self._is_slstm(i):
                    params[f"blocks.{i}"] = {
                        "pre_norm": norm_init(cfg.d_model, cfg.norm),
                        **slstm_init(km[i], cfg),
                    }
                else:
                    params[f"blocks.{i}"] = {
                        "pre_norm": norm_init(cfg.d_model, cfg.norm),
                        **mlstm_init(km[i], cfg),
                    }
        return params

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, *, quant: QuantContext = FP_CONTEXT,
                taps: Optional[Taps] = None, unroll: bool = False
                ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], cfg.activation_dtype)

        if cfg.scan_layers:
            def group(x, gp):
                mp, sp = gp

                def inner(x, bp):
                    f = lambda xx: xx + mlstm_block(
                        bp, norm(bp["pre_norm"], xx, cfg.norm), cfg=cfg,
                        site="blocks.*/mlstm", quant=quant, taps=taps,
                        unroll=unroll)[0]
                    if cfg.remat:
                        f = jax.checkpoint(f)
                    return f(constrain(x)), None

                x, _ = jax.lax.scan(inner, x, mp)
                g = lambda xx: xx + slstm_block(
                    sp, norm(sp["pre_norm"], xx, cfg.norm), cfg=cfg,
                    site="blocks.*/slstm", quant=quant, taps=taps)[0]
                if cfg.remat:
                    # without remat the 4096-step sLSTM scan's residuals for
                    # every group stay live through the whole forward
                    g = jax.checkpoint(g)
                return g(x), None

            x, _ = jax.lax.scan(group, x, (params["mlstm"], params["slstm"]))
        else:
            for i in range(cfg.n_layers):
                bp = params[f"blocks.{i}"]
                h = norm(bp["pre_norm"], x, cfg.norm)
                if self._is_slstm(i):
                    y, _ = slstm_block(bp, h, cfg=cfg,
                                       site=f"blocks.{i}/slstm",
                                       quant=quant, taps=taps)
                else:
                    y, _ = mlstm_block(bp, h, cfg=cfg,
                                       site=f"blocks.{i}/mlstm",
                                       quant=quant, taps=taps,
                                       unroll=unroll)
                x = x + y

        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["embed"], x), {}

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, max_len: int, *,
                          quantized: bool) -> Dict[str, Any]:
        cfg = self.cfg
        d_inner = 2 * cfg.d_model
        H = cfg.n_heads
        dh = d_inner // H
        G, M = self.n_groups, self.m_per_group
        return {
            "mlstm": MLSTMState(
                C=jnp.zeros((G, M, batch, H, dh, dh), jnp.float32),
                n=jnp.zeros((G, M, batch, H, dh), jnp.float32),
                m=jnp.full((G, M, batch, H), -1e30, jnp.float32),
            ),
            "slstm": SLSTMState(
                c=jnp.zeros((G, batch, d_inner), jnp.float32),
                n=jnp.zeros((G, batch, d_inner), jnp.float32),
                h=jnp.zeros((G, batch, d_inner), jnp.float32),
                m=jnp.full((G, batch, d_inner), -1e30, jnp.float32),
            ),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, params, batch, state, *,
                quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        """Sequence prefill: run blocks with return_state (unrolled)."""
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], cfg.activation_dtype)
        B, S, _ = x.shape
        lengths = batch.get("lengths", jnp.full((B,), S, jnp.int32))

        G, M = self.n_groups, self.m_per_group
        state = dict(state)
        if cfg.scan_layers:
            def group(x, gp):
                mp, sp = gp

                def inner(x, bp):
                    h = norm(bp["pre_norm"], x, cfg.norm)
                    y, st = mlstm_block(bp, h, cfg=cfg,
                                        site="blocks.*/mlstm", quant=quant,
                                        return_state=True)
                    return x + y, st

                x, msts = jax.lax.scan(inner, x, mp)
                h = norm(sp["pre_norm"], x, cfg.norm)
                y, sst = slstm_block(sp, h, cfg=cfg, site="blocks.*/slstm",
                                     quant=quant, return_state=True)
                return x + y, (msts, sst)

            x, (m_st, s_st) = jax.lax.scan(
                group, x, (params["mlstm"], params["slstm"]))
            state["mlstm"], state["slstm"] = m_st, s_st
        else:
            m_states, s_states = [], []
            for i in range(cfg.n_layers):
                bp = params[f"blocks.{i}"]
                h = norm(bp["pre_norm"], x, cfg.norm)
                if self._is_slstm(i):
                    y, st = slstm_block(bp, h, cfg=cfg,
                                        site=f"blocks.{i}/slstm",
                                        quant=quant, return_state=True)
                    s_states.append(st)
                else:
                    y, st = mlstm_block(bp, h, cfg=cfg,
                                        site=f"blocks.{i}/mlstm",
                                        quant=quant, return_state=True)
                    m_states.append(st)
                x = x + y
            stack = lambda xs: jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *xs)
            m_flat = stack(m_states)      # (G*M, ...) in layer order
            state["mlstm"] = jax.tree_util.tree_map(
                lambda a: a.reshape(G, M, *a.shape[1:]), m_flat)
            state["slstm"] = stack(s_states)
        state["lengths"] = lengths

        x = norm(params["final_norm"], x, cfg.norm)
        idx = jnp.maximum(lengths - 1, 0)
        x_last = x[jnp.arange(B), idx]
        return unembed(params["embed"], x_last[:, None, :])[:, 0], state

    def decode_step(self, params, tokens, state, *,
                    quant: QuantContext = FP_CONTEXT) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None], cfg.activation_dtype)

        if cfg.scan_layers:
            def group(x, xs):
                mp, sp, mst, sst = xs

                def inner(x, ys):
                    bp, st = ys
                    h = norm(bp["pre_norm"], x, cfg.norm)
                    y, st2 = mlstm_decode_step(bp, h, st, cfg=cfg,
                                               site="blocks.*/mlstm",
                                               quant=quant)
                    return x + y, st2

                x, mst2 = jax.lax.scan(inner, x, (mp, mst))
                h = norm(sp["pre_norm"], x, cfg.norm)
                y, sst2 = slstm_decode_step(sp, h, sst, cfg=cfg,
                                            site="blocks.*/slstm",
                                            quant=quant)
                return x + y, (mst2, sst2)

            x, (m2, s2) = jax.lax.scan(
                group, x, (params["mlstm"], params["slstm"],
                           state["mlstm"], state["slstm"]))
        else:
            m_states, s_states = [], []
            mi = si = 0
            for i in range(cfg.n_layers):
                g, j = divmod(i, self.every)
                bp = params[f"blocks.{i}"]
                h = norm(bp["pre_norm"], x, cfg.norm)
                if self._is_slstm(i):
                    st = jax.tree_util.tree_map(lambda a: a[g],
                                                state["slstm"])
                    y, st2 = slstm_decode_step(bp, h, st, cfg=cfg,
                                               site=f"blocks.{i}/slstm",
                                               quant=quant)
                    s_states.append(st2)
                else:
                    st = jax.tree_util.tree_map(lambda a: a[g][j],
                                                state["mlstm"])
                    y, st2 = mlstm_decode_step(bp, h, st, cfg=cfg,
                                               site=f"blocks.{i}/mlstm",
                                               quant=quant)
                    m_states.append(st2)
                x = x + y
            G, M = self.n_groups, self.m_per_group
            stack = lambda xs: jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *xs)
            m2 = jax.tree_util.tree_map(
                lambda a: a.reshape(G, M, *a.shape[1:]), stack(m_states))
            s2 = stack(s_states)

        state = dict(state)
        state["mlstm"], state["slstm"] = m2, s2
        state["lengths"] = state["lengths"] + 1
        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["embed"], x)[:, 0], state
