"""Model registry: ModelConfig.family → model class.

Every model exposes the same protocol:

    model = build_model(cfg)
    params           = model.init(key)
    logits, aux      = model.forward(params, batch, quant=..., taps=...)
    state            = model.init_decode_state(B, max_len, quantized=...)
    logits, state    = model.prefill(params, batch, state, quant=...)
    logits, state    = model.decode_step(params, tokens, state, quant=...)
"""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.transformer import DecoderLM
from repro.models.xlstm_model import XLSTMLM

_FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": DecoderLM,
    "audio": EncDecLM,
    "hybrid": HybridLM,
    "ssm": XLSTMLM,
}


def build_model(cfg: ModelConfig):
    if cfg.family not in _FAMILIES:
        raise KeyError(f"unknown family {cfg.family}")
    return _FAMILIES[cfg.family](cfg)
