"""Mamba2 (SSD) block — the zamba2 backbone.

Implements the chunked state-space-dual algorithm: within a chunk the output
is an attention-like einsum against a lower-triangular decay matrix; across
chunks a ``lax.scan`` carries the (H, N, P) state.  Decode is the O(1)
recurrence.  Chunking keeps live memory at O(S·Lc) per head instead of
O(S²), and the scan keeps the HLO depth-independent.

Quantization (paper technique applied per DESIGN §Arch-applicability): the
in/out projections are quantizable ``dense`` sites; the recurrence itself —
exp/softplus/divisions — stays f32, the paper's "Softmax & LayerNorm stay
FP32" rule transplanted to SSMs.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import Taps
from repro.core.ptq import FP_CONTEXT, QuantContext
from repro.models.layers import dense, dense_init, rmsnorm


class SSMState(NamedTuple):
    h: jax.Array          # (B, H, N, P) f32 — SSM state
    conv: jax.Array       # (B, W-1, d_conv) activation dtype — conv tail


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return s, d_inner, n_heads


def ssm_init(key, cfg, *, stack: tuple = (), dtype=jnp.float32):
    s, d_inner, H = _dims(cfg)
    N = s.state
    k1, k2, k3 = jax.random.split(key, 3)
    # packed in-projection: [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (H)]
    d_proj = 2 * d_inner + 2 * N + H
    return {
        "in_proj": dense_init(k1, cfg.d_model, d_proj, dtype=dtype, stack=stack),
        "out_proj": dense_init(k2, d_inner, cfg.d_model, dtype=dtype,
                               stack=stack),
        "conv_w": jax.random.normal(k3, (*stack, s.conv_width, d_inner),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((*stack, d_inner), dtype),
        "A_log": jnp.zeros((*stack, H), dtype),            # A = -exp(A_log)
        "D_skip": jnp.ones((*stack, H), dtype),
        "dt_bias": jnp.zeros((*stack, H), dtype),
        "norm": {"scale": jnp.ones((*stack, d_inner), dtype)},
    }


def _split_proj(proj, d_inner: int, N: int, H: int):
    z = proj[..., :d_inner]
    xs = proj[..., d_inner:2 * d_inner]
    Bm = proj[..., 2 * d_inner:2 * d_inner + N]
    Cm = proj[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N:]
    return z, xs, Bm, Cm, dt


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv along sequence. x: (B,S,Dc); w: (W,Dc)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_tail = xp[:, -(W - 1):] if W > 1 else tail
    return out + b, new_tail


def ssm_block(
    params,
    x: jax.Array,                   # (B, S, D)
    *,
    cfg,
    site: str,
    quant: QuantContext = FP_CONTEXT,
    taps: Optional[Taps] = None,
    state: Optional[SSMState] = None,
    return_state: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[SSMState]]:
    """Full-sequence (train/prefill) Mamba2 block.  Chunked SSD."""
    s, d_inner, H = _dims(cfg)
    N, P, Lc = s.state, s.head_dim, s.chunk
    B, S, D = x.shape
    dt_ = x.dtype

    proj = dense(params["in_proj"], x, site=f"{site}/in_proj", quant=quant,
                 taps=taps)
    z, xs, Bm, Cm, dt = _split_proj(proj, d_inner, N, H)

    conv_tail = state.conv if state is not None else None
    xs, new_tail = _causal_conv(xs, params["conv_w"].astype(dt_),
                                params["conv_b"].astype(dt_), conv_tail)
    xs = jax.nn.silu(xs.astype(jnp.float32))

    # heads
    xh = xs.reshape(B, S, H, P)                                  # f32
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (H,)
    Bf = Bm.astype(jnp.float32)                                  # (B,S,N)
    Cf = Cm.astype(jnp.float32)

    # chunking
    pad = (-S) % Lc
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
        Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    Nc = Sp // Lc
    xh = xh.reshape(B, Nc, Lc, H, P)
    dtc = dt.reshape(B, Nc, Lc, H)
    Bc = Bf.reshape(B, Nc, Lc, N)
    Cc = Cf.reshape(B, Nc, Lc, N)

    dA = dtc * A                                               # (B,Nc,Lc,H)
    cum = jnp.cumsum(dA, axis=2)                               # within-chunk

    h0 = (state.h if state is not None
          else jnp.zeros((B, H, N, P), jnp.float32))

    def chunk_step(h, xs_c):
        x_c, dt_c, B_c, C_c, cum_c = xs_c
        # x_c: (B,Lc,H,P); B_c/C_c: (B,Lc,N); cum_c: (B,Lc,H)
        xbar = x_c * dt_c[..., None]                           # (B,Lc,H,P)
        # intra-chunk: y[i] = Σ_{j<=i} C_i·B_j exp(cum_i - cum_j) x̄_j
        decay = jnp.exp(cum_c[:, :, None, :] - cum_c[:, None, :, :])
        tri = jnp.tril(jnp.ones((x_c.shape[1], x_c.shape[1]), bool))
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)   # (B,Lc,Lc,H)
        scores = jnp.einsum("bin,bjn->bij", C_c, B_c)          # (B,Lc,Lc)
        y_diag = jnp.einsum("bij,bijh,bjhp->bihp", scores, decay, xbar)
        # inter-chunk: y[i] += C_i · h_prev · exp(cum_i)
        y_off = jnp.einsum("bin,bhnp,bih->bihp", C_c, h,
                           jnp.exp(cum_c))
        # state update: h = h·exp(cum_last) + Σ_j exp(cum_last - cum_j) B_j x̄ᵀ
        last = cum_c[:, -1, :]                                 # (B,H)
        h_decay = jnp.exp(last)[:, :, None, None]
        chunk_state = jnp.einsum("bjn,bjh,bjhp->bhnp", B_c,
                                 jnp.exp(last[:, None, :] - cum_c), xbar)
        h_new = h * h_decay + chunk_state
        return h_new, y_diag + y_off

    xs_seq = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dtc, 1, 0),
              jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0),
              jnp.moveaxis(cum, 1, 0))
    if unroll:  # roofline cost extraction (see EXPERIMENTS.md §Roofline)
        h, ys = h0, []
        for i in range(Nc):
            h, y_i = chunk_step(h, tuple(a[i] for a in xs_seq))
            ys.append(y_i)
        h_final, y = h, jnp.stack(ys, axis=0)
    else:
        h_final, y = jax.lax.scan(chunk_step, h0, xs_seq)
    y = jnp.moveaxis(y, 0, 1).reshape(B, Sp, H, P)[:, :S]

    y = y + params["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.reshape(B, Sp, H, P)[:, :S]
    y = y.reshape(B, S, d_inner)

    # gated RMSNorm then out-projection (recurrence output normalizer f32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], y.astype(dt_))
    out = dense(params["out_proj"], y, site=f"{site}/out_proj", quant=quant,
                taps=taps)

    new_state = None
    if return_state:
        new_state = SSMState(h=h_final, conv=new_tail)
    return out, new_state


def ssm_decode_step(
    params,
    x: jax.Array,                   # (B, 1, D)
    state: SSMState,
    *,
    cfg,
    site: str,
    quant: QuantContext = FP_CONTEXT,
) -> Tuple[jax.Array, SSMState]:
    """O(1) single-token recurrence: h = h·exp(A·dt) + B x̄ᵀ ; y = C·h."""
    s, d_inner, H = _dims(cfg)
    N, P = s.state, s.head_dim
    B = x.shape[0]
    dt_ = x.dtype

    proj = dense(params["in_proj"], x, site=f"{site}/in_proj", quant=quant)
    z, xs, Bm, Cm, dt = _split_proj(proj, d_inner, N, H)

    xs, new_tail = _causal_conv(xs, params["conv_w"].astype(dt_),
                                params["conv_b"].astype(dt_), state.conv)
    xs = jax.nn.silu(xs.astype(jnp.float32))

    xh = xs.reshape(B, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                    # (B,H)
    xbar = xh * dt[..., None]                                  # (B,H,P)
    Bf = Bm.astype(jnp.float32)[:, 0]                          # (B,N)
    Cf = Cm.astype(jnp.float32)[:, 0]

    h = state.h * decay[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bf, xbar)
    y = jnp.einsum("bn,bhnp->bhp", Cf, h)
    y = y + params["D_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], y.astype(dt_))
    out = dense(params["out_proj"], y, site=f"{site}/out_proj", quant=quant)
    return out, SSMState(h=h, conv=new_tail)
