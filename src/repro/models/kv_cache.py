"""KV cache with optional INT8 payload (paper §5.3, TPU-adapted).

The paper found the decoder while-loop's GatherNd (beam-search cache
reordering) dominated by memory copies and quantized it for a 3.8× copy-size
reduction.  On TPU the same traffic appears twice per decode step:

* every attention read streams the whole cache from HBM, and
* beam reordering gathers it along the batch axis.

Keeping the cache int8 (per-token per-head symmetric scales, computed when
the token is appended — one cheap amax over head_dim) cuts both 4× vs f32.

Ragged batches: sequences in a decode batch may have different lengths.
Appends scatter each sequence's new token at its own ``lengths[b]`` cursor,
so token-sorted (but not exactly equal-length) batches — the paper's §5.4
input pipeline — decode correctly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
_EPS = 1e-12


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """Fixed-capacity cache for one attention stack (layers stacked).

    ``k``/``v``: (L, B, S_max, HKV, dh) int8 or activation dtype.
    ``k_scale``/``v_scale``: (L, B, S_max, HKV) f32, or None (fp cache).
    ``lengths``: (B,) int32 valid lengths / per-sequence write cursors.
    """

    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    lengths: jax.Array

    def tree_flatten(self):
        return ((self.k, self.v, self.k_scale, self.v_scale, self.lengths),
                None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def capacity(self) -> int:
        return self.k.shape[2]

    def nbytes(self) -> int:
        n = self.k.size * self.k.dtype.itemsize * 2
        if self.quantized:
            n += self.k_scale.size * 4 * 2
        return int(n)


def init_cache(n_layers: int, batch: int, max_len: int, n_kv: int, dh: int,
               *, quantized: bool, dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, batch, max_len, n_kv, dh)
    if quantized:
        k = jnp.zeros(shape, jnp.int8)
        v = jnp.zeros(shape, jnp.int8)
        ks = jnp.zeros(shape[:-1], jnp.float32)
        vs = jnp.zeros(shape[:-1], jnp.float32)
    else:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
        ks = vs = None
    return KVCache(k=k, v=v, k_scale=ks, v_scale=vs,
                   lengths=jnp.zeros((batch,), jnp.int32))


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-token per-head symmetric quantization: (…, dh) → int8 + scale."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), _EPS)
    scale = amax / INT8_MAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


@dataclasses.dataclass(frozen=True)
class LayerCacheView:
    """One layer's slice, as consumed by attention."""

    k: jax.Array            # (B, S, HKV, dh)
    v: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    lengths: jax.Array      # (B,)

    def dequantized(self, dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        if self.k_scale is None:
            return self.k.astype(dtype), self.v.astype(dtype)
        k = self.k.astype(jnp.float32) * self.k_scale[..., None]
        v = self.v.astype(jnp.float32) * self.v_scale[..., None]
        return k.astype(dtype), v.astype(dtype)


def fill_prefix(
    k_cache: jax.Array,                  # (B, S_max, HKV, dh)
    v_cache: jax.Array,
    ks_cache: Optional[jax.Array],
    vs_cache: Optional[jax.Array],
    k_new: jax.Array,                    # (B, T, HKV, dh) fp — prefill block
    v_new: jax.Array,
):
    """Write the prefill's K/V at positions [0, T) (right-padded batches)."""
    if ks_cache is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, 0, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, 0, 1)
        ks_cache = jax.lax.dynamic_update_slice_in_dim(ks_cache, ks, 0, 1)
        vs_cache = jax.lax.dynamic_update_slice_in_dim(vs_cache, vs, 0, 1)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), 0, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), 0, 1)
    return k_cache, v_cache, ks_cache, vs_cache


def append_token(
    k_cache: jax.Array,                  # (B, S_max, HKV, dh)
    v_cache: jax.Array,
    ks_cache: Optional[jax.Array],
    vs_cache: Optional[jax.Array],
    k_new: jax.Array,                    # (B, 1, HKV, dh) fp
    v_new: jax.Array,
    lengths: jax.Array,                  # (B,) per-sequence cursors
):
    """Scatter one new token per sequence at its own cursor (ragged decode).

    ``mode="drop"`` is load-bearing for decode bursts: rows that finished
    mid-burst keep stepping with cursors at/past capacity until the burst
    edge, and their writes must vanish rather than clamp onto the last
    valid position (which could corrupt a still-live neighbour of a
    shared-capacity cache on backends where clamping is the default).
    """
    b_idx = jnp.arange(k_cache.shape[0])
    if ks_cache is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_cache = k_cache.at[b_idx, lengths].set(kq[:, 0], mode="drop")
        v_cache = v_cache.at[b_idx, lengths].set(vq[:, 0], mode="drop")
        ks_cache = ks_cache.at[b_idx, lengths].set(ks[:, 0], mode="drop")
        vs_cache = vs_cache.at[b_idx, lengths].set(vs[:, 0], mode="drop")
    else:
        k_cache = k_cache.at[b_idx, lengths].set(
            k_new[:, 0].astype(k_cache.dtype), mode="drop")
        v_cache = v_cache.at[b_idx, lengths].set(
            v_new[:, 0].astype(v_cache.dtype), mode="drop")
    return k_cache, v_cache, ks_cache, vs_cache


def insert_at_slots(cache: KVCache, sub: KVCache,
                    slots: jax.Array) -> KVCache:
    """Scatter ``sub``'s batch rows into ``slots`` of the running cache.

    The continuous-batching engine (``serving/engine.py``) prefills newly
    admitted requests as a small side batch and splices the resulting rows
    into the long-lived decode cache mid-flight, so a finished sequence's
    slot is refilled instead of idling until the batch drains.

    ``slots``: (B_sub,) int32 destination rows, unique.  Works for both FP
    and INT8 caches (both sides must agree); out-of-range slot indices are
    dropped (jax scatter semantics), which the engine uses to pad admission
    groups to a fixed compile-stable width.
    """
    if cache.quantized != sub.quantized:
        raise ValueError("cannot mix quantized and fp caches "
                         f"(main quantized={cache.quantized}, "
                         f"sub quantized={sub.quantized})")
    if cache.capacity != sub.capacity:
        raise ValueError(f"capacity mismatch: {cache.capacity} vs "
                         f"{sub.capacity}")
    slots = jnp.asarray(slots, jnp.int32)
    put = lambda main, part: (None if main is None
                              else main.at[:, slots].set(
                                  part.astype(main.dtype)))
    return KVCache(
        k=put(cache.k, sub.k), v=put(cache.v, sub.v),
        k_scale=put(cache.k_scale, sub.k_scale),
        v_scale=put(cache.v_scale, sub.v_scale),
        lengths=cache.lengths.at[slots].set(sub.lengths),
    )


def free_slots(cache: KVCache, slots: jax.Array) -> KVCache:
    """Mark ``slots`` empty by resetting their write cursors to zero.

    The payload is left in place — every read (attention, gathers) is
    masked by ``lengths``, and the next ``insert_at_slots`` overwrites the
    rows wholesale — so eviction is a (B,)-sized scatter, not a cache copy.
    """
    slots = jnp.asarray(slots, jnp.int32)
    return KVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        lengths=cache.lengths.at[slots].set(0),
    )


def free_inactive(cache: KVCache, live: jax.Array) -> KVCache:
    """Mask-driven ``free_slots`` for use *inside* a jitted burst program.

    ``live``: (B,) bool — rows whose cursor must be preserved.  Every other
    row (finished since the last admission, or never occupied) gets its
    write cursor reset to 0, exactly what the host-dispatched
    ``free_slots`` did between bursts before admissions were fused into
    the burst program.  Payload untouched — reads are length-masked and
    the next ``splice_prefill``/``insert_at_slots`` overwrites the rows.
    """
    return KVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        lengths=jnp.where(live, cache.lengths, 0),
    )


def group_rows(base_slots: jax.Array, group: int) -> jax.Array:
    """Expand group base rows to the strided row set they own.

    ``base_slots``: (G,) int32 group base rows (multiples of ``group`` for
    in-range entries) → (G * group,) row indices ``base + [0, group)``.
    An out-of-range sentinel base (≥ the cache batch) expands to ``group``
    out-of-range rows, so the padding convention of ``insert_at_slots``
    (OOB rows are dropped by jax scatter semantics) carries over to whole
    groups.
    """
    base = jnp.asarray(base_slots, jnp.int32)
    return (base[:, None] + jnp.arange(group, dtype=jnp.int32)[None, :]
            ).reshape(-1)


def insert_at_groups(cache: KVCache, sub: KVCache, base_slots: jax.Array,
                     group: int) -> KVCache:
    """Group-strided ``insert_at_slots``: splice whole beam groups.

    ``sub`` holds ``len(base_slots) * group`` batch rows — ``group``
    contiguous rows per admitted request — scattered into rows
    ``[base, base + group)`` of each base slot.  Works for FP and INT8
    caches exactly like ``insert_at_slots`` (it is one).
    """
    return insert_at_slots(cache, sub, group_rows(base_slots, group))


def free_groups(cache: KVCache, base_slots: jax.Array, group: int) -> KVCache:
    """Group-strided ``free_slots``: a finishing beam group frees all
    ``group`` of its rows atomically (cursor reset only — see
    ``free_slots`` for why no payload copy happens)."""
    return free_slots(cache, group_rows(base_slots, group))


def gather_beams(cache: KVCache, beam_idx: jax.Array) -> KVCache:
    """Beam-search cache reorder along batch — the paper's GatherNd.

    ``beam_idx``: (B,) int32 source rows.  On an int8 cache this moves 4×
    fewer bytes than f32 (2× vs bf16); ``benchmarks/bench_kv_gather.py``
    measures exactly this op.
    """
    take = lambda a: jnp.take(a, beam_idx, axis=1) if a is not None else None
    return KVCache(
        k=take(cache.k), v=take(cache.v),
        k_scale=take(cache.k_scale), v_scale=take(cache.v_scale),
        lengths=jnp.take(cache.lengths, beam_idx, axis=0),
    )
