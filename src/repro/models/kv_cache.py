"""KV cache with optional INT8 payload (paper §5.3, TPU-adapted).

The paper found the decoder while-loop's GatherNd (beam-search cache
reordering) dominated by memory copies and quantized it for a 3.8× copy-size
reduction.  On TPU the same traffic appears twice per decode step:

* every attention read streams the whole cache from HBM, and
* beam reordering gathers it along the batch axis.

Keeping the cache int8 (per-token per-head symmetric scales, computed when
the token is appended — one cheap amax over head_dim) cuts both 4× vs f32.

Ragged batches: sequences in a decode batch may have different lengths.
Appends scatter each sequence's new token at its own ``lengths[b]`` cursor,
so token-sorted (but not exactly equal-length) batches — the paper's §5.4
input pipeline — decode correctly.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
_EPS = 1e-12


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """Fixed-capacity cache for one attention stack (layers stacked).

    ``k``/``v``: (L, B, S_max, HKV, dh) int8 or activation dtype.
    ``k_scale``/``v_scale``: (L, B, S_max, HKV) f32, or None (fp cache).
    ``lengths``: (B,) int32 valid lengths / per-sequence write cursors.
    """

    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    lengths: jax.Array

    def tree_flatten(self):
        return ((self.k, self.v, self.k_scale, self.v_scale, self.lengths),
                None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def capacity(self) -> int:
        return self.k.shape[2]

    def nbytes(self) -> int:
        n = self.k.size * self.k.dtype.itemsize * 2
        if self.quantized:
            n += self.k_scale.size * 4 * 2
        return int(n)


def init_cache(n_layers: int, batch: int, max_len: int, n_kv: int, dh: int,
               *, quantized: bool, dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, batch, max_len, n_kv, dh)
    if quantized:
        k = jnp.zeros(shape, jnp.int8)
        v = jnp.zeros(shape, jnp.int8)
        ks = jnp.zeros(shape[:-1], jnp.float32)
        vs = jnp.zeros(shape[:-1], jnp.float32)
    else:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
        ks = vs = None
    return KVCache(k=k, v=v, k_scale=ks, v_scale=vs,
                   lengths=jnp.zeros((batch,), jnp.int32))


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-token per-head symmetric quantization: (…, dh) → int8 + scale."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), _EPS)
    scale = amax / INT8_MAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


@dataclasses.dataclass(frozen=True)
class LayerCacheView:
    """One layer's slice, as consumed by attention.

    Contiguous cache: ``k``/``v`` are (B, S, HKV, dh) rows.  Paged cache:
    ``k``/``v`` are the layer's (P, ps, HKV, dh) page pool and
    ``block_tables`` (B, maxP) maps rows to pages (None ⇔ contiguous).
    """

    k: jax.Array            # (B, S, HKV, dh) or (P, ps, HKV, dh) paged
    v: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    lengths: jax.Array      # (B,)
    block_tables: Optional[jax.Array] = None      # (B, maxP) when paged

    def dequantized(self, dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        if self.k_scale is None:
            return self.k.astype(dtype), self.v.astype(dtype)
        k = self.k.astype(jnp.float32) * self.k_scale[..., None]
        v = self.v.astype(jnp.float32) * self.v_scale[..., None]
        return k.astype(dtype), v.astype(dtype)


def fill_prefix(
    k_cache: jax.Array,                  # (B, S_max, HKV, dh)
    v_cache: jax.Array,
    ks_cache: Optional[jax.Array],
    vs_cache: Optional[jax.Array],
    k_new: jax.Array,                    # (B, T, HKV, dh) fp — prefill block
    v_new: jax.Array,
):
    """Write the prefill's K/V at positions [0, T) (right-padded batches)."""
    if ks_cache is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, 0, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, 0, 1)
        ks_cache = jax.lax.dynamic_update_slice_in_dim(ks_cache, ks, 0, 1)
        vs_cache = jax.lax.dynamic_update_slice_in_dim(vs_cache, vs, 0, 1)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), 0, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), 0, 1)
    return k_cache, v_cache, ks_cache, vs_cache


def append_token(
    k_cache: jax.Array,                  # (B, S_max, HKV, dh)
    v_cache: jax.Array,
    ks_cache: Optional[jax.Array],
    vs_cache: Optional[jax.Array],
    k_new: jax.Array,                    # (B, 1, HKV, dh) fp
    v_new: jax.Array,
    lengths: jax.Array,                  # (B,) per-sequence cursors
):
    """Scatter one new token per sequence at its own cursor (ragged decode).

    ``mode="drop"`` is load-bearing for decode bursts: rows that finished
    mid-burst keep stepping with cursors at/past capacity until the burst
    edge, and their writes must vanish rather than clamp onto the last
    valid position (which could corrupt a still-live neighbour of a
    shared-capacity cache on backends where clamping is the default).
    """
    b_idx = jnp.arange(k_cache.shape[0])
    if ks_cache is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_cache = k_cache.at[b_idx, lengths].set(kq[:, 0], mode="drop")
        v_cache = v_cache.at[b_idx, lengths].set(vq[:, 0], mode="drop")
        ks_cache = ks_cache.at[b_idx, lengths].set(ks[:, 0], mode="drop")
        vs_cache = vs_cache.at[b_idx, lengths].set(vs[:, 0], mode="drop")
    else:
        k_cache = k_cache.at[b_idx, lengths].set(
            k_new[:, 0].astype(k_cache.dtype), mode="drop")
        v_cache = v_cache.at[b_idx, lengths].set(
            v_new[:, 0].astype(v_cache.dtype), mode="drop")
    return k_cache, v_cache, ks_cache, vs_cache


def append_tokens(
    k_cache: jax.Array,                  # (B, S_max, HKV, dh)
    v_cache: jax.Array,
    ks_cache: Optional[jax.Array],
    vs_cache: Optional[jax.Array],
    k_new: jax.Array,                    # (B, T, HKV, dh) fp
    v_new: jax.Array,
    lengths: jax.Array,                  # (B,) per-sequence cursors
):
    """Scatter ``T`` consecutive tokens per row starting at its cursor.

    The speculative-decode verify pass appends a whole drafted window at
    once: row b's token t lands at position ``lengths[b] + t``.  Positions
    are distinct within a row so there are no scatter collisions, and the
    same ``mode="drop"`` contract as :func:`append_token` applies — any
    position at/past capacity writes nowhere.
    """
    B, T = k_new.shape[0], k_new.shape[1]
    b_idx = jnp.arange(B)[:, None]
    pos = lengths[:, None] + jnp.arange(T, dtype=lengths.dtype)[None, :]
    if ks_cache is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_cache = k_cache.at[b_idx, pos].set(kq, mode="drop")
        v_cache = v_cache.at[b_idx, pos].set(vq, mode="drop")
        ks_cache = ks_cache.at[b_idx, pos].set(ks, mode="drop")
        vs_cache = vs_cache.at[b_idx, pos].set(vs, mode="drop")
    else:
        k_cache = k_cache.at[b_idx, pos].set(
            k_new.astype(k_cache.dtype), mode="drop")
        v_cache = v_cache.at[b_idx, pos].set(
            v_new.astype(v_cache.dtype), mode="drop")
    return k_cache, v_cache, ks_cache, vs_cache


def insert_at_slots(cache: KVCache, sub: KVCache,
                    slots: jax.Array) -> KVCache:
    """Scatter ``sub``'s batch rows into ``slots`` of the running cache.

    The continuous-batching engine (``serving/engine.py``) prefills newly
    admitted requests as a small side batch and splices the resulting rows
    into the long-lived decode cache mid-flight, so a finished sequence's
    slot is refilled instead of idling until the batch drains.

    ``slots``: (B_sub,) int32 destination rows, unique.  Works for both FP
    and INT8 caches (both sides must agree); out-of-range slot indices are
    dropped (jax scatter semantics), which the engine uses to pad admission
    groups to a fixed compile-stable width.
    """
    if cache.quantized != sub.quantized:
        raise ValueError("cannot mix quantized and fp caches "
                         f"(main quantized={cache.quantized}, "
                         f"sub quantized={sub.quantized})")
    if cache.capacity != sub.capacity:
        raise ValueError(f"capacity mismatch: {cache.capacity} vs "
                         f"{sub.capacity}")
    slots = jnp.asarray(slots, jnp.int32)
    put = lambda main, part: (None if main is None
                              else main.at[:, slots].set(
                                  part.astype(main.dtype)))
    return KVCache(
        k=put(cache.k, sub.k), v=put(cache.v, sub.v),
        k_scale=put(cache.k_scale, sub.k_scale),
        v_scale=put(cache.v_scale, sub.v_scale),
        lengths=cache.lengths.at[slots].set(sub.lengths),
    )


def free_slots(cache: KVCache, slots: jax.Array) -> KVCache:
    """Mark ``slots`` empty by resetting their write cursors to zero.

    The payload is left in place — every read (attention, gathers) is
    masked by ``lengths``, and the next ``insert_at_slots`` overwrites the
    rows wholesale — so eviction is a (B,)-sized scatter, not a cache copy.
    """
    slots = jnp.asarray(slots, jnp.int32)
    return KVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        lengths=cache.lengths.at[slots].set(0),
    )


def free_inactive(cache: KVCache, live: jax.Array) -> KVCache:
    """Mask-driven ``free_slots`` for use *inside* a jitted burst program.

    ``live``: (B,) bool — rows whose cursor must be preserved.  Every other
    row (finished since the last admission, or never occupied) gets its
    write cursor reset to 0, exactly what the host-dispatched
    ``free_slots`` did between bursts before admissions were fused into
    the burst program.  Payload untouched — reads are length-masked and
    the next ``splice_prefill``/``insert_at_slots`` overwrites the rows.
    """
    return KVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        lengths=jnp.where(live, cache.lengths, 0),
    )


def with_lengths(cache, lengths: jax.Array):
    """Replace the write cursors of a :class:`KVCache`/:class:`PagedKVCache`.

    Speculative decoding rolls rejected draft positions back by resetting
    cursors — the payload past the cursor is junk by contract (reads are
    length-masked, later writes overwrite), so rollback is cursor-only.
    """
    return dataclasses.replace(cache, lengths=lengths)


def group_rows(base_slots: jax.Array, group: int) -> jax.Array:
    """Expand group base rows to the strided row set they own.

    ``base_slots``: (G,) int32 group base rows (multiples of ``group`` for
    in-range entries) → (G * group,) row indices ``base + [0, group)``.
    An out-of-range sentinel base (≥ the cache batch) expands to ``group``
    out-of-range rows, so the padding convention of ``insert_at_slots``
    (OOB rows are dropped by jax scatter semantics) carries over to whole
    groups.
    """
    base = jnp.asarray(base_slots, jnp.int32)
    return (base[:, None] + jnp.arange(group, dtype=jnp.int32)[None, :]
            ).reshape(-1)


def insert_at_groups(cache: KVCache, sub: KVCache, base_slots: jax.Array,
                     group: int) -> KVCache:
    """Group-strided ``insert_at_slots``: splice whole beam groups.

    ``sub`` holds ``len(base_slots) * group`` batch rows — ``group``
    contiguous rows per admitted request — scattered into rows
    ``[base, base + group)`` of each base slot.  Works for FP and INT8
    caches exactly like ``insert_at_slots`` (it is one).
    """
    return insert_at_slots(cache, sub, group_rows(base_slots, group))


def free_groups(cache: KVCache, base_slots: jax.Array, group: int) -> KVCache:
    """Group-strided ``free_slots``: a finishing beam group frees all
    ``group`` of its rows atomically (cursor reset only — see
    ``free_slots`` for why no payload copy happens)."""
    return free_slots(cache, group_rows(base_slots, group))


def gather_beams(cache: KVCache, beam_idx: jax.Array) -> KVCache:
    """Beam-search cache reorder along batch — the paper's GatherNd.

    ``beam_idx``: (B,) int32 source rows.  On an int8 cache this moves 4×
    fewer bytes than f32 (2× vs bf16); ``benchmarks/bench_kv_gather.py``
    measures exactly this op.  The paged cache
    (:func:`gather_beams_paged`) takes the same optimization to its
    logical endpoint: the payload stops moving entirely.
    """
    take = lambda a: jnp.take(a, beam_idx, axis=1) if a is not None else None
    return KVCache(
        k=take(cache.k), v=take(cache.v),
        k_scale=take(cache.k_scale), v_scale=take(cache.v_scale),
        lengths=jnp.take(cache.lengths, beam_idx, axis=0),
    )


# ---------------------------------------------------------------------------
# paged cache: fixed-size pages + per-row block tables
# ---------------------------------------------------------------------------
#
# The contiguous cache above reserves a full (S_max,) row per decode slot and
# beam-reorders by moving the whole slab.  The paged cache stores tokens in
# fixed-size pages shared by all rows; each row sees its sequence through a
# block table of page ids.  Consequences:
#
# * beam reorder = permuting (B, maxP) int32 block-table rows plus one
#   partial-page copy per row (the page currently being written) — the
#   payload slab never moves, which is the logical endpoint of the paper's
#   §5.3 copy-size optimization (INT8 shrank the gather 4×; paging makes it
#   ~S_max/page_size smaller again, independent of dtype);
# * HBM is reserved per *request* (ceil(budget / page_size) pages per live
#   row) instead of per grid row, so short-budget requests stop paying for
#   S_max capacity and a fixed pool admits more concurrent rows;
# * freeing is returning page ids to a free list — fragmentation cannot
#   exist, which is what unlocks mixed beam widths per request.
#
# Sentinel convention: the page id ``n_pages`` (one past the pool) marks an
# unreserved block-table slot.  Every payload write goes through
# ``mode="drop"`` scatters, so a row stepping past its reservation (finished
# rows keep stepping until the burst edge) writes nowhere; reads clamp into
# the pool and are masked by ``lengths``.

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """Paged cache for one attention stack (layers stacked).

    ``k``/``v``: (L, n_pages, page_size, HKV, dh) int8 or activation dtype.
    ``k_scale``/``v_scale``: (L, n_pages, page_size, HKV) f32 or None.
    ``block_tables``: (B, max_pages) int32 — row r's logical view: token
    position p lives in page ``block_tables[r, p // page_size]`` at offset
    ``p % page_size``.  After a beam reorder, early (read-only) entries may
    point into a sibling row's pages; the entry for the *next write slot*
    always points into ``own_pages`` (see :func:`gather_beams_paged`).
    ``own_pages``: (B, max_pages) int32 — the pages physically reserved for
    row r (never permuted by beam reorders; sentinel past the reservation).
    ``lengths``: (B,) int32 valid lengths / per-row write cursors.
    """

    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    block_tables: jax.Array
    own_pages: jax.Array
    lengths: jax.Array

    def tree_flatten(self):
        return ((self.k, self.v, self.k_scale, self.v_scale,
                 self.block_tables, self.own_pages, self.lengths), None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]

    @property
    def page_size(self) -> int:
        return self.k.shape[2]

    @property
    def max_pages(self) -> int:
        return self.block_tables.shape[1]

    @property
    def capacity(self) -> int:
        """Logical row capacity in tokens (same contract as ``KVCache``)."""
        return self.max_pages * self.page_size

    def nbytes(self) -> int:
        n = self.k.size * self.k.dtype.itemsize * 2
        if self.quantized:
            n += self.k_scale.size * 4 * 2
        n += (self.block_tables.size + self.own_pages.size) * 4
        return int(n)

    def reorder_bytes_per_step(self) -> int:
        """Bytes a beam reorder moves per decode step: the block-table /
        length permutation plus one partial-page payload copy per row —
        compare ``KVCache.nbytes()``, which :func:`gather_beams` moves."""
        L, _, ps, HKV, dh = self.k.shape
        B = self.block_tables.shape[0]
        page = L * B * ps * HKV * dh * self.k.dtype.itemsize * 2
        if self.quantized:
            page += L * B * ps * HKV * 4 * 2
        return int(page + self.block_tables.size * 4 + B * 4)


def pages_per_row(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache positions (≥ 1)."""
    return max((int(n_tokens) + page_size - 1) // page_size, 1)


def init_paged_cache(n_layers: int, batch: int, max_len: int, n_kv: int,
                     dh: int, *, page_size: int, n_pages: Optional[int] = None,
                     quantized: bool, dtype=jnp.bfloat16) -> PagedKVCache:
    """Pool of ``n_pages`` pages + empty (all-sentinel) block tables.

    ``max_len`` must be a page multiple (the engine validates) so the
    linearized paged view has exactly the contiguous cache's shape — that
    shape equality is what makes the paged path bit-identical to the
    unpaged one.  ``n_pages`` defaults to full contiguous-equivalent
    capacity (``batch × max_pages``); serving configs pass less and admit
    against the page budget instead.
    """
    if max_len % page_size:
        raise ValueError(f"max_len={max_len} must be a multiple of "
                         f"page_size={page_size}")
    max_pages = max_len // page_size
    if n_pages is None:
        n_pages = batch * max_pages
    shape = (n_layers, n_pages, page_size, n_kv, dh)
    if quantized:
        k = jnp.zeros(shape, jnp.int8)
        v = jnp.zeros(shape, jnp.int8)
        ks = jnp.zeros(shape[:-1], jnp.float32)
        vs = jnp.zeros(shape[:-1], jnp.float32)
    else:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
        ks = vs = None
    # two distinct buffers (donation-safe: the serving engine donates the
    # whole decode state, and one buffer may not be donated twice)
    tables = jnp.full((batch, max_pages), n_pages, jnp.int32)
    own = jnp.full((batch, max_pages), n_pages, jnp.int32)
    return PagedKVCache(k=k, v=v, k_scale=ks, v_scale=vs,
                        block_tables=tables, own_pages=own,
                        lengths=jnp.zeros((batch,), jnp.int32))


def append_token_paged(
    k_pages: jax.Array,                  # (P, ps, HKV, dh) one layer's pool
    v_pages: jax.Array,
    ks_pages: Optional[jax.Array],       # (P, ps, HKV)
    vs_pages: Optional[jax.Array],
    block_tables: jax.Array,             # (B, maxP) int32
    k_new: jax.Array,                    # (B, 1, HKV, dh) fp
    v_new: jax.Array,
    lengths: jax.Array,                  # (B,) per-row cursors
):
    """Paged ``append_token``: scatter one token per row at its cursor.

    The destination page comes from the block table; rows whose cursor is
    past capacity, or whose table entry is the unreserved sentinel, drop
    the write (same ``mode="drop"`` contract as the contiguous append —
    finished rows keep stepping inside a burst and must write nowhere).
    """
    P, ps = k_pages.shape[0], k_pages.shape[1]
    maxP = block_tables.shape[1]
    b_idx = jnp.arange(block_tables.shape[0])
    slot = lengths // ps
    off = lengths % ps
    entry = block_tables[b_idx, jnp.minimum(slot, maxP - 1)]
    page = jnp.where(slot < maxP, entry, P)          # past capacity → drop
    if ks_pages is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_pages = k_pages.at[page, off].set(kq[:, 0], mode="drop")
        v_pages = v_pages.at[page, off].set(vq[:, 0], mode="drop")
        ks_pages = ks_pages.at[page, off].set(ks[:, 0], mode="drop")
        vs_pages = vs_pages.at[page, off].set(vs[:, 0], mode="drop")
    else:
        k_pages = k_pages.at[page, off].set(
            k_new[:, 0].astype(k_pages.dtype), mode="drop")
        v_pages = v_pages.at[page, off].set(
            v_new[:, 0].astype(v_pages.dtype), mode="drop")
    return k_pages, v_pages, ks_pages, vs_pages


def append_tokens_paged(
    k_pages: jax.Array,                  # (P, ps, HKV, dh) one layer's pool
    v_pages: jax.Array,
    ks_pages: Optional[jax.Array],       # (P, ps, HKV)
    vs_pages: Optional[jax.Array],
    block_tables: jax.Array,             # (B, maxP) int32
    k_new: jax.Array,                    # (B, T, HKV, dh) fp
    v_new: jax.Array,
    lengths: jax.Array,                  # (B,) per-row cursors
):
    """Paged :func:`append_tokens`: T consecutive tokens per row.

    Row b's token t targets position ``lengths[b] + t``; its page comes
    from the block table, sentinel/past-capacity positions drop (same
    contract as :func:`append_token_paged`).  Positions are distinct
    within a row, so no two writes collide on a (page, offset) pair.
    """
    P, ps = k_pages.shape[0], k_pages.shape[1]
    maxP = block_tables.shape[1]
    B, T = k_new.shape[0], k_new.shape[1]
    b_idx = jnp.arange(B)[:, None]
    pos = lengths[:, None] + jnp.arange(T, dtype=lengths.dtype)[None, :]
    slot = pos // ps
    off = pos % ps
    entry = block_tables[b_idx, jnp.minimum(slot, maxP - 1)]
    page = jnp.where(slot < maxP, entry, P)          # past capacity → drop
    if ks_pages is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_pages = k_pages.at[page, off].set(kq, mode="drop")
        v_pages = v_pages.at[page, off].set(vq, mode="drop")
        ks_pages = ks_pages.at[page, off].set(ks, mode="drop")
        vs_pages = vs_pages.at[page, off].set(vs, mode="drop")
    else:
        k_pages = k_pages.at[page, off].set(
            k_new.astype(k_pages.dtype), mode="drop")
        v_pages = v_pages.at[page, off].set(
            v_new.astype(v_pages.dtype), mode="drop")
    return k_pages, v_pages, ks_pages, vs_pages


def linearize_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Gather one layer's paged payload into the contiguous row view.

    ``pages``: (P, ps, …) → (B, maxP·ps, …).  Sentinel entries clamp into
    the pool and read garbage — every consumer masks by ``lengths``.  This
    is the XLA fallback read path; the Pallas kernel walks the table
    per-block instead and never materializes this view.
    """
    P = pages.shape[0]
    B, maxP = block_tables.shape
    got = pages[jnp.clip(block_tables, 0, P - 1)]    # (B, maxP, ps, …)
    return got.reshape((B, maxP * pages.shape[1]) + pages.shape[2:])


def assign_pages(cache: PagedKVCache, rows: jax.Array,
                 pages: jax.Array) -> PagedKVCache:
    """Install per-row page reservations (admission).

    ``rows``: (R,) destination rows (OOB sentinels dropped);
    ``pages``: (R, maxP) page ids, sentinel-padded past each row's
    reservation.  Both ``own_pages`` and ``block_tables`` are set — a
    freshly admitted row starts with its logical view equal to its
    physical reservation — and the cursor resets to 0.
    """
    rows = jnp.asarray(rows, jnp.int32)
    pages = jnp.asarray(pages, jnp.int32)
    return PagedKVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        block_tables=cache.block_tables.at[rows].set(pages, mode="drop"),
        own_pages=cache.own_pages.at[rows].set(pages, mode="drop"),
        lengths=cache.lengths.at[rows].set(0, mode="drop"),
    )


def free_slots_paged(cache: PagedKVCache, slots: jax.Array) -> PagedKVCache:
    """Paged ``free_slots``: reset cursors AND sentinel the freed rows'
    tables.  The sentinel matters here (unlike the contiguous cache, where
    a dead row harmlessly scribbles inside its own slab): a freed row keeps
    stepping until refilled, and its pages may be handed to a *new* request
    — its writes must drop, not land in reallocated pages."""
    slots = jnp.asarray(slots, jnp.int32)
    sent = jnp.full((slots.shape[0], cache.max_pages), cache.n_pages,
                    jnp.int32)
    return PagedKVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        block_tables=cache.block_tables.at[slots].set(sent, mode="drop"),
        own_pages=cache.own_pages.at[slots].set(sent, mode="drop"),
        lengths=cache.lengths.at[slots].set(0, mode="drop"),
    )


def free_inactive_paged(cache: PagedKVCache, live: jax.Array) -> PagedKVCache:
    """Mask-driven :func:`free_slots_paged` for the fused burst prologue:
    every row not in ``live`` gets cursor 0 and all-sentinel tables, so its
    pages can be reassigned by the splice that follows in the same
    program."""
    live_col = live[:, None]
    sent = jnp.int32(cache.n_pages)
    return PagedKVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        block_tables=jnp.where(live_col, cache.block_tables, sent),
        own_pages=jnp.where(live_col, cache.own_pages, sent),
        lengths=jnp.where(live, cache.lengths, 0),
    )


def insert_rows_paged(cache: PagedKVCache, sub: KVCache, slots: jax.Array,
                      pages: jax.Array) -> PagedKVCache:
    """Splice a *contiguous* prefilled side batch into the paged cache
    (the unfused admission path: prefill runs on a plain side batch, then
    its rows are copied into the destination rows' reserved pages).

    ``sub``'s row capacity must equal the paged logical capacity
    (``maxP × ps`` — the engine guarantees ``max_len`` is a page
    multiple); each sub row is reshaped into page-sized chunks and
    scattered to ``pages`` (sentinel entries drop their chunk, so
    unreserved tails and OOB padding rows vanish).
    """
    if cache.quantized != sub.quantized:
        raise ValueError("cannot mix quantized and fp caches "
                         f"(main quantized={cache.quantized}, "
                         f"sub quantized={sub.quantized})")
    if sub.capacity != cache.capacity:
        raise ValueError(f"capacity mismatch: paged {cache.capacity} vs "
                         f"side batch {sub.capacity}")
    ps, maxP = cache.page_size, cache.max_pages
    W = sub.k.shape[1]
    ids = jnp.asarray(pages, jnp.int32).reshape(W * maxP)

    def put(pool, part):
        if pool is None:
            return None
        # (L, W, maxP·ps, …) → (L, W·maxP, ps, …) page-chunked payload
        chunks = part.reshape((part.shape[0], W * maxP, ps) + part.shape[3:])
        return pool.at[:, ids].set(chunks.astype(pool.dtype), mode="drop")

    return PagedKVCache(
        k=put(cache.k, sub.k), v=put(cache.v, sub.v),
        k_scale=put(cache.k_scale, sub.k_scale),
        v_scale=put(cache.v_scale, sub.v_scale),
        block_tables=cache.block_tables.at[slots].set(
            jnp.asarray(pages, jnp.int32), mode="drop"),
        own_pages=cache.own_pages.at[slots].set(
            jnp.asarray(pages, jnp.int32), mode="drop"),
        lengths=cache.lengths.at[slots].set(sub.lengths, mode="drop"),
    )


def cow_write_slot(cache: PagedKVCache) -> PagedKVCache:
    """Copy-on-write for each row's *current write slot* page.

    For every row, copy the page its block table currently maps for the
    next write position into the row's privately-owned page for that slot
    (``own_pages``) and repoint the table entry there.  After this, the
    next ``append_token_paged`` is guaranteed to land in a page the row
    owns exclusively — it never writes into a page another row (or a
    cached prefix chain with refcount > 1) also maps.

    Rows whose table entry already points at their own page copy a page
    onto itself (a no-op on content); rows whose own slot is the
    unreserved sentinel drop the copy (``mode="drop"``).  This is the
    primitive behind the zero-copy beam reorder (see
    :func:`gather_beams_paged`) and the copy-on-write contract of shared
    prefix pages: shared pages are only ever *read* through block tables,
    and any row about to write through a shared mapping first diverts the
    write slot into its own reservation here.
    """
    P, ps, maxP = cache.n_pages, cache.page_size, cache.max_pages
    B = cache.block_tables.shape[0]
    b_idx = jnp.arange(B)
    sp = jnp.minimum(cache.lengths // ps, maxP - 1)  # next write slot
    src_page = jnp.clip(cache.block_tables[b_idx, sp], 0, P - 1)
    dst_page = cache.own_pages[b_idx, sp]            # sentinel → copy drops

    def cow(pool):
        if pool is None:
            return None
        payload = jnp.take(pool, src_page, axis=1)   # (L, B, ps, …)
        return pool.at[:, dst_page].set(payload, mode="drop")

    return PagedKVCache(
        k=cow(cache.k), v=cow(cache.v),
        k_scale=cow(cache.k_scale), v_scale=cow(cache.v_scale),
        block_tables=cache.block_tables.at[b_idx, sp].set(dst_page),
        own_pages=cache.own_pages,                   # physical, never moves
        lengths=cache.lengths,
    )


def gather_beams_paged(cache: PagedKVCache, beam_idx: jax.Array
                       ) -> PagedKVCache:
    """Zero-copy beam reorder: permute block tables, not payload.

    The contiguous :func:`gather_beams` moves the whole (L, B, S, HKV, dh)
    slab every beam step; here the reorder is

    1. gather the (B, maxP) block tables and (B,) cursors by ``beam_idx``
       (int32 index traffic only);
    2. :func:`cow_write_slot`: copy the source lineage's *current partial
       page* into the destination row's own page for that slot and point
       the table entry there — so the next append (which lands in that
       slot) writes into a page the row owns privately, never into a page
       a sibling also writes.

    Invariant maintained: at append time, the table entry for the slot
    being written always comes from ``own_pages`` — fresh admissions set
    the whole table to ``own_pages`` and every reorder re-establishes it
    for the next write slot.  Full (read-only) pages stay shared between
    beams; sharing is always intra-group, and a group's rows are freed
    atomically, so no refcounting is needed on device.
    """
    return cow_write_slot(PagedKVCache(
        k=cache.k, v=cache.v, k_scale=cache.k_scale, v_scale=cache.v_scale,
        block_tables=jnp.take(cache.block_tables, beam_idx, axis=0),
        own_pages=cache.own_pages,
        lengths=jnp.take(cache.lengths, beam_idx, axis=0),
    ))


# ---------------------------------------------------------------------------
# prefix-chain pools: page-granular storage for cached cross-attention K/V
# ---------------------------------------------------------------------------
#
# The prefix cache (serving/prefix_cache.py) stores each cached source's
# encoded cross-attention K/V as a chain of fixed-size pages in a dedicated
# pool of shape (L, n_pages, page_size, HKV, dh), kept in the activation
# dtype (never re-quantized: a cached read must be bit-identical to a fresh
# encode).  These two helpers are the only device ops it needs: scatter a
# freshly encoded batch into reserved chains, and gather chains back into
# the (L, B, S, HKV, dh) layout that ``splice_prefill`` consumes.

def insert_chain_pages(pool: jax.Array, part: jax.Array,
                       pages: jax.Array) -> jax.Array:
    """Scatter per-row payload into reserved page chains.

    ``pool``: (L, P, ps, …); ``part``: (L, B, S, …); ``pages``: (B, nP)
    int32 with ``nP = ceil(S / ps)`` — sentinel entries (≥ P) drop their
    chunk, so padding rows write nowhere.
    """
    L, B, S = part.shape[0], part.shape[1], part.shape[2]
    ps = pool.shape[2]
    nP = pages.shape[1]
    pad = nP * ps - S
    if pad:
        part = jnp.pad(part, [(0, 0), (0, 0), (0, pad)]
                       + [(0, 0)] * (part.ndim - 3))
    chunks = part.reshape((L, B * nP, ps) + part.shape[3:])
    ids = jnp.asarray(pages, jnp.int32).reshape(B * nP)
    return pool.at[:, ids].set(chunks.astype(pool.dtype), mode="drop")


def gather_chain_pages(pool: jax.Array, pages: jax.Array,
                       seq_len: int) -> jax.Array:
    """Read page chains back as contiguous rows.

    ``pages``: (B, nP) int32 → (L, B, seq_len, …).  Sentinel entries clamp
    into the pool and read garbage past each chain's valid span — callers
    mask by source length exactly as they would a fresh encode's padding.
    """
    P = pool.shape[1]
    B, nP = pages.shape
    got = pool[:, jnp.clip(pages, 0, P - 1)]         # (L, B, nP, ps, …)
    got = got.reshape((pool.shape[0], B, nP * pool.shape[2]) + pool.shape[3:])
    return got[:, :, :seq_len]


class PageAllocator:
    """Host-side page pool: free list + refcounts + high-water mark.

    The scheduler reserves ``pages_per_row(budget) × live rows`` pages at
    admission and returns them at release, so admission is gated by real
    HBM instead of contiguous row capacity.  Refcounts support shared
    reservations (``retain``): the prefix cache hash-conses page chains
    across requests, so counts > 1 are real — the chain's tree entry holds
    one reference and every request currently reading it holds another.
    Decode reservations stay exclusive (sharing there happens on device,
    strictly inside beam groups that free atomically).

    Every mutating call validates its *entire* argument first and only
    then mutates, so a bad call (double free, retain of a free page,
    duplicate page ids whose combined drop exceeds the refcount) raises
    without changing any state — callers can treat errors as atomic.
    """

    def __init__(self, n_pages: int, page_size: int, *,
                 overcommit_limit: float = 1.0):
        if n_pages < 1 or page_size < 1:
            raise ValueError(f"bad pool: n_pages={n_pages}, "
                             f"page_size={page_size}")
        if overcommit_limit < 1.0:
            raise ValueError(
                f"overcommit_limit={overcommit_limit} must be >= 1.0")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.overcommit_limit = float(overcommit_limit)
        self._free = list(range(self.n_pages - 1, -1, -1))   # pop() = page 0
        self._refcount = [0] * self.n_pages
        self.hwm = 0
        self.free_lwm = self.n_pages      # low-water mark of the free list
        self.reserved = 0                 # virtual worst-case reservations
        self.spilled = 0                  # pages' worth of KV held on host

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return pages_per_row(n_tokens, self.page_size)

    def _check(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(f"page id {p} outside pool "
                                 f"[0, {self.n_pages})")

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages (refcount 1 each) or None if the pool can't.

        The free list is peeked and validated *before* any page leaves it:
        a corrupted pool (a free-listed page with a live refcount) raises
        with the free list intact rather than handing out the page.  These
        are raised exceptions, not asserts — the invariants must hold
        under ``python -O`` too, now that refcounts > 1 are real.
        """
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        candidates = self._free[len(self._free) - n:]
        for p in candidates:
            if self._refcount[p] != 0:
                raise RuntimeError(
                    f"page {p} double-assigned: on the free list with "
                    f"refcount {self._refcount[p]}")
        del self._free[len(self._free) - n:]
        pages = list(reversed(candidates))               # pop() order
        for p in pages:
            self._refcount[p] = 1
        self.hwm = max(self.hwm, self.in_use)
        self.free_lwm = min(self.free_lwm, len(self._free))
        return pages

    # -------------------------------------------- overcommit reservations
    # ``reserve``/``unreserve`` track *virtual* worst-case page claims: the
    # scheduler reserves each admitted request's full worst case but only
    # physically allocates what the next burst needs, so the sum of
    # reservations may exceed the physical pool — up to
    # ``overcommit_limit × n_pages``.  The gap is backed by preemption
    # (spill a victim's pages to host when a physical alloc comes up
    # short), which is what makes overcommit deadlock-free.

    @property
    def reserve_cap(self) -> int:
        return int(self.overcommit_limit * self.n_pages)

    def can_reserve(self, n: int) -> bool:
        if n < 0:
            raise ValueError(f"cannot reserve {n} pages")
        return self.reserved + n <= self.reserve_cap

    def reserve(self, n: int) -> bool:
        if not self.can_reserve(n):
            return False
        self.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n < 0 or n > self.reserved:
            raise ValueError(f"unreserve({n}) with reserved={self.reserved}")
        self.reserved -= n

    # ------------------------------------------------- spill accounting
    def spill(self, pages: Sequence[int]) -> None:
        """Release ``pages`` whose content moved to a host spill store.

        Atomic: validates exactly like :meth:`release` (it *is* a release)
        before mutating, then counts the pages as spilled so leak checks
        can demand ``spilled == 0`` after every chaos schedule.
        """
        self.release(pages)          # validate-then-mutate, may raise
        self.spilled += len(pages)

    def unspill(self, n: int) -> None:
        """Account ``n`` spilled pages' worth of KV restored on device
        (the physical pages come from a fresh :meth:`alloc`)."""
        if n < 0 or n > self.spilled:
            raise ValueError(f"unspill({n}) with spilled={self.spilled}")
        self.spilled -= n

    @property
    def fragmentation(self) -> float:
        """Free-list scatter in [0, 1]: 0 when the free pages form one
        contiguous id run, →1 as every free page sits in its own run.
        Paged serving is immune to it (any page serves any slot); the stat
        exists to show that churn *does* scatter the pool and the engine
        keeps running at full occupancy anyway."""
        if len(self._free) <= 1:
            return 0.0
        ids = sorted(self._free)
        runs = 1 + sum(1 for a, b in zip(ids, ids[1:]) if b != a + 1)
        return (runs - 1) / (len(self._free) - 1)

    def retain(self, pages: Sequence[int]) -> None:
        self._check(pages)
        for p in pages:
            if self._refcount[p] <= 0:
                raise ValueError(f"retain of unallocated page {p}")
        for p in pages:
            self._refcount[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        # validate the FULL list (with multiplicity: releasing [p, p]
        # against refcount 1 is a double free) before mutating anything —
        # a partial release would leave the pool inconsistent.
        self._check(pages)
        drops: dict = {}
        for p in pages:
            drops[p] = drops.get(p, 0) + 1
        for p, n in drops.items():
            if self._refcount[p] < n:
                raise ValueError(
                    f"release of page {p} ×{n} exceeds refcount "
                    f"{self._refcount[p]} (double free)")
        for p in pages:
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)

    def refcount(self, page: int) -> int:
        return self._refcount[page]
