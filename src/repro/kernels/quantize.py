"""Pallas TPU kernels: fused activation quantization.

The paper's graph inserts ``Min → Max → QuantizeV2`` chains (§4.1), i.e.
three HBM passes per quantized tensor; its §5.5 then removes the Min/Max for
calibrated sites.  These kernels are the TPU form of both:

* ``quantize_rowwise_pallas`` — *dynamic* symmetric quantization: one fused
  pass computes the per-row abs-max, the scale, and the rounded int8 payload
  (one read + one write instead of three reads).
* ``quantize_static_pallas`` — *calibrated* quantization: the scale is a
  trace-time constant (the KL threshold), so the kernel is a single
  elementwise pass — the paper's "thresholds become Const ops".
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_MAX = 127.0
_EPS = 1e-12


def _rowwise_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), _EPS)
    scale = amax / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_rowwise_pallas(
    x: jax.Array,                  # (M, K) f32/bf16
    *,
    block_rows: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused dynamic row-wise quantizer. Returns (int8 (M,K), f32 (M,1))."""
    M, K = x.shape
    # block row count must be sublane-aligned (multiple of 8): a bare
    # min(block_rows, M) picks e.g. bm=12 for M=12, which interpret mode
    # accepts but real TPU lowering rejects — round up, padding covers it
    bm = min(block_rows, ((max(8, M) + 7) // 8) * 8)
    pad = (-M) % bm
    x_p = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    Mp = x_p.shape[0]

    q, scale = pl.pallas_call(
        _rowwise_kernel,
        grid=(Mp // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, K), jnp.int8),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x_p)
    return q[:M], scale[:M]


def _static_kernel(x_ref, amax_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(amax_ref[0, 0], _EPS) / INT8_MAX
    q_ref[...] = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX
                          ).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_static_pallas(
    x: jax.Array,                  # (M, K)
    amax: jax.Array,               # scalar f32 — calibrated threshold
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Calibrated-scale quantizer: single elementwise pass to int8."""
    M, K = x.shape
    # sublane-align the block rows (see quantize_rowwise_pallas)
    bm = min(block_rows, ((max(8, M) + 7) // 8) * 8)
    pad = (-M) % bm
    x_p = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    Mp = x_p.shape[0]
    amax2 = jnp.asarray(amax, jnp.float32).reshape(1, 1)

    q = pl.pallas_call(
        _static_kernel,
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, K), jnp.int8),
        interpret=interpret,
    )(x_p, amax2)
    return q[:M]
