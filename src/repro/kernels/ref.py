"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function computes the kernel's result with plain jax.numpy at
full (int32/float32) precision.  The kernel tests sweep shapes/dtypes and
``assert_allclose`` against these.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

def ref_int8_matmul(
    a_q: jax.Array,            # (M, K) int8
    a_scale: jax.Array,        # (M, 1) or scalar f32 (dequant scale)
    b_q: jax.Array,            # (K, N) int8
    b_scale: jax.Array,        # (1, N) or scalar f32
    a_zero_point: Optional[jax.Array] = None,   # scalar f32 (q-space offset)
    bias: Optional[jax.Array] = None,           # (N,) f32
    out_dtype=jnp.float32,
) -> jax.Array:
    """Exact integer accumulation then affine epilogue.

    real(a) = (a_q - zp_a) * a_scale ;  real(b) = b_q * b_scale (symmetric)
    =>  a @ b = a_scale*b_scale * (a_q@b_q - zp_a * colsum(b_q))
    """
    acc = jax.lax.dot_general(
        a_q, b_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32)
    if a_zero_point is not None:
        colsum = jnp.sum(b_q.astype(jnp.int32), axis=0, keepdims=True)
        acc = acc - jnp.asarray(a_zero_point, jnp.float32) * colsum.astype(jnp.float32)
    out = acc * jnp.asarray(a_scale, jnp.float32) * jnp.asarray(b_scale, jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(out_dtype)


def ref_int8_matmul_batched(
    a_q: jax.Array,            # (E, M, K) int8
    a_scale: jax.Array,        # (E, M, 1) f32
    b_q: jax.Array,            # (E, K, N) int8
    b_scale: jax.Array,        # (E, 1, N) f32
    out_dtype=jnp.float32,
) -> jax.Array:
    """Grouped (per-expert) int8 matmul oracle."""
    acc = jax.lax.dot_general(
        a_q, b_q, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    out = acc * jnp.asarray(a_scale, jnp.float32) * jnp.asarray(b_scale,
                                                                jnp.float32)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# int4 (block-quantized weight) matmul
# ---------------------------------------------------------------------------

def ref_int4_matmul(
    a_q: jax.Array,            # (M, K) int8 activations
    a_scale: jax.Array,        # (M, 1) or scalar f32 (dequant scale)
    b_packed: jax.Array,       # (K_store//2, N) int8 packed nibbles
    b_scale: jax.Array,        # (n_groups, N) f32/f16 block scales
    b_min: jax.Array,          # (n_groups, N) f32/f16 block minimums
    a_zero_point: Optional[jax.Array] = None,   # scalar f32 (q-space offset)
    bias: Optional[jax.Array] = None,           # (N,) f32
    *,
    group_size: int,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Group-wise oracle for the dequant-in-kernel INT4 matmul.

    real(b)[k, n] = nib[k, n] * scale[k // G, n] + vmin[k // G, n]
    =>  a @ b = a_scale * [ Σ_g (scale_g · (a_q @ nib)_g + vmin_g · rowsum_g)
                            - zp · colsum(real(b)) ] + bias

    The per-group integer dots are exact (int32); the f32 combination runs
    in ascending-group order with the same op sequence as the Pallas kernel,
    so interpret-mode results are bit-identical (both paths execute each
    primitive separately — no cross-op FMA contraction).
    """
    from repro.core.qtensor import unpack_nibbles

    M, K = a_q.shape
    n_g = b_scale.shape[0]
    G = group_size
    k_store = n_g * G
    N = b_packed.shape[1]
    nib = unpack_nibbles(b_packed).astype(jnp.int8)          # (k_store, N)
    a_p = (jnp.pad(a_q, ((0, 0), (0, k_store - K)))
           if k_store > K else a_q)
    acc = jnp.zeros((M, N), jnp.float32)
    for g in range(n_g):
        a_g = a_p[:, g * G:(g + 1) * G]
        d = jnp.dot(a_g, nib[g * G:(g + 1) * G, :],
                    preferred_element_type=jnp.int32)
        rsum = jnp.sum(a_g.astype(jnp.int32), axis=1, keepdims=True)
        acc = acc + (d.astype(jnp.float32)
                     * b_scale[g].astype(jnp.float32)[None, :]
                     + rsum.astype(jnp.float32)
                     * b_min[g].astype(jnp.float32)[None, :])
    if a_zero_point is not None:
        s = jnp.repeat(b_scale.astype(jnp.float32), G, axis=0)
        m = jnp.repeat(b_min.astype(jnp.float32), G, axis=0)
        deq = nib.astype(jnp.float32) * s + m
        colsum = jnp.sum(deq[:K, :], axis=0, keepdims=True)
        acc = acc - jnp.asarray(a_zero_point, jnp.float32) * colsum
    out = acc * jnp.asarray(a_scale, jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

def ref_quantize_rowwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dynamic symmetric row-wise quantization: returns (int8, (M,1) scales)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                               keepdims=True), 1e-12)
    scale = amax / INT8_MAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scale


def ref_quantize_static(x: jax.Array, amax: jax.Array) -> jax.Array:
    """Static-scale symmetric quantization (calibrated threshold)."""
    scale = jnp.maximum(jnp.asarray(amax, jnp.float32), 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8)


# ---------------------------------------------------------------------------
# decode attention over int8 KV cache
# ---------------------------------------------------------------------------

def ref_decode_attention(
    q: jax.Array,          # (B, H, dh) f32/bf16
    k_q: jax.Array,        # (B, S, HKV, dh) int8
    k_scale: jax.Array,    # (B, S, HKV) f32
    v_q: jax.Array,        # (B, S, HKV, dh) int8
    v_scale: jax.Array,    # (B, S, HKV) f32
    lengths: jax.Array,    # (B,) int32 — valid cache length per sequence
    sm_scale: float,
) -> jax.Array:
    """Masked attention of one query token against a dequantized KV cache."""
    B, S, HKV, dh = k_q.shape
    H = q.shape[1]
    G = H // HKV
    k = k_q.astype(jnp.float32) * k_scale[..., None]
    v = v_q.astype(jnp.float32) * v_scale[..., None]
    qf = q.astype(jnp.float32).reshape(B, HKV, G, dh)
    # scores: (B, HKV, G, S)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k) * sm_scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v)
    return out.reshape(B, H, dh).astype(q.dtype)


def ref_decode_attention_paged(
    q: jax.Array,           # (B, H, dh) f32/bf16
    k_pages: jax.Array,     # (P, ps, HKV, dh) int8 page pool
    k_scale: jax.Array,     # (P, ps, HKV) f32
    v_pages: jax.Array,     # (P, ps, HKV, dh) int8
    v_scale: jax.Array,     # (P, ps, HKV) f32
    block_tables: jax.Array,  # (B, maxP) int32 (sentinel = P, clamped)
    lengths: jax.Array,     # (B,) int32
    sm_scale: float,
) -> jax.Array:
    """Paged oracle: linearize each row's pages through its block table,
    then run the contiguous oracle.  Sentinel (unreserved) entries clamp
    into the pool and are masked out by ``lengths``; with the logical
    capacity equal to the contiguous cache's ``S`` the result is
    bit-identical to :func:`ref_decode_attention` on the linearized rows.
    """
    P = k_pages.shape[0]
    B, maxP = block_tables.shape
    tab = jnp.clip(block_tables, 0, P - 1)

    def lin(pool):
        got = pool[tab]                           # (B, maxP, ps, …)
        return got.reshape((B, maxP * pool.shape[1]) + pool.shape[2:])

    return ref_decode_attention(q, lin(k_pages), lin(k_scale),
                                lin(v_pages), lin(v_scale), lengths, sm_scale)
