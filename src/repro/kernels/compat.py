"""Version-compat for the Pallas TPU API (companion to
``repro.distributed.compat`` on the sharding side).

jax ≥0.5 renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``;
every kernel gets the resolved class from here so the version check lives
in one place and fails loudly if a future pallas drops both names.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Construct pallas TPU compiler params across the rename."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; unsupported jax/pallas version")
    return cls(**kwargs)
