"""Pallas TPU kernel: dequant-in-kernel INT4(weight) × INT8(activation) matmul.

The weight operand arrives as a :class:`~repro.core.qtensor.BlockQTensor`
payload: 4-bit codes packed two-nibbles-per-int8 along K plus per-block
(group-wise) scale/min pairs.  The kernel unpacks the nibbles and applies the
block affine map *inside* the K loop, so the unpacked FP weights never touch
HBM — decode streams 4 bits + ~0.25 bits of metadata per weight instead of 8.

Math.  With activations ``real(a) = (a_q - zp) * a_scale`` and weights
``real(b)[k, n] = nib[k, n] * scale[g, n] + vmin[g, n]`` (g = k // G):

    a @ b = a_scale * [ Σ_g ( scale_g · (a_q[:, g] @ nib[g])          (MXU, s8·s8→s32)
                            + vmin_g · rowsum(a_q[:, g]) )            (VPU)
                        - zp · colsum(real(b)) ]  + bias

Each group's two integer reductions are exact in int32; only the per-group
combination runs in f32, in ascending-group order — the same order the
reference oracle uses, which is what makes bit-identity tests meaningful.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost, like ``int8_matmul``.  The
wrapper forces ``bk`` to a multiple of ``group_size`` so a block's scale/min
never straddles two k-tiles; packed rows tile at ``bk // 2`` and the
scale/min operands at ``bk // group_size`` rows per step.

Padding contract (the colsum/zp analogue of the INT8 one): ``a`` is padded
with zeros along K, so padded rows contribute exactly zero to both the MXU
term (0 · nib) and the min term (rowsum counts only real activations);
grid-tail groups beyond the stored K get scale = vmin = 0 as a second guard.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.int8_matmul import _pad_to

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _unpack_nibbles_tile(packed: jax.Array) -> jax.Array:
    """(bk//2, bn) int8 → (bk, bn) int8 codes in [0, 15] (row 2r=lo, 2r+1=hi)."""
    pu = jax.lax.bitcast_convert_type(packed, jnp.uint8)
    lo = (pu & 0xF).astype(jnp.int8)
    hi = (pu >> 4).astype(jnp.int8)
    k2, bn = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, bn)


def _kernel(a_ref, b_ref, scale_ref, min_ref, a_scale_ref, zp_ref,
            colsum_ref, bias_ref, out_ref, acc_ref, *, k_steps: int,
            groups_per_block: int, group_size: int, has_zp: bool,
            has_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nib = _unpack_nibbles_tile(b_ref[...])            # (bk, bn) int8 in [0,15]
    a_tile = a_ref[...]                               # (bm, bk) int8
    scales = scale_ref[...].astype(jnp.float32)       # (bk//G, bn)
    mins = min_ref[...].astype(jnp.float32)           # (bk//G, bn)
    for g in range(groups_per_block):
        sl = slice(g * group_size, (g + 1) * group_size)
        a_g = a_tile[:, sl]                           # (bm, G) int8
        # MXU step: s8 × s8 → s32, exact
        d = jnp.dot(a_g, nib[sl, :], preferred_element_type=jnp.int32)
        rsum = jnp.sum(a_g.astype(jnp.int32), axis=1, keepdims=True)
        acc_ref[...] += (d.astype(jnp.float32) * scales[g][None, :]
                         + rsum.astype(jnp.float32) * mins[g][None, :])

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_zp:
            # zero-point correction for asymmetric activations: colsum here
            # is over the *dequantized* weights (precomputed in the wrapper).
            acc = acc - zp_ref[0, 0] * colsum_ref[...]
        out = acc * a_scale_ref[...]
        if has_bias:
            out = out + bias_ref[...].astype(jnp.float32)
        out_ref[...] = out.astype(out_ref.dtype)


def _pick_bk(k_store: int, group_size: int, bk: int) -> int:
    """Largest multiple of ``group_size`` ≤ ``bk`` (at least one group),
    clamped to the stored K so tiny layers stay single-step."""
    cand = group_size * max(1, bk // group_size)
    return min(cand, -(-k_store // group_size) * group_size) \
        if k_store < cand else cand


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "out_dtype", "bm", "bn", "bk", "interpret"),
)
def int4_matmul_pallas(
    a_q: jax.Array,                       # (M, K) int8 activations
    a_scale: jax.Array,                   # (M, 1) or (1, 1) f32
    b_packed: jax.Array,                  # (K_store//2, N) int8 packed nibbles
    b_scale: jax.Array,                   # (n_groups, N) f32/f16
    b_min: jax.Array,                     # (n_groups, N) f32/f16
    a_zero_point: Optional[jax.Array] = None,   # scalar f32 (q-space)
    bias: Optional[jax.Array] = None,           # (N,) f32
    *,
    group_size: int,
    out_dtype=jnp.float32,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    M, K = a_q.shape
    K2, N = b_packed.shape
    n_g = b_scale.shape[0]
    k_store = n_g * group_size
    if 2 * K2 != k_store:
        raise ValueError(f"packed rows {K2} inconsistent with "
                         f"{n_g} groups of {group_size}")
    if K > k_store:
        raise ValueError(f"activation K={K} exceeds stored K={k_store}")

    bm = min(bm, max(8, M))
    bn = min(bn, max(128, N))
    bk = _pick_bk(k_store, group_size, bk)

    # pad along K to the grid: a with zeros (the padding contract), the
    # weight payload with zero bytes and the tail groups with scale=min=0.
    Kp = -(-k_store // bk) * bk
    a_p = _pad_to(jnp.pad(a_q, ((0, 0), (0, Kp - K))), (bm, bk))
    b_p = _pad_to(b_packed, (Kp // 2, bn))
    scale_p = _pad_to(b_scale, (Kp // group_size, bn))
    min_p = _pad_to(b_min, (Kp // group_size, bn))
    Mp = a_p.shape[0]
    Np = b_p.shape[1]

    a_scale_p = _pad_to(jnp.broadcast_to(a_scale, (M, 1)).astype(jnp.float32),
                        (bm, 1))

    has_zp = a_zero_point is not None
    has_bias = bias is not None
    if has_zp:
        zp = jnp.asarray(a_zero_point, jnp.float32).reshape(1, 1)
        # Σ_{k<K} real(b)[k, n] — over the *logical* rows only: padded a rows
        # carry no zero-point because they are not real activations.
        from repro.core.qtensor import unpack_nibbles
        nib = unpack_nibbles(b_packed).astype(jnp.float32)       # (k_store, N)
        s = jnp.repeat(b_scale.astype(jnp.float32), group_size, axis=0)
        m = jnp.repeat(b_min.astype(jnp.float32), group_size, axis=0)
        deq = nib * s + m
        colsum = jnp.sum(deq[:K, :], axis=0, keepdims=True)
        colsum = _pad_to(colsum, (1, bn))
    else:
        zp = jnp.zeros((1, 1), jnp.float32)
        colsum = jnp.zeros((1, Np), jnp.float32)
    bias_p = (_pad_to(bias.reshape(1, N).astype(jnp.float32), (1, bn))
              if has_bias else jnp.zeros((1, Np), jnp.float32))

    m_steps, n_steps, k_steps = Mp // bm, Np // bn, Kp // bk
    gpb = bk // group_size

    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, groups_per_block=gpb,
                          group_size=group_size, has_zp=has_zp,
                          has_bias=has_bias),
        grid=(m_steps, n_steps, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),           # a
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),      # packed b
            pl.BlockSpec((gpb, bn), lambda i, j, k: (k, j)),          # scales
            pl.BlockSpec((gpb, bn), lambda i, j, k: (k, j)),          # mins
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),            # a_scale
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),             # zp
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),            # colsum
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),            # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p, scale_p, min_p, a_scale_p, zp, colsum, bias_p)
    return out[:M, :N]
