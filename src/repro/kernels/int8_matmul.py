"""Pallas TPU kernel: s8·s8→s32 matmul with fused dequantize epilogue.

This is the TPU-native analogue of the paper's MKL/VNNI ``QuantizedMatMul``
(§5.2): the MXU consumes int8 operand tiles at 2× the bf16 FLOP rate and
accumulates in int32.  The epilogue applies

    out = (acc - zp_a · colsum(b_q)) · a_scale · b_scale + bias

inside the kernel, so no separate Requantize/Dequantize pass ever touches
HBM — the paper's §5.5 "eliminate graph ops" expressed as epilogue fusion.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost; the int32 accumulator
lives in VMEM scratch.  Default blocks (256, 256, 512) keep the working set
at ~0.6 MB (a) + 0.5 MB (b) + 0.25 MB (acc) per step — far under the 16 MB
v5e VMEM — while every matmul dim stays a multiple of the (32, 128) int8
native tile.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _kernel(a_ref, b_ref, a_scale_ref, b_scale_ref, zp_ref, colsum_ref,
            bias_ref, out_ref, acc_ref, *, k_steps: int, has_zp: bool,
            has_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU step: int8 × int8 → int32
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        if has_zp:
            # zero-point correction for asymmetric activations
            # (independent-mode calibration): zp is scalar in q-space.
            acc = acc - zp_ref[0, 0] * colsum_ref[...].astype(jnp.float32)
        out = acc * a_scale_ref[...] * b_scale_ref[...]
        if has_bias:
            out = out + bias_ref[...].astype(jnp.float32)
        out_ref[...] = out.astype(out_ref.dtype)


def _batched_kernel(a_ref, b_ref, a_scale_ref, b_scale_ref, out_ref, acc_ref,
                    *, k_steps: int):
    """Expert-batched variant: grid (E, M/bm, N/bn, K/bk)."""
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out_ref[0] = (acc * a_scale_ref[0] * b_scale_ref[0]
                      ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "bm", "bn", "bk", "interpret")
)
def int8_matmul_batched_pallas(
    a_q: jax.Array,                   # (E, M, K) int8
    a_scale: jax.Array,               # (E, M, 1) f32
    b_q: jax.Array,                   # (E, K, N) int8
    b_scale: jax.Array,               # (E, 1, N) f32
    *,
    out_dtype=jnp.float32,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Grouped (per-expert) s8 matmul — the MoE expert-FFN hot path."""
    E, M, K = a_q.shape
    _, _, N = b_q.shape
    bm = min(bm, max(8, M))
    bn = min(bn, max(128, N))
    bk = min(bk, max(128, K))
    a_p = _pad_to(a_q, (1, bm, bk))
    b_p = _pad_to(b_q, (1, bk, bn))
    a_scale_p = _pad_to(jnp.broadcast_to(a_scale, (E, M, 1)
                                         ).astype(jnp.float32), (1, bm, 1))
    b_scale_p = _pad_to(jnp.broadcast_to(b_scale, (E, 1, N)
                                         ).astype(jnp.float32), (1, 1, bn))
    _, Mp, Kp = a_p.shape
    _, _, Np = b_p.shape
    m_steps, n_steps, k_steps = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_batched_kernel, k_steps=k_steps),
        grid=(E, m_steps, n_steps, k_steps),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bm, 1), lambda e, i, j, k: (e, i, 0)),
            pl.BlockSpec((1, 1, bn), lambda e, i, j, k: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p, a_scale_p, b_scale_p)
    return out[:, :M, :N]


def _pad_to(x: jax.Array, multiples) -> jax.Array:
    pads = []
    needs = False
    for dim, mult in zip(x.shape, multiples):
        pad = (-dim) % mult
        pads.append((0, pad))
        needs = needs or pad > 0
    return jnp.pad(x, pads) if needs else x


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "bm", "bn", "bk", "interpret"),
)
def int8_matmul_pallas(
    a_q: jax.Array,                       # (M, K) int8
    a_scale: jax.Array,                   # (M, 1) or (1, 1) f32
    b_q: jax.Array,                       # (K, N) int8
    b_scale: jax.Array,                   # (1, N) or (1, 1) f32
    a_zero_point: Optional[jax.Array] = None,   # scalar f32 (q-space)
    bias: Optional[jax.Array] = None,           # (N,) f32
    *,
    out_dtype=jnp.float32,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    M, K = a_q.shape
    K2, N = b_q.shape
    assert K == K2, (a_q.shape, b_q.shape)
    bm = min(bm, max(8, M))
    bn = min(bn, max(128, N))
    bk = min(bk, max(128, K))

    a_p = _pad_to(a_q, (bm, bk))
    b_p = _pad_to(b_q, (bk, bn))
    Mp, Kp = a_p.shape
    _, Np = b_p.shape

    a_scale_p = _pad_to(jnp.broadcast_to(a_scale, (M, 1)).astype(jnp.float32),
                        (bm, 1))
    b_scale_p = _pad_to(jnp.broadcast_to(b_scale, (1, N)).astype(jnp.float32),
                        (1, bn))

    has_zp = a_zero_point is not None
    has_bias = bias is not None
    if has_zp:
        zp = jnp.asarray(a_zero_point, jnp.float32).reshape(1, 1)
        colsum = jnp.sum(b_p.astype(jnp.int32), axis=0, keepdims=True)
        colsum = colsum.astype(jnp.float32)
    else:
        zp = jnp.zeros((1, 1), jnp.float32)
        colsum = jnp.zeros((1, Np), jnp.float32)
    bias_p = (_pad_to(bias.reshape(1, N).astype(jnp.float32), (1, bn))
              if has_bias else jnp.zeros((1, Np), jnp.float32))

    m_steps, n_steps, k_steps = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, has_zp=has_zp,
                          has_bias=has_bias),
        grid=(m_steps, n_steps, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),      # a
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),      # b
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),       # a_scale
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # b_scale
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),        # zp
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # colsum
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p, a_scale_p, b_scale_p, zp, colsum, bias_p)
    return out[:M, :N]
