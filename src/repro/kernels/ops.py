"""Public jit'd wrappers around the Pallas kernels.

Every op dispatches on ``impl``:

* ``"pallas"``     — the TPU kernel (the deployment path),
* ``"interpret"``  — the same kernel body interpreted on CPU (tests),
* ``"xla"``        — pure-jnp fallback (identical math; this is what the
                     CPU dry-run compiles, and the oracle for tests).
* ``"auto"``       — pallas on TPU backends, xla elsewhere.

The wrappers are QTensor-aware and handle leading-batch flattening so model
code can stay shape-agnostic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import BlockQTensor, QTensor
from repro.kernels import ref
from repro.kernels.decode_attention import (
    decode_attention_paged_pallas,
    decode_attention_pallas,
)
from repro.kernels.int4_matmul import int4_matmul_pallas
from repro.kernels.int8_matmul import (
    int8_matmul_batched_pallas,
    int8_matmul_pallas,
)
from repro.kernels.quantize import quantize_rowwise_pallas, quantize_static_pallas


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

def _row_scale(scale, M: int) -> jax.Array:
    """Normalize an activation scale to (1, 1) or (M, 1) f32."""
    return (jnp.reshape(jnp.asarray(scale, jnp.float32), (1, 1))
            if jnp.size(scale) == 1
            else jnp.reshape(jnp.asarray(scale, jnp.float32), (M, 1)))


def _fold_zero_point(zero_point) -> Optional[jax.Array]:
    """Symmetric activations have zp == 0 everywhere; fold to the no-zp fast
    path when that is decidable at trace time (calibrated constants)."""
    if jnp.size(zero_point) != 1:
        return None
    if isinstance(zero_point, (float, int)):
        return None if float(zero_point) == 0.0 else jnp.float32(zero_point)
    azp = jnp.asarray(zero_point)
    try:  # concrete (calibrated constant) → fold the decision now
        return None if float(azp) == 0.0 else azp.astype(jnp.float32)
    except Exception:  # traced → keep correction term
        return azp.astype(jnp.float32)


def int8_matmul(
    a: QTensor,
    b: QTensor,
    bias: Optional[jax.Array] = None,
    *,
    out_dtype=jnp.float32,
    impl: str = "auto",
) -> jax.Array:
    """``dequant(a) @ dequant(b) + bias`` computed in int8 on the MXU.

    ``a``: activations, shape (..., K); scale per-row (…,1) or scalar;
    ``b``: weights, shape (K, N); symmetric per-column scale (1, N)/scalar.
    """
    impl = _resolve(impl)
    batch_shape = a.data.shape[:-1]
    K = a.data.shape[-1]
    N = b.data.shape[-1]
    a2 = a.data.reshape(-1, K)
    M = a2.shape[0]
    a_scale = _row_scale(a.scale, M)
    b_scale = jnp.asarray(b.scale, jnp.float32)
    b_scale = (jnp.broadcast_to(b_scale.reshape(1, 1), (1, N))
               if b_scale.size == 1 else b_scale.reshape(1, N))
    zp = _fold_zero_point(a.zero_point)
    if impl in ("pallas", "interpret"):
        out = int8_matmul_pallas(
            a2, a_scale, b.data, b_scale, zp, bias,
            out_dtype=out_dtype, interpret=(impl == "interpret"),
        )
    else:
        out = ref.ref_int8_matmul(a2, a_scale, b.data, b_scale, zp, bias,
                                  out_dtype=out_dtype)
    return out.reshape(*batch_shape, N)


def int4_matmul(
    a: QTensor,
    b: BlockQTensor,
    bias: Optional[jax.Array] = None,
    *,
    out_dtype=jnp.float32,
    impl: str = "auto",
) -> jax.Array:
    """``dequant(a) @ block_dequant(b) + bias`` with dequant fused in-kernel.

    ``a``: int8 activations, shape (..., K); scale per-row (…, 1) or scalar;
    ``b``: block-quantized INT4 weights (packed nibbles + group scale/min).
    """
    impl = _resolve(impl)
    batch_shape = a.data.shape[:-1]
    K = a.data.shape[-1]
    if b.data.ndim != 2:
        raise ValueError(f"int4_matmul wants 2-D weights, got {b.shape}")
    if K != b.k_dim:
        raise ValueError(f"K mismatch: activations {K}, weights {b.k_dim}")
    N = b.data.shape[-1]
    a2 = a.data.reshape(-1, K)
    M = a2.shape[0]
    a_scale = _row_scale(a.scale, M)
    zp = _fold_zero_point(a.zero_point)
    if impl in ("pallas", "interpret"):
        out = int4_matmul_pallas(
            a2, a_scale, b.data, b.scale, b.vmin, zp, bias,
            group_size=b.group_size, out_dtype=out_dtype,
            interpret=(impl == "interpret"),
        )
    else:
        out = ref.ref_int4_matmul(a2, a_scale, b.data, b.scale, b.vmin,
                                  zp, bias, group_size=b.group_size,
                                  out_dtype=out_dtype)
    return out.reshape(*batch_shape, N)


def int8_matmul_batched(
    a: QTensor,                    # data (E, M, K); scale (E, M, 1) or scalar
    b: QTensor,                    # data (E, K, N); scale (E, 1, N)
    *,
    out_dtype=jnp.float32,
    impl: str = "auto",
) -> jax.Array:
    """Per-expert grouped int8 matmul (MoE expert FFN hot path)."""
    impl = _resolve(impl)
    E, M, K = a.data.shape
    _, _, N = b.data.shape
    a_scale = (jnp.broadcast_to(jnp.asarray(a.scale, jnp.float32),
                                (E, M, 1))
               if jnp.size(a.scale) != 1
               else jnp.broadcast_to(jnp.asarray(a.scale, jnp.float32
                                                 ).reshape(1, 1, 1), (E, 1, 1)))
    b_scale = jnp.asarray(b.scale, jnp.float32).reshape(E, 1, N)
    if impl in ("pallas", "interpret"):
        return int8_matmul_batched_pallas(
            a.data, a_scale, b.data, b_scale, out_dtype=out_dtype,
            interpret=(impl == "interpret"))
    return ref.ref_int8_matmul_batched(a.data, a_scale, b.data, b_scale,
                                       out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

def quantize_rowwise(x: jax.Array, *, impl: str = "auto") -> QTensor:
    """Dynamic symmetric per-row quantization of (..., K) activations."""
    impl = _resolve(impl)
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if impl in ("pallas", "interpret"):
        q, scale = quantize_rowwise_pallas(x2, interpret=(impl == "interpret"))
    else:
        q, scale = ref.ref_quantize_rowwise(x2)
    return QTensor(
        data=q.reshape(*batch_shape, x.shape[-1]),
        scale=scale.reshape(*batch_shape, 1),
        zero_point=jnp.zeros((), jnp.float32),
        axis=None,
    )


def quantize_static(x: jax.Array, amax, *, impl: str = "auto") -> QTensor:
    """Calibrated symmetric quantization with a constant threshold."""
    impl = _resolve(impl)
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if impl in ("pallas", "interpret"):
        q = quantize_static_pallas(x2, jnp.float32(amax),
                                   interpret=(impl == "interpret"))
    else:
        q = ref.ref_quantize_static(x2, jnp.float32(amax))
    return QTensor(
        data=q.reshape(x.shape),
        scale=jnp.float32(amax) / 127.0,
        zero_point=jnp.zeros((), jnp.float32),
        axis=None,
    )


# ---------------------------------------------------------------------------
# decode attention over int8 KV cache
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    lengths: jax.Array,
    *,
    sm_scale: float,
    impl: str = "auto",
) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return decode_attention_pallas(
            q, k_q, k_scale, v_q, v_scale, lengths,
            sm_scale=sm_scale, interpret=(impl == "interpret"),
        )
    return ref.ref_decode_attention(q, k_q, k_scale, v_q, v_scale, lengths,
                                    sm_scale)


def decode_attention_paged(
    q: jax.Array,            # (B, H, dh)
    k_pages: jax.Array,      # (P, ps, HKV, dh) int8
    k_scale: jax.Array,      # (P, ps, HKV) f32
    v_pages: jax.Array,      # (P, ps, HKV, dh) int8
    v_scale: jax.Array,      # (P, ps, HKV) f32
    block_tables: jax.Array, # (B, maxP) int32
    lengths: jax.Array,      # (B,) int32
    *,
    sm_scale: float,
    impl: str = "auto",
) -> jax.Array:
    """Paged-cache decode attention: the Pallas kernel walks the block
    table per page slot (scalar-prefetched index map); the XLA fallback
    linearizes the table then reuses the contiguous oracle."""
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return decode_attention_paged_pallas(
            q, k_pages, k_scale, v_pages, v_scale, block_tables, lengths,
            sm_scale=sm_scale, interpret=(impl == "interpret"),
        )
    return ref.ref_decode_attention_paged(q, k_pages, k_scale, v_pages,
                                          v_scale, block_tables, lengths,
                                          sm_scale)
