"""Pallas TPU kernel: flash-decode attention over an INT8 KV cache.

TPU-native form of the paper's §5.3 (quantized GatherNd): during
auto-regressive decode the per-step cost is dominated by *reading the KV
cache* — exactly the big-tensor copies the paper quantized.  Keeping the
cache int8 and dequantizing in VMEM registers cuts decode HBM traffic ~4×
vs f32 (2× vs bf16) and shrinks beam-search cache reorders by the same
factor.

One query token per sequence attends to the full cache with an online
(flash) softmax: grid (batch, kv_head, seq_blocks), f32 running max / sum /
accumulator in VMEM scratch.  GQA query groups (G = H / H_kv) ride along the
sublane dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30
DEFAULT_BLOCK_S = 256
# f32/int8-dequant compute tiles want ≥ 8 rows in the sublane dim: a paged
# grid step covering a single page_size < 8 page would run its dots on
# mostly-empty tiles, so small-page pools fetch SUBLANE // page_size pages
# per step instead (see decode_attention_paged_pallas)
SUBLANE = 8


def _kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, len_ref, out_ref,
            m_ref, l_ref, acc_ref, *, s_steps: int, block_s: int,
            sm_scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # (G, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bs, dh)
    k = k * ks_ref[0, :, 0][:, None]                         # dequant in VREGs
    scores = jax.lax.dot_general(                            # (G, bs)
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale

    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos < len_ref[0, 0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                                      # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid, p, 0.0)

    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (bs, dh)
    v = v * vs_ref[0, :, 0][:, None]

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == s_steps - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_s", "interpret"))
def decode_attention_pallas(
    q: jax.Array,          # (B, H, dh)
    k_q: jax.Array,        # (B, S, HKV, dh) int8
    k_scale: jax.Array,    # (B, S, HKV) f32
    v_q: jax.Array,        # (B, S, HKV, dh) int8
    v_scale: jax.Array,    # (B, S, HKV) f32
    lengths: jax.Array,    # (B,) int32
    *,
    sm_scale: float,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    B, H, dh = q.shape
    _, S, HKV, _ = k_q.shape
    assert H % HKV == 0, (H, HKV)
    G = H // HKV
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        k_q = jnp.pad(k_q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_q = jnp.pad(v_q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    s_steps = Sp // bs

    q4 = q.reshape(B, HKV, G, dh)
    len2 = lengths.astype(jnp.int32).reshape(B, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, s_steps=s_steps, block_s=bs,
                          sm_scale=sm_scale),
        grid=(B, HKV, s_steps),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, s: (b, h, 0, 0)),   # q
            pl.BlockSpec((1, bs, 1, dh), lambda b, h, s: (b, s, h, 0)),  # k
            pl.BlockSpec((1, bs, 1), lambda b, h, s: (b, s, h)),         # k_scale
            pl.BlockSpec((1, bs, 1, dh), lambda b, h, s: (b, s, h, 0)),  # v
            pl.BlockSpec((1, bs, 1), lambda b, h, s: (b, s, h)),         # v_scale
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),                # lengths
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, HKV, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # running max
            pltpu.VMEM((G, 1), jnp.float32),    # running denom
            pltpu.VMEM((G, dh), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q4, k_q, k_scale, v_q, v_scale, len2)
    return out.reshape(B, H, dh)


# ---------------------------------------------------------------------------
# paged variant: walk the block table per sequence block
# ---------------------------------------------------------------------------

def _paged_kernel(tab_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, len_ref,
                  out_ref, m_ref, l_ref, acc_ref, *, s_steps: int,
                  page_size: int, sm_scale: float):
    """Same online-softmax body as ``_kernel``; the *grid* walks logical
    page slots and the BlockSpec index maps translate each (row, slot)
    into the physical page to DMA — the paged cache is consumed in place,
    with no linearized copy ever materialized."""
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # (G, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (ps, dh)
    k = k * ks_ref[0, :, 0][:, None]                         # dequant in VREGs
    scores = jax.lax.dot_general(                            # (G, ps)
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale

    # logical position of this page slot's tokens; cursor mask also hides
    # sentinel (unreserved) slots, whose index map clamped into the pool
    pos = s * page_size + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos < len_ref[0, 0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid, p, 0.0)

    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (ps, dh)
    v = v * vs_ref[0, :, 0][:, None]

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == s_steps - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def _paged_kernel_multi(tab_ref, q_ref, *refs, s_steps: int, page_size: int,
                        block_pages: int, sm_scale: float):
    """Multi-page variant of ``_paged_kernel``: one grid step DMAs
    ``block_pages`` *consecutive logical slots* (each its own BlockSpec
    operand, each landing wherever its table entry points) and runs one
    online-softmax update over their concatenation — so a
    ``page_size < 8`` pool still feeds the dots full sublane tiles."""
    F = block_pages
    k_refs, ks_refs = refs[0:F], refs[F:2 * F]
    v_refs, vs_refs = refs[2 * F:3 * F], refs[3 * F:4 * F]
    len_ref, out_ref = refs[4 * F], refs[4 * F + 1]
    m_ref, l_ref, acc_ref = refs[4 * F + 2:4 * F + 5]
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # (G, dh)
    # consecutive slots hold consecutive token positions, so stacking the
    # pages along the sublane dim keeps the position iota contiguous
    k = jnp.concatenate(
        [r[0, :, 0, :] for r in k_refs], axis=0).astype(jnp.float32)
    ks = jnp.concatenate([r[0, :, 0] for r in ks_refs], axis=0)
    k = k * ks[:, None]                                      # (F·ps, dh)
    scores = jax.lax.dot_general(                            # (G, F·ps)
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale

    pos = (s * F * page_size
           + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))
    valid = pos < len_ref[0, 0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid, p, 0.0)

    v = jnp.concatenate(
        [r[0, :, 0, :] for r in v_refs], axis=0).astype(jnp.float32)
    vs = jnp.concatenate([r[0, :, 0] for r in vs_refs], axis=0)
    v = v * vs[:, None]                                      # (F·ps, dh)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == s_steps - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret",
                                             "pages_per_block"))
def decode_attention_paged_pallas(
    q: jax.Array,            # (B, H, dh)
    k_pages: jax.Array,      # (P, ps, HKV, dh) int8 page pool
    k_scale: jax.Array,      # (P, ps, HKV) f32
    v_pages: jax.Array,      # (P, ps, HKV, dh) int8
    v_scale: jax.Array,      # (P, ps, HKV) f32
    block_tables: jax.Array, # (B, maxP) int32; sentinel P = unreserved
    lengths: jax.Array,      # (B,) int32
    *,
    sm_scale: float,
    interpret: bool = False,
    pages_per_block: int = 0,  # 0 = auto: SUBLANE // ps for small pages
) -> jax.Array:
    """Flash-decode over a paged INT8 KV cache (paper §5.3, paged).

    Grid (batch, kv_head, page_slot); the block table rides in as a
    scalar-prefetch operand so each slot's physical page id is known
    before the body runs and the K/V DMAs fetch pages directly — the
    paper's "big tensor stops moving" taken to its endpoint: decode reads
    exactly the pages a row owns, wherever they sit in the pool.

    When ``page_size < SUBLANE`` each grid step covers
    ``pages_per_block = SUBLANE // page_size`` consecutive slots (auto
    unless overridden) so the per-step dot still fills the 8-row sublane
    tile; block tables fill slots densely from the front, so a block's
    pages hold contiguous positions and the tail mask is unchanged.
    """
    B, H, dh = q.shape
    P, ps, HKV, _ = k_pages.shape
    assert H % HKV == 0, (H, HKV)
    G = H // HKV
    maxP = block_tables.shape[1]

    if pages_per_block < 0:
        raise ValueError(f"pages_per_block must be >= 0, got {pages_per_block}")
    F = pages_per_block or max(1, SUBLANE // ps)

    q4 = q.reshape(B, HKV, G, dh)
    len2 = lengths.astype(jnp.int32).reshape(B, 1)
    tab = block_tables.astype(jnp.int32)
    if F > 1 and maxP % F:
        # pad logical slots to a block multiple with sentinels: their
        # positions land past every cursor, so the `pos < len` mask drops
        # them exactly like any other unreserved slot
        tab = jnp.pad(tab, ((0, 0), (0, (-maxP) % F)), constant_values=P)
        maxP = tab.shape[1]
    tab = jnp.clip(tab, 0, P - 1)

    if F > 1:
        def page_map_j(j):
            return lambda b, h, s, t: (t[b, s * F + j], 0, h, 0)

        def scale_map_j(j):
            return lambda b, h, s, t: (t[b, s * F + j], 0, h)

        s_steps = maxP // F
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, HKV, s_steps),
            in_specs=[
                pl.BlockSpec((1, 1, G, dh), lambda b, h, s, t: (b, h, 0, 0)),
                *[pl.BlockSpec((1, ps, 1, dh), page_map_j(j))
                  for j in range(F)],                        # k pages
                *[pl.BlockSpec((1, ps, 1), scale_map_j(j))
                  for j in range(F)],                        # k scales
                *[pl.BlockSpec((1, ps, 1, dh), page_map_j(j))
                  for j in range(F)],                        # v pages
                *[pl.BlockSpec((1, ps, 1), scale_map_j(j))
                  for j in range(F)],                        # v scales
                pl.BlockSpec((1, 1), lambda b, h, s, t: (b, 0)),  # lengths
            ],
            out_specs=pl.BlockSpec((1, 1, G, dh),
                                   lambda b, h, s, t: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),    # running max
                pltpu.VMEM((G, 1), jnp.float32),    # running denom
                pltpu.VMEM((G, dh), jnp.float32),   # output accumulator
            ],
        )
        out = pl.pallas_call(
            functools.partial(_paged_kernel_multi, s_steps=s_steps,
                              page_size=ps, block_pages=F,
                              sm_scale=sm_scale),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, HKV, G, dh), q.dtype),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(tab, q4, *([k_pages] * F), *([k_scale] * F),
          *([v_pages] * F), *([v_scale] * F), len2)
        return out.reshape(B, H, dh)

    def page_map(b, h, s, tab_ref):
        return (tab_ref[b, s], 0, h, 0)

    def scale_map(b, h, s, tab_ref):
        return (tab_ref[b, s], 0, h)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, HKV, maxP),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, s, t: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, dh), page_map),                  # k pages
            pl.BlockSpec((1, ps, 1), scale_map),                     # k_scale
            pl.BlockSpec((1, ps, 1, dh), page_map),                  # v pages
            pl.BlockSpec((1, ps, 1), scale_map),                     # v_scale
            pl.BlockSpec((1, 1), lambda b, h, s, t: (b, 0)),         # lengths
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, s, t: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # running max
            pltpu.VMEM((G, 1), jnp.float32),    # running denom
            pltpu.VMEM((G, dh), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, s_steps=maxP, page_size=ps,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, HKV, G, dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tab, q4, k_pages, k_scale, v_pages, v_scale, len2)
    return out.reshape(B, H, dh)
