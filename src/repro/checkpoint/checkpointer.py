"""Fault-tolerant checkpointing: atomic writes, retention, async save, and
mesh-independent restore (elastic rescaling).

Format: one ``.npz`` with leaves keyed by their pytree path + a JSON
metadata sidecar.  Checkpoints store *full* (unsharded) arrays, so a restart
may use a different mesh — restore re-shards each leaf onto the current
mesh via ``jax.device_put`` with the new sharding (this is the elastic-
scaling path: 2 pods → 1 pod just works).

Atomicity: write to ``<dir>/tmp.<step>``, fsync, ``os.replace`` into place —
a killed job never leaves a half-written "latest".
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.qtensor import QTensor  # noqa: F401  (registered pytree)


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_paths:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> str:
        # Materialize on host *before* handing to the async thread so the
        # training loop can donate/overwrite device buffers immediately.
        flat = _flatten_with_paths(tree)
        meta = {"step": int(step),
                "treedef": jax.tree_util.tree_structure(tree).__repr__(),
                "extra": extra or {}}

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)
        return self._step_dir(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               meta: Dict) -> None:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``target``.

        ``shardings``: optional matching tree of NamedSharding — each leaf is
        placed directly onto the *current* mesh (elastic restore).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self._step_dir(step), "arrays.npz")
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}

        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(paths_leaves))
        out = []
        for (path, leaf), shard in zip(paths_leaves, shard_leaves):
            key = "/".join(_path_str(p) for p in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key]
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def read_meta(self, step: Optional[int] = None) -> Dict:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            return json.load(f)
