"""Cross-request prefix sharing (ISSUE 6): radix tree + chain pool.

Three layers:

* **Tree semantics**: exact-match only (a strict prefix or extension of a
  cached source is NOT a hit — the encoder is bidirectional), page-chunk
  keying, LRU eviction that never touches a chain someone is reading,
  refcount lifecycle (tree ref + one per reader), and skip-not-deadlock
  under pool pressure.
* **Engine identity**: ``serve(prefix_cache=True)`` on a repeated-source
  mix is token-identical to the cold-cache serve — greedy and beam
  (uniform + mixed widths), FP and INT8, fused and unfused admission,
  fixed and auto burst — with hits > 0 asserted so the matrix can't pass
  vacuously.
* **Persistence**: the cache spans serve() calls — re-serving the same
  sources is all-hit, allocates nothing, and never runs the encoder.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.models import kv_cache as kvc
from repro.serving import PrefixCache, ServingEngine

MAX_LEN = 32
PAGE_SIZE = 8
BUDGETS = [3, 7, 5, 3, 7, 5]            # repeated sources → repeated budgets
MIXED = [4, 2, 1, 4, 2, 1]


# ------------------------------------------------------------------ fixtures
_CACHED = {}


def _module_state():
    if "model" not in _CACHED:
        cfg = get_config("transformer-base").reduced(
            vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
            n_heads=2, n_kv_heads=2, head_dim=24)
        from repro.models import build_model
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams, qctx = quantize_model(params, {},
                                       QuantPolicy(act_quant="dynamic"))
        corpus = make_corpus(3, cfg.vocab, seed=11, max_words=8)
        # each distinct source twice: second occurrence must hit
        srcs = [r.src for r in corpus] * 2
        _CACHED.update(cfg=cfg, model=model, params=params,
                       qparams=qparams, qctx=qctx, srcs=srcs, colds={})
    return _CACHED


def _engine(quant, paged, warm):
    s = _module_state()
    kw = dict(max_len=MAX_LEN, paged=paged, page_size=PAGE_SIZE)
    if quant == "int8":
        kw["quant"] = s["qctx"]
    params = s["qparams"] if quant == "int8" else s["params"]
    if warm:
        kw.update(prefix_cache=True, prefix_pages=64)
    return ServingEngine(s["model"], params, **kw)


def _serve(eng, *, beam, fused, burst):
    s = _module_state()
    return eng.serve(s["srcs"], max_new_tokens=BUDGETS, n_slots=8,
                     beam=beam, burst_len=burst, fused_admission=fused)


def _cold(quant, paged, beam, fused, burst):
    """Cold-cache reference streams, cached per configuration."""
    s = _module_state()
    key = (quant, paged, tuple(beam) if isinstance(beam, list) else beam,
           fused, burst)
    if key not in s["colds"]:
        res = _serve(_engine(quant, paged, warm=False), beam=beam,
                     fused=fused, burst=burst)
        s["colds"][key] = ([list(r.tokens) for r in res.requests],
                           [r.score for r in res.requests])
    return s["colds"][key]


# ------------------------------------------------------------- tree semantics
def _pc(n_pages=16, page_size=4):
    return PrefixCache(kvc.PageAllocator(n_pages, page_size))


def test_exact_match_only():
    """A strict prefix or extension of a cached source is a miss: the
    bidirectional encoder makes partial reuse change tokens."""
    pc = _pc()
    src = np.arange(1, 8, dtype=np.int32)            # 7 tokens, ps=4
    role, chain = pc.admit(src)
    assert role == "insert" and chain.n_pages == 2
    assert pc.lookup(src) is chain
    assert pc.lookup(src[:4]) is None                # page-aligned prefix
    assert pc.lookup(src[:6]) is None                # same chunk count
    assert pc.lookup(np.concatenate([src, [8]])) is None     # extension
    role2, chain2 = pc.admit(src)
    assert role2 == "hit" and chain2 is chain
    # distinct sources with a shared page-aligned prefix coexist
    other = np.concatenate([src[:4], [9, 9]]).astype(np.int32)
    role3, chain3 = pc.admit(other)
    assert role3 == "insert" and chain3 is not chain
    assert pc.lookup(src) is chain and pc.lookup(other) is chain3


def test_refcount_lifecycle():
    """Tree holds one reference per chain; every reader holds another."""
    pc = _pc()
    src = np.arange(1, 6, dtype=np.int32)
    _, chain = pc.admit(src)                         # tree + inserter
    assert all(pc.allocator.refcount(p) == 2 for p in chain.pages)
    _, c2 = pc.admit(src)                            # a second reader
    assert all(pc.allocator.refcount(p) == 3 for p in chain.pages)
    pc.finish(chain)
    pc.finish(c2)
    assert all(pc.allocator.refcount(p) == 1 for p in chain.pages)
    assert pc.allocator.in_use == chain.n_pages      # tree keeps it cached
    pc.clear()
    assert pc.allocator.in_use == 0


def test_lru_eviction_skips_retained_chains():
    """Eviction pressure removes the LRU *unreferenced* chain; a chain a
    request is still reading is never evicted, and when nothing is
    evictable admission degrades to skip (not deadlock, not eviction)."""
    pc = _pc(n_pages=4, page_size=4)
    a = np.asarray([1, 1, 1, 1, 1, 1], np.int32)     # 2 pages each
    b = np.asarray([2, 2, 2, 2, 2, 2], np.int32)
    c = np.asarray([3, 3, 3, 3, 3, 3], np.int32)
    _, ca = pc.admit(a)
    _, cb = pc.admit(b)
    pc.finish(cb)                                    # b: cold, evictable
    role, cc = pc.admit(c)                           # needs b's pages
    assert role == "insert" and pc.stats.evictions == 1
    assert pc.lookup(b) is None and pc.lookup(a) is ca   # a survived: held
    role_b, got = pc.admit(b)                        # a held, c held: full
    assert role_b == "skip" and got is None
    assert pc.stats.evictions == 1                   # nothing was evicted
    pc.finish(ca)
    pc.finish(cc)
    _, _ = pc.admit(b)                               # now evictable again
    assert pc.stats.evictions >= 2


def test_lru_order_follows_hits():
    """A hit bumps recency: the *least recently used* chain is the one
    evicted under pressure, not the oldest-inserted."""
    pc = _pc(n_pages=4, page_size=4)
    a = np.asarray([1] * 4, np.int32)                # 1 page each
    b = np.asarray([2] * 4, np.int32)
    c = np.asarray([3] * 4, np.int32)
    for s in (a, b, c):
        _, ch = pc.admit(s)
        pc.finish(ch)
    _, ch = pc.admit(a)                              # bump a over b
    pc.finish(ch)
    _, _ = pc.admit(np.asarray([4] * 9, np.int32))   # 3 pages: evicts 2
    assert pc.lookup(b) is None and pc.lookup(c) is None
    assert pc.lookup(a) is not None


def test_empty_source_is_cacheable():
    pc = _pc()
    role, chain = pc.admit(np.zeros((0,), np.int32))
    assert role == "insert"
    role2, chain2 = pc.admit(np.zeros((0,), np.int32))
    assert role2 == "hit" and chain2 is chain


# ------------------------------------------------------------ engine identity
@pytest.mark.parametrize("quant,paged,fused,burst", [
    ("fp", False, True, 4),
    ("fp", True, False, 4),
    ("int8", True, True, "auto"),
    ("int8", False, False, 1),
])
def test_greedy_identity_with_hits(quant, paged, fused, burst):
    warm = _serve(_engine(quant, paged, warm=True), beam=None, fused=fused,
                  burst=burst)
    want, _ = _cold(quant, paged, None, fused, burst)
    assert warm.prefix_hits >= len(_module_state()["srcs"]) // 2
    assert warm.prefix_hit_pages >= warm.prefix_hits
    got = [list(r.tokens) for r in warm.requests]
    assert got == want


@pytest.mark.parametrize("quant,paged,beam,fused,burst", [
    ("fp", True, 4, True, 4),
    ("int8", True, 4, False, 4),
    ("fp", False, MIXED, False, 4),
    ("int8", False, MIXED, True, "auto"),
])
def test_beam_identity_with_hits(quant, paged, beam, fused, burst):
    warm = _serve(_engine(quant, paged, warm=True), beam=beam, fused=fused,
                  burst=burst)
    want, want_scores = _cold(quant, paged, beam, fused, burst)
    assert warm.prefix_hits >= 1
    got = [list(r.tokens) for r in warm.requests]
    assert got == want
    np.testing.assert_allclose([r.score for r in warm.requests],
                               want_scores, rtol=1e-6)


def test_cache_persists_across_serves():
    """Second serve on the same warm engine: all-hit, zero new chain
    pages, zero encoder tokens — and still token-identical."""
    eng = _engine("fp", True, warm=True)
    first = _serve(eng, beam=None, fused=True, burst=4)
    second = _serve(eng, beam=None, fused=True, burst=4)
    n = len(_module_state()["srcs"])
    assert second.prefix_hits == n
    assert second.prefix_misses == 0
    assert second.prefix_pages_allocated == 0
    assert second.encoder_tokens == 0
    assert ([list(r.tokens) for r in second.requests]
            == [list(r.tokens) for r in first.requests])
    m = second.metrics()
    assert m["prefix_hit_rate"] == 1.0 and m["prefix_cache"] == 1.0


def test_serve_flag_overrides_engine_default():
    """serve(prefix_cache=False) on a cache-enabled engine must bypass
    the cache entirely (no stats movement, no prefix fields set)."""
    eng = _engine("fp", False, warm=True)
    res = eng.serve(_module_state()["srcs"], max_new_tokens=BUDGETS,
                    n_slots=8, burst_len=4, prefix_cache=False)
    assert not res.prefix_cache
    assert res.prefix_hits == 0 and res.prefix_misses == 0
    assert eng._prefix_cache_obj is None or \
        eng._prefix_cache_obj.stats.hits == 0
