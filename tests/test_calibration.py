"""Calibration pipeline: histograms, classification, KL threshold search."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    Calibrator,
    QuantMode,
    StreamingHistogram,
    classify,
    kl_threshold_search,
    kl_thresholds,
)


def test_streaming_histogram_conserves_counts(rng):
    h = StreamingHistogram()
    total = 0
    for scale in [1.0, 4.0, 0.5, 32.0]:     # forces range expansions
        x = rng.normal(size=5000).astype(np.float32) * scale
        h.observe(x)
        total += x.size
    assert h.total == total
    assert h.counts.sum() == total


def test_histogram_range_covers_observations(rng):
    h = StreamingHistogram()
    x = rng.normal(size=1000).astype(np.float32) * 7
    h.observe(x)
    assert h.range >= np.abs(x).max() * 0.999


def test_classification_taxonomy(rng):
    gaussian = StreamingHistogram()
    gaussian.observe(rng.normal(size=20000).astype(np.float32))
    assert classify(gaussian).kind == "gaussian"

    sparse = StreamingHistogram()
    x = np.zeros(20000, np.float32)
    x[:50] = rng.normal(size=50) * 10
    sparse.observe(x)
    assert classify(sparse).kind == "sparse"

    narrow = StreamingHistogram()
    x = rng.normal(size=20000).astype(np.float32) * 0.01
    x[0] = 5.0   # single outlier stretches the range
    narrow.observe(x)
    assert classify(narrow).kind == "narrow"


def test_kl_clips_long_tails(rng):
    """Paper §4.2: KL threshold sits well inside the absolute range for
    long-tailed distributions."""
    x = rng.standard_t(df=2, size=200_000).astype(np.float32)
    h = StreamingHistogram()
    h.observe(x)
    thr = kl_thresholds(h, QuantMode.SYMMETRIC)
    amax = np.abs(x).max()
    assert thr.t_max < 0.5 * amax
    assert thr.t_max > np.percentile(np.abs(x), 90)


def test_kl_keeps_gaussian_nearly_whole(rng):
    x = rng.normal(size=100_000).astype(np.float32)
    h = StreamingHistogram()
    h.observe(x)
    thr = kl_thresholds(h, QuantMode.SYMMETRIC)
    assert thr.t_max > 0.5 * np.abs(x).max()


def test_mode_relationships(rng):
    x = np.concatenate([rng.normal(size=50_000),
                        -np.abs(rng.standard_t(df=2, size=50_000)) * 3]
                       ).astype(np.float32)
    h = StreamingHistogram()
    h.observe(x)
    ind = kl_thresholds(h, QuantMode.INDEPENDENT)
    conj = kl_thresholds(h, QuantMode.CONJUGATE)
    naive = kl_thresholds(h, QuantMode.NAIVE)
    assert conj.symmetric
    assert conj.t_max == pytest.approx(
        max(abs(ind.t_min), abs(ind.t_max)), rel=1e-6)
    assert naive.t_min <= ind.t_min <= ind.t_max <= naive.t_max


def test_calibrator_end_to_end(rng):
    cal = Calibrator()
    for _ in range(5):
        cal.observe_site("layer/ffn/in", rng.normal(size=4096))
        sparse = np.zeros(4096, np.float32)
        sparse[:5] = 10.0
        cal.observe_site("layer/attn/probs", sparse)
    recs = cal.compute("symmetric")
    assert recs["layer/ffn/in"].quantize
    assert not recs["layer/attn/probs"].quantize          # sparse → FP32
    assert recs["layer/attn/probs"].classification.kind == "sparse"


@given(st.integers(min_value=200, max_value=2000),
       st.floats(min_value=0.5, max_value=50.0))
@settings(max_examples=20, deadline=None)
def test_prop_kl_threshold_positive_and_bounded(n, scale):
    rng = np.random.default_rng(n)
    x = (rng.normal(size=n) * scale).astype(np.float32)
    h = StreamingHistogram()
    h.observe(x)
    counts, r = h.magnitude()
    t = kl_threshold_search(counts, r)
    assert 0 < t <= r * 1.0001
