"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces the 512-device placeholder topology)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def trained_nmt():
    """Tiny Transformer NMT trained on the synthetic corpus — the paper's
    workload at miniature scale, shared (session-scoped: trained once) by
    the end-to-end system test and the INT8 BLEU-parity test layer.

    Returns ``(cfg, model, params, corpus, final_loss)``.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data import TranslationBatches, make_corpus
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import inverse_sqrt
    from repro.train import make_train_step

    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=inverse_sqrt(cfg.d_model, warmup=200), b2=0.98)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = make_corpus(400, cfg.vocab, max_words=5, seed=0)
    data = TranslationBatches(corpus, 32, sort_mode="tokens", seed=0)
    loss = None
    for _ in range(500):
        batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch())
        (params, opt_state), m = step(params, opt_state, batch)
        loss = float(m["loss"])
    return cfg, model, params, corpus, loss
