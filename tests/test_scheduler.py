"""Bin-packer invariants + ContinuousScheduler lifecycle (ISSUE 1)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import (
    make_batches,
    make_corpus,
    pack_batches_token_budget,
    padding_stats,
)
from repro.serving import ContinuousScheduler, Request, simulate_continuous


# ---------------------------------------------------------------------------
# first-fit-decreasing bin packing
# ---------------------------------------------------------------------------

def _flat(bins):
    return sorted(i for b in bins for i in b)


def test_ffd_places_every_request_exactly_once():
    corpus = make_corpus(200, vocab=64, seed=2)
    bins = pack_batches_token_budget(corpus, token_budget=128)
    assert _flat(bins) == list(range(len(corpus)))


def test_ffd_respects_token_budget():
    corpus = make_corpus(150, vocab=64, seed=3)
    budget = 96
    for b in pack_batches_token_budget(corpus, budget):
        grid = max(corpus[i].n_tokens for i in b) * len(b)
        if len(b) > 1:
            assert grid <= budget
        else:
            # singleton bins may exceed the budget only because the single
            # sentence itself does
            assert grid <= budget or corpus[b[0]].n_tokens > budget


def test_ffd_oversized_sentence_gets_own_bin():
    corpus = make_corpus(40, vocab=64, seed=4, min_words=20, max_words=30)
    # budget below every sentence's token count → all singletons
    bins = pack_batches_token_budget(corpus, token_budget=2)
    assert all(len(b) == 1 for b in bins)
    assert _flat(bins) == list(range(len(corpus)))


def test_ffd_max_rows_cap():
    corpus = make_corpus(100, vocab=64, seed=5, min_words=2, max_words=3)
    bins = pack_batches_token_budget(corpus, token_budget=10_000, max_rows=8)
    assert all(len(b) <= 8 for b in bins)
    assert _flat(bins) == list(range(len(corpus)))


def test_ffd_rejects_nonpositive_budget():
    corpus = make_corpus(4, vocab=64, seed=0)
    with pytest.raises(ValueError):
        pack_batches_token_budget(corpus, token_budget=0)


def test_ffd_pad_waste_no_worse_than_greedy():
    """FFD budget bins beat unsorted greedy fixed-size batches on pad waste
    and stay close to token-sorted greedy (both place in descending order,
    but FFD trades a little padding for fewer, budget-equalized bins)."""
    corpus = make_corpus(400, vocab=64, seed=6)
    unsorted = padding_stats(corpus, make_batches(corpus, 32, "none"))
    sorted_ = padding_stats(corpus, make_batches(corpus, 32, "tokens"))
    ffd = padding_stats(corpus, pack_batches_token_budget(corpus, 32 * 40))
    assert ffd["pad_waste"] <= unsorted["pad_waste"] + 1e-9
    assert ffd["pad_waste"] <= sorted_["pad_waste"] + 0.05


@given(st.integers(min_value=1, max_value=400),
       st.integers(min_value=32, max_value=4096))
@settings(max_examples=25, deadline=None)
def test_prop_ffd_partition(n, budget):
    corpus = make_corpus(n, vocab=64, seed=n)
    bins = pack_batches_token_budget(corpus, token_budget=budget)
    assert _flat(bins) == list(range(n))
    for b in bins:
        if len(b) > 1:
            assert max(corpus[i].n_tokens for i in b) * len(b) <= budget


# ---------------------------------------------------------------------------
# ContinuousScheduler lifecycle
# ---------------------------------------------------------------------------

def _mk_requests(lengths, max_new=8):
    return [Request(req_id=i, src=np.arange(3, 3 + n, dtype=np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lengths)]


def test_lifecycle_waiting_running_finished():
    sched = ContinuousScheduler(2)
    reqs = _mk_requests([4, 5, 6])
    sched.submit_many(reqs)
    assert [r.status for r in reqs] == ["waiting"] * 3

    admitted = sched.admit(now=1.0)
    assert [r.req_id for r in admitted] == [0, 1]          # FIFO
    assert {r.slot for r in admitted} == {0, 1}            # distinct slots
    assert all(r.status == "running" and r.admitted_s == 1.0
               for r in admitted)
    assert sched.n_free == 0 and sched.n_waiting == 1
    assert sched.admit(now=2.0) == []                      # no free slot

    slot = sched.release(reqs[0], now=3.0)
    assert reqs[0].status == "finished" and reqs[0].finish_s == 3.0
    assert sched.n_free == 1

    nxt = sched.admit(now=4.0)
    assert [r.req_id for r in nxt] == [2]
    assert nxt[0].slot == slot                             # slot reuse
    sched.release(reqs[1], now=5.0)
    sched.release(reqs[2], now=5.0)
    assert sched.all_done
    assert len(sched.finished) == 3


def test_release_requires_running():
    sched = ContinuousScheduler(1)
    req = _mk_requests([3])[0]
    sched.submit(req)
    with pytest.raises(ValueError):
        sched.release(req)


def test_no_starvation_under_adversarial_length_mix():
    """Long/short interleave + tight prefill budget: strict FIFO still
    admits every request within n_requests rounds."""
    lengths = [40, 1, 40, 1, 40, 1, 40, 1, 40, 1] * 4
    reqs = _mk_requests(lengths)
    sched = ContinuousScheduler(3, prefill_token_budget=8)
    sched.submit_many(reqs)
    admitted_order = []
    rounds = 0
    while not sched.all_done:
        rounds += 1
        assert rounds <= 10 * len(reqs), "scheduler livelocked"
        batch = sched.admit(now=float(rounds))
        admitted_order.extend(r.req_id for r in batch)
        # finish one running request per round to keep slots cycling
        if sched.slot_map:
            slot = min(sched.slot_map)
            sched.release(sched.slot_map[slot], now=float(rounds))
    assert admitted_order == list(range(len(reqs)))        # FIFO, none starved


def test_prefill_budget_limits_round_but_first_always_admitted():
    sched = ContinuousScheduler(4, prefill_token_budget=10)
    reqs = _mk_requests([30, 2, 2])                        # first exceeds budget
    sched.submit_many(reqs)
    first = sched.admit()
    assert [r.req_id for r in first] == [0]                # admitted anyway
    second = sched.admit()
    assert [r.req_id for r in second] == [1, 2]


# ---------------------------------------------------------------------------
# continuous queueing model
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(min_value=1, max_value=40), min_size=2,
                max_size=60),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=40, deadline=None)
def test_prop_simulate_continuous_invariants(lens, n_slots):
    out = simulate_continuous(lens, n_slots, static_batch=n_slots)
    assert out["continuous_steps"] >= max(lens)            # critical path
    assert out["continuous_steps"] >= -(-sum(lens) // n_slots)  # work bound
    assert 0 < out["continuous_utilization"] <= 1.0 + 1e-9
    assert 0 < out["static_utilization"] <= 1.0 + 1e-9
    # slot refill never loses to batch-synchronized execution
    assert out["speedup_steps"] >= 1.0 - 1e-9
    if len(lens) % n_slots == 0:
        # with equal grid widths (no partial final batch) refill also wins
        # on utilization; a partial static batch is charged only its actual
        # rows, so its utilization can exceed the always-full-width grid
        assert (out["continuous_utilization"]
                >= out["static_utilization"] - 1e-9)


def test_simulate_continuous_skewed_gap():
    """The benchmark regime: skewed decode lengths *interleaved in arrival
    order* (lengths are unknown at schedule time) → big utilization gap."""
    lens = [4, 4, 4, 24] * 8
    out = simulate_continuous(lens, 8, static_batch=8)
    assert out["speedup_steps"] > 1.5


def test_plan_admission_device_shapes():
    """AdmissionPlan (ISSUE 4): pow2-padded device-shaped admission batch
    with OOB sentinels and row-0 replay padding; zero-budget requests are
    finished at admission and never reach the device."""
    sched = ContinuousScheduler(8)
    reqs = [Request(req_id=i, src=np.arange(3, 6 + i, dtype=np.int32),
                    max_new_tokens=m)
            for i, m in enumerate([4, 0, 5])]
    sched.submit_many(reqs)
    plan = sched.plan_admission(0.0, step=0, enc_len=8, oob_row=8)
    assert [r.req_id for r in plan.requests] == [0, 2]
    assert [r.req_id for r in plan.released] == [1]
    assert reqs[1].status == "finished" and reqs[1].tokens == []
    assert reqs[1].first_token_s is not None
    assert plan.n_admitted == 3
    assert plan.width == 2                       # next_pow2(2 live)
    assert plan.src_tokens.shape == (2, 8)
    assert plan.src_lengths.tolist() == [3, 5]
    assert plan.base_rows.tolist() == [reqs[0].slot, reqs[2].slot]

    # 3 live admissions pad to width 4: sentinel destination, row-0 replay
    sched2 = ContinuousScheduler(8)
    reqs2 = [Request(req_id=i, src=np.arange(4, dtype=np.int32) + 3)
             for i in range(3)]
    sched2.submit_many(reqs2)
    plan2 = sched2.plan_admission(0.0, step=0, enc_len=8, oob_row=8)
    assert plan2.width == 4
    assert plan2.base_rows[3] == 8                       # OOB sentinel
    assert (plan2.src_tokens[3] == plan2.src_tokens[0]).all()
    assert plan2.src_lengths[3] == plan2.src_lengths[0]

    # nothing waiting → empty plan, no device work
    plan3 = sched2.plan_admission(0.0, step=0, enc_len=8, oob_row=8)
    assert plan3.width == 0
    assert not plan3.requests and not plan3.released


def test_simulate_continuous_fused_admission_events():
    """Fused-admission queueing model (ISSUE 4): burst-granular events,
    prefill no longer a separate service event; burst_len=1 fused keeps
    the PR 1 closed-form continuous_steps (argmin packing)."""
    lens = [4, 4, 4, 24] * 4
    base = simulate_continuous(lens, 8, static_batch=8)
    free = np.zeros(8)
    for ln in lens:
        free[int(np.argmin(free))] += ln
    assert base["continuous_steps"] == int(free.max())
    assert base["prefill_events"] == 0 and base["fused_admission"]

    f = simulate_continuous(lens, 8, static_batch=8, burst_len=8)
    u = simulate_continuous(lens, 8, static_batch=8, burst_len=8,
                            fused_admission=False)
    assert f["burst_len"] == 8
    assert f["prefill_events"] == 0 and u["prefill_events"] > 0
    assert f["host_events"] < u["host_events"]
    # fused first tokens are observed at burst edges — never earlier than
    # the unfused admission-edge drain
    assert f["first_token_steps_mean"] >= u["first_token_steps_mean"]
    # token accounting is identical either way
    assert f["useful_slot_steps"] == u["useful_slot_steps"] == sum(lens)
    # group-granular events keep idle_rows accounting
    b = simulate_continuous(lens, 8, static_batch=4, beam=3, burst_len=4)
    assert b["idle_rows"] == 2 and b["n_groups"] == 2
    assert 0 < b["continuous_utilization"] <= 6.0 / 8.0 + 1e-9
    with pytest.raises(ValueError):
        simulate_continuous(lens, 8, burst_len=0)


def test_simulate_continuous_beam_groups():
    """Group-granular queueing model (ISSUE 3): a beam-B request occupies
    B rows, the grid has n_slots // B servers, and a non-dividing beam
    strands rows the utilization ceiling accounts for."""
    lens = [4, 4, 4, 24] * 4
    base = simulate_continuous(lens, 8, static_batch=4)
    assert base["beam"] == 1 and base["idle_rows"] == 0
    out = simulate_continuous(lens, 8, static_batch=4, beam=2)
    assert out["n_groups"] == 4 and out["idle_rows"] == 0
    # same requests over half the servers: ≥ the 4-server critical path
    assert out["continuous_steps"] >= base["continuous_steps"]
    assert 0 < out["continuous_utilization"] <= 1.0 + 1e-9
    assert out["speedup_steps"] >= 1.0 - 1e-9
    # beam 3 into 8 rows strands 2 rows: utilization can never reach 1
    odd = simulate_continuous(lens, 8, static_batch=4, beam=3)
    assert odd["idle_rows"] == 2
    assert odd["continuous_utilization"] <= 6.0 / 8.0 + 1e-9
    with pytest.raises(ValueError):
        simulate_continuous(lens, 2, beam=4)
