"""Self-speculative decoding (ISSUE 8): INT8-path drafts verified by one
batched multi-position pass inside the jitted burst loop.

The contract under test is **lossless verification**: greedy output must
be bit-identical to the engine's own non-speculative path for every
``speculative_k`` × ``burst_len`` (incl. auto) × fused/unfused ×
FP/INT8-verify combination — speculation may only change wall-clock and
the draft/accept counters, never a token.  On top of the identity matrix:
mid-burst EOS inside an accepted draft window, cursor rollback leaving
allocator/page state fully reclaimed, composition with chaos preemption +
overcommit growth, and a hypothesis property pinning the accept rule to
"longest agreeing prefix plus the verifier's correction".
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ServingEngine, make_chaos
from repro.serving.engine import _spec_accept

MAX_LEN = 32
PAGE_SIZE = 8
BUDGETS = [3, 7, 0, 5, 7, 2, 6, 4, 7, 3]
SPEC_KS = [1, 2, 4]
# 64 and "auto" share one compiled ring bucket (AUTO_MAX_BURST == 64), so
# the matrix covers three cap regimes for two bursts' worth of compiles
BURST_LENS = [2, 64, "auto"]

_CACHED = {}


def _module_state():
    if "engines" not in _CACHED:
        cfg = get_config("transformer-base").reduced(
            vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
            n_heads=2, n_kv_heads=2, head_dim=24)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams, qctx = quantize_model(params, {},
                                       QuantPolicy(act_quant="dynamic"))
        _CACHED.update(
            cfg=cfg, model=model, params=params, qparams=qparams, qctx=qctx,
            engines={
                "fp": ServingEngine(model, params, max_len=MAX_LEN),
                "int8_paged": ServingEngine(model, qparams, quant=qctx,
                                            max_len=MAX_LEN, paged=True,
                                            page_size=PAGE_SIZE),
            },
            srcs=[r.src for r in make_corpus(len(BUDGETS), cfg.vocab,
                                             seed=11, max_words=8)])
    return _CACHED


def _ref_tokens(engine, srcs, **kw):
    key = ("ref", id(engine)) + tuple(sorted(kw.items()))
    if key not in _CACHED:
        res = engine.serve(srcs, n_slots=4, max_new_tokens=BUDGETS, **kw)
        _CACHED[key] = [list(np.asarray(res.tokens_for(i)))
                        for i in range(len(srcs))]
    return _CACHED[key]


# ------------------------------------------------------------ identity matrix
@pytest.mark.parametrize("quant", ["fp", "int8_paged"])
@pytest.mark.parametrize("fused", [True, False])
def test_serve_speculative_identity_matrix(quant, fused):
    s = _module_state()
    eng = s["engines"][quant]
    ref = _ref_tokens(eng, s["srcs"])
    for k in SPEC_KS:
        for bl in BURST_LENS:
            res = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS,
                            burst_len=bl, fused_admission=fused,
                            speculative_k=k)
            for i in range(len(s["srcs"])):
                assert list(np.asarray(res.tokens_for(i))) == ref[i], \
                    (quant, fused, k, bl)
            assert res.speculative_k == k
            assert res.draft_tokens > 0
            assert 0 <= res.accepted_tokens <= res.draft_tokens
            assert 0.0 <= res.acceptance_rate <= 1.0
            assert res.metrics()["acceptance_rate"] == res.acceptance_rate


def test_generate_speculative_identity():
    s = _module_state()
    eng = s["engines"]["fp"]
    src, lens = pad_batch([x for x in s["srcs"][:4]])
    batch = {"src_tokens": src, "src_lengths": lens}
    base = eng.generate(batch, max_new_tokens=9)
    for k in SPEC_KS:
        res = eng.generate(batch, max_new_tokens=9, speculative_k=k)
        for a, b in zip(base.tokens, res.tokens):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert res.speculative_k == k and res.draft_tokens > 0
        assert 0.0 <= res.acceptance_rate <= 1.0


def test_speculative_distinct_draft_context_still_lossless():
    """A deliberately crude draft context (coarse static activation
    thresholds — cheap, numerically different from the dynamic verifier)
    must lower acceptance at most, never change a token: emitted tokens
    always come from the verifier."""
    from repro.core.ptq import QuantContext
    s = _module_state()
    draft_ctx = QuantContext(policy=QuantPolicy(act_quant="static",
                                                default_amax=4.0))
    eng = ServingEngine(s["model"], s["qparams"], quant=s["qctx"],
                        draft_quant=draft_ctx, max_len=MAX_LEN,
                        paged=True, page_size=PAGE_SIZE)
    base = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS)
    res = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS,
                    speculative_k=3)
    for a, b in zip(base.requests, res.requests):
        assert a.tokens == b.tokens
    assert res.draft_tokens > 0


def test_speculative_rejects_beam_and_bad_k():
    s = _module_state()
    eng = s["engines"]["fp"]
    with pytest.raises(ValueError):
        eng.serve(s["srcs"][:2], n_slots=4, max_new_tokens=4, beam=2,
                  speculative_k=2)
    with pytest.raises(ValueError):
        eng.serve(s["srcs"][:2], n_slots=4, max_new_tokens=4,
                  speculative_k=-1)
    with pytest.raises(ValueError):
        eng.generate({"src_tokens": np.zeros((1, 4), np.int32),
                      "src_lengths": np.asarray([4], np.int32)},
                     speculative_k=-3)


# ------------------------------------------------- mid-burst EOS in a window
def test_speculative_eos_inside_accepted_window():
    """EOS emitted by the verifier *inside* an accepted draft window must
    terminate the row exactly where sequential decode would: find a
    frequently emitted token via a probe serve, rebuild the engine with it
    as eos_id, and pin the speculative output to the non-speculative one."""
    s = _module_state()
    probe = s["engines"]["fp"].serve(s["srcs"], n_slots=4,
                                     max_new_tokens=BUDGETS)
    emitted = [t for r in probe.requests for t in r.tokens]
    assert emitted
    fake_eos = int(np.bincount(emitted).argmax())
    eng = ServingEngine(s["model"], s["params"], max_len=MAX_LEN,
                        eos_id=fake_eos)
    base = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS)
    assert any(len(r.tokens) < r.max_new_tokens for r in base.requests), \
        "probe failed to produce a mid-budget EOS"
    for k in (2, 4):
        res = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS,
                        speculative_k=k, burst_len=64)
        for a, b in zip(base.requests, res.requests):
            assert a.tokens == b.tokens, (k, a.req_id)


# ------------------------------------------------------------- page rollback
def test_speculative_rollback_full_reclaim():
    """Rejected draft positions only ever touch KV past the accepted
    cursor: after a speculative serve the allocator must be exactly as
    reclaimed as after the step-by-step serve (no leaked or double-freed
    pages, same reservation high-water mark)."""
    s = _module_state()
    eng = s["engines"]["int8_paged"]
    base = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS)
    res = eng.serve(s["srcs"], n_slots=4, max_new_tokens=BUDGETS,
                    speculative_k=4)
    assert res.pages_in_use == 0
    assert res.page_hwm == base.page_hwm
    for a, b in zip(base.requests, res.requests):
        assert a.tokens == b.tokens


# ------------------------------------------------------- chaos × speculation
@pytest.mark.parametrize("k", [2, 4])
def test_speculative_chaos_identity(k):
    """Speculation composed with forced preemption + overcommit growth:
    tokens identical to an unloaded non-speculative serve, every page
    reclaimed, spill store drained.  Overcommit exercises the spec-scaled
    page growth (each macro-step may append spec+1 KV positions)."""
    s = _module_state()
    eng = s["engines"]["int8_paged"]
    budgets = [13, 17, 0, 15, 16, 12, 14, 13, 17, 15]
    base = eng.serve(s["srcs"], n_slots=4, max_new_tokens=budgets)
    # burst_len=1: a speculative burst emits up to k+1 tokens per round,
    # so requests span several rounds and the round-edge chaos schedule
    # actually catches mid-flight victims (longer bursts finish a whole
    # admission wave inside one round — nothing left to preempt)
    res = eng.serve(s["srcs"], n_slots=4, max_new_tokens=budgets,
                    speculative_k=k, overcommit=1.5, burst_len=1,
                    chaos=make_chaos(4, n_rounds=64, preempt_every=1))
    assert res.preemptions > 0          # the schedule actually fired
    for a, b in zip(base.requests, res.requests):
        assert a.tokens == b.tokens
    assert res.pages_in_use == 0
    assert res.spill_events == res.restore_events


# ------------------------------------------------------- accept-rule property
def _ref_accept(d_row, v_row, remaining, eos):
    """Pure-python oracle for one row of _spec_accept."""
    s = len(d_row)
    a = 0
    while a < s and d_row[a] == v_row[a]:
        a += 1
    cand = a + 1
    eos_first = next((i for i, t in enumerate(v_row) if t == eos), s + 1)
    stop = min(cand, eos_first + 1, remaining) if remaining > 0 else 0
    hit_eos = remaining > 0 and (eos_first + 1) <= min(cand, remaining)
    return stop, hit_eos, min(a, stop)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=5),
                min_size=2, max_size=10),
       st.integers(min_value=0, max_value=12),
       st.integers(min_value=0, max_value=5))
def test_accept_rule_longest_agreeing_prefix(seq, remaining, eos):
    """The accepted prefix is always the longest agreeing one, clamped by
    budget and EOS; the emitted window always ends with a verifier token."""
    s = len(seq) - 1
    d_row = seq[:s]
    # verifier row (length s+1): either shifted (agreement only where the
    # sequence happens to repeat) or a full copy of the draft + one more
    v_row = (list(seq[1:]) + [seq[0]]) if remaining % 2 \
        else list(d_row) + [seq[0]]
    d = jnp.asarray([d_row], jnp.int32)
    v = jnp.asarray([v_row], jnp.int32)
    rem = jnp.asarray([remaining], jnp.int32)
    stop, hit_eos, acc = _spec_accept(d, v, rem, eos)
    want = _ref_accept(d_row, v_row, remaining, eos)
    got = (int(stop[0]), bool(hit_eos[0]), int(acc[0]))
    assert got == want, (d_row, v_row, remaining, eos, got, want)
    # invariants: at least one token per active row, never over budget,
    # accepted prefix is exactly the agreeing run inside the window
    if remaining > 0:
        assert 1 <= got[0] <= min(s + 1, remaining)
    else:
        assert got == (0, False, 0)
    assert got[2] <= got[0]
    for i in range(got[2]):
        assert d_row[i] == v_row[i]
    if got[0] == got[2] and got[0] < min(s, remaining) and not got[1]:
        # window ended below every clamp → the next pair must disagree
        assert got[0] == s or d_row[got[0]] != v_row[got[0]]
