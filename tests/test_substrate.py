"""Substrate tests: data pipeline/sorting, BLEU, checkpointing (fault
tolerance + elastic restore), optimizer, serving scheduler/streams,
gradient compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import Checkpointer
from repro.data import (
    LMBatches,
    TranslationBatches,
    corpus_bleu,
    make_batches,
    make_corpus,
    padding_stats,
)
from repro.distributed import (
    StepWatchdog,
    run_with_restarts,
    tree_ef_compressed_mean,
    wire_bytes_fp32_allreduce,
    wire_bytes_int8_gather,
)
from repro.optim import AdamW, inverse_sqrt, warmup_cosine
from repro.serving import TokenSortedScheduler, simulate_streams


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_corpus_words_vs_tokens():
    corpus = make_corpus(100, vocab=64, seed=1)
    assert any(s.n_tokens != s.n_words for s in corpus)
    assert all(s.n_tokens >= s.n_words for s in corpus)


def test_token_sorting_reduces_padding():
    """Paper §5.4: token-sorted batching wastes less padding than unsorted,
    and at least as little as word-sorted."""
    corpus = make_corpus(600, vocab=256, seed=2)
    stats = {m: padding_stats(corpus, make_batches(corpus, 64, m))
             for m in ("none", "words", "tokens")}
    assert stats["tokens"]["pad_waste"] < stats["none"]["pad_waste"]
    assert stats["tokens"]["pad_waste"] <= stats["words"]["pad_waste"] + 1e-9


def test_translation_batches_resume_exactly():
    corpus = make_corpus(64, vocab=64, seed=3)
    a = TranslationBatches(corpus, 8, seed=5)
    for _ in range(3):
        a.next_batch()
    state = a.state_dict()
    want = a.next_batch()

    b = TranslationBatches(corpus, 8, seed=0)
    b.load_state_dict(state)
    got = b.next_batch()
    np.testing.assert_array_equal(want["src_tokens"], got["src_tokens"])


def test_bleu_properties():
    ref = [[3, 4, 5, 6, 7, 8]]
    assert corpus_bleu(ref, ref) == pytest.approx(100.0)
    assert corpus_bleu([[9, 10, 11, 12, 13, 14]], ref) == 0.0
    partial = corpus_bleu([[3, 4, 5, 6, 9, 10]], ref)
    assert 0.0 < partial < 100.0


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(rng):
    tree = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32),
            "nested": {"b": jnp.arange(3)}}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for step in (1, 2, 3):
            ck.save(step, tree)
        assert ck.all_steps() == [2, 3]          # retention
        out = ck.restore(tree)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(tree["w"]))


def test_checkpoint_atomicity_tmp_never_visible(rng):
    tree = {"w": jnp.zeros((8,))}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(7, tree)
        assert not any(n.startswith("tmp") for n in os.listdir(d))
        assert ck.latest_step() == 7


def test_checkpoint_restores_quantized_tree(rng):
    from repro.core import QuantPolicy, quantize_model
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("yi-9b").reduced(n_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp, _ = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, qp)
        out = ck.restore(qp)
        a = jax.tree_util.tree_leaves(out)
        b = jax.tree_util.tree_leaves(qp)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_run_with_restarts_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("preempted")

    run_with_restarts(flaky, max_restarts=5)
    assert calls["n"] == 3


def test_watchdog_flags_straggler():
    wd = StepWatchdog(threshold=2.0)
    import time
    for _ in range(8):
        wd.start(); time.sleep(0.002); wd.stop()
    wd.start(); time.sleep(0.05)
    assert wd.stop() is True
    assert wd.summary()["stragglers"] >= 1


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_schedules_shapes():
    lr = inverse_sqrt(512)
    warm = float(lr(jnp.asarray(100)))
    peak = float(lr(jnp.asarray(4000)))
    late = float(lr(jnp.asarray(40000)))
    assert warm < peak and late < peak
    wc = warmup_cosine(1e-3, 10, 100)
    assert float(wc(jnp.asarray(5))) < 1e-3
    assert float(wc(jnp.asarray(100))) < float(wc(jnp.asarray(20)))


# ---------------------------------------------------------------------------
# serving scheduler / streams
# ---------------------------------------------------------------------------

def test_scheduler_plan_covers_all_requests():
    corpus = make_corpus(50, vocab=64, seed=4)
    sched = TokenSortedScheduler(batch_size=8)
    items = sched.plan(corpus)
    covered = sorted(i for item in items for i in item.indices)
    assert covered == list(range(50))
    # token-sorted: batch maxima non-increasing
    maxima = [max(corpus[i].n_tokens for i in item.indices)
              for item in items]
    assert maxima == sorted(maxima, reverse=True)


def test_simulate_streams_parallel_speedup():
    """Paper §5.6/Fig 6: mixed long/short batches gain from parallel
    streams; utilization stays ≤ 1."""
    costs = [8.0, 1.0] * 10
    serial = simulate_streams(costs, 1)
    par = simulate_streams(costs, 2)
    assert par["speedup_vs_serial"] > 1.6
    assert serial["utilization"] == pytest.approx(1.0)
    assert par["utilization"] <= 1.0


@given(st.lists(st.floats(min_value=0.1, max_value=10.0), min_size=2,
                max_size=40),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=40, deadline=None)
def test_prop_stream_simulation_invariants(costs, n):
    out = simulate_streams(costs, n)
    assert out["makespan_s"] >= max(costs) - 1e-9          # critical path
    assert out["makespan_s"] <= sum(costs) + 1e-9          # never worse than serial
    assert out["speedup_vs_serial"] <= n + 1e-9            # bounded by streams


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_ef_compression_unbiased_over_steps(rng):
    """Error feedback: accumulated compressed updates converge to the true
    gradient sum over repeated steps (bias is pushed into the residual)."""
    from repro.distributed import make_mesh, shard_map
    mesh = make_mesh((1,), ("data",), explicit=True)

    def one_body(gx, err):
        return tree_ef_compressed_mean(gx, err, "data", 1)

    one = shard_map(one_body, mesh=mesh,
                    in_specs=(jax.sharding.PartitionSpec(),
                              jax.sharding.PartitionSpec()),
                    out_specs=(jax.sharding.PartitionSpec(),
                               jax.sharding.PartitionSpec()))

    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for step in range(8):
        out, err = one(g, err)
        applied = applied + out
        # error feedback: applied-so-far + residual == true sum exactly
        np.testing.assert_allclose(np.asarray(applied + err),
                                   np.asarray(g * (step + 1)),
                                   rtol=1e-4, atol=1e-4)
    # per-step quantization error is bounded by one int8 step
    np.testing.assert_allclose(np.asarray(out), np.asarray(g),
                               atol=float(jnp.max(jnp.abs(g))) / 127 + 1e-6)


def test_compression_wire_math():
    n = 1_000_000
    fp32 = wire_bytes_fp32_allreduce(n, 16)
    int8 = wire_bytes_int8_gather(n, 16)
    assert fp32 / int8 == pytest.approx(8.0, rel=1e-6)
