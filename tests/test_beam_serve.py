"""Continuous beam serving (ISSUE 3): beam-group slot lifecycle end-to-end.

``serve(beam=B)`` — beam groups of contiguous rows flowing through the
continuous-batching grid — must be **token-identical** to per-request
``generate_beam`` for every beam width, burst length, and KV-cache dtype
(FP and INT8), including mid-burst group finish, zero-budget requests, and
group refill mid-decode.  A property layer locks down the scheduler's
group invariants: no slot double-assignment, freed rows always multiples
of ``beam``, every admitted request finishes exactly once.

Fused admission (ISSUE 4): beam admissions ride the burst program with
**encode-once** prefill — each admitted source is encoded once and its
memory/cross-KV broadcast across the group's ``beam`` rows (the unfused
path tiles it ``beam×`` through the encoder), and the group's first-step
top-k comes out of the shared beam step via the ``[0, -1e30, …]`` score
seed.  The fused-vs-unfused matrix below pins both paths to per-request
``generate_beam`` and asserts the ``beam×`` encoder-token reduction.
"""

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, ServingEngine

BEAMS = [1, 4]
BURST_LENS = [1, 2, 7]
BUDGETS = [3, 7, 0, 5, 6, 2, 7, 4]          # incl. zero-budget request


def _make_engines():
    """One tiny dispatch-dominated config; FP and INT8 engines share it."""
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=2, n_kv_heads=2, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    engines = {
        "fp": ServingEngine(model, params, max_len=32),
        "int8": ServingEngine(model, qparams, quant=qctx, max_len=32),
    }
    assert engines["int8"].quant.quantize_kv          # INT8 KV cache is on
    return cfg, model, params, engines


_CACHED = {}


def _module_state():
    """Module-level cache (plain dict, not a fixture, so the hypothesis
    fallback's zero-arg wrappers can reach it too)."""
    if "engines" not in _CACHED:
        cfg, model, params, engines = _make_engines()
        _CACHED.update(
            cfg=cfg, model=model, params=params, engines=engines,
            requests=make_corpus(len(BUDGETS), cfg.vocab, seed=11,
                                 max_words=8),
            refs={})
    return _CACHED


def _beam_each(engine, requests, budgets, beam):
    """Per-request ``generate_beam`` reference (burst_len=1 — the per-step
    path), truncated to each request's budget."""
    outs = []
    for s, cap in zip(requests, budgets):
        src, lens = pad_batch([s.src])
        res = engine.generate_beam({"src_tokens": src, "src_lengths": lens},
                                   beam=beam, max_new_tokens=int(cap),
                                   burst_len=1)
        outs.append(np.asarray(res.tokens[0])[:int(cap)])
    return outs


def _reference(quant, beam):
    """BUDGETS references, computed once per (engine, beam)."""
    state = _module_state()
    key = (quant, beam)
    if key not in state["refs"]:
        state["refs"][key] = _beam_each(state["engines"][quant],
                                        state["requests"], BUDGETS, beam)
    return state["refs"][key]


# --------------------------------------------------------------- identity
@pytest.mark.parametrize("quant", ["fp", "int8"])
@pytest.mark.parametrize("burst_len", BURST_LENS)
@pytest.mark.parametrize("beam", BEAMS)
def test_serve_beam_token_identical_to_generate_beam(quant, burst_len, beam):
    """serve(beam=B) == per-request generate_beam for B ∈ {1, 4},
    burst_len ∈ {1, 2, 7}, FP and INT8 KV cache, over heterogeneous
    budgets (incl. zero-budget) with group refill (8 requests, 3 groups).
    """
    state = _module_state()
    engine, requests = state["engines"][quant], state["requests"]
    res = engine.serve(requests, n_slots=3 * beam, max_new_tokens=BUDGETS,
                       burst_len=burst_len, beam=beam)
    want = _reference(quant, beam)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert all(r.status == "finished" for r in res.requests)
    assert res.tokens_for(2).size == 0          # zero-budget stayed empty
    assert res.beam == beam and res.burst_len == burst_len
    assert res.n_slots == 3 * beam and res.n_groups == 3
    # group refill happened: 8 requests through 3 groups needs ≥ 3 prefills
    assert res.prefill_rounds >= 3


@pytest.mark.parametrize("quant", ["fp", "int8"])
@pytest.mark.parametrize("burst_len", [2, 7])
@pytest.mark.parametrize("beam", BEAMS)
def test_fused_vs_unfused_beam_identity(quant, burst_len, beam):
    """Fused (encode-once, admission-in-burst) vs unfused (PR 3 tiled
    side-batch prefill) beam serving: token-identical to each other and to
    per-request generate_beam; the fused path dispatches no prefills and
    pays ≥ beam× fewer encoder row-tokens."""
    state = _module_state()
    engine, requests = state["engines"][quant], state["requests"]
    fused = engine.serve(requests, n_slots=3 * beam, max_new_tokens=BUDGETS,
                         burst_len=burst_len, beam=beam)
    unfused = engine.serve(requests, n_slots=3 * beam,
                           max_new_tokens=BUDGETS, burst_len=burst_len,
                           beam=beam, fused_admission=False)
    want = _reference(quant, beam)
    for i in range(len(requests)):
        np.testing.assert_array_equal(fused.tokens_for(i), want[i])
        np.testing.assert_array_equal(unfused.tokens_for(i), want[i])
    assert fused.fused_admission and not unfused.fused_admission
    assert fused.prefill_dispatches == 0
    assert unfused.prefill_dispatches == unfused.prefill_rounds >= 3
    # encode-once broadcast: the unfused side batch tiles each source
    # beam× through the encoder (and also encodes the zero-budget request)
    assert unfused.encoder_tokens >= beam * fused.encoder_tokens > 0
    assert fused.host_syncs < unfused.host_syncs
    assert all(r.first_token_s is not None for r in fused.requests)


def test_fused_beam_zero_budget_only():
    """All-zero-budget beam stream under fused admission: finished at
    admission, nothing encoded, no decode steps."""
    state = _module_state()
    engine, requests = state["engines"]["fp"], state["requests"]
    res = engine.serve(requests[:3], n_slots=4, max_new_tokens=0, beam=2)
    assert all(r.status == "finished" and not r.tokens
               for r in res.requests)
    assert res.decode_steps == 0
    assert res.prefill_dispatches == 0 and res.encoder_tokens == 0


def test_serve_beam_auto_burst_identity():
    """burst_len='auto' through the beam grid stays identical to the
    per-request reference."""
    state = _module_state()
    engine, requests = state["engines"]["fp"], state["requests"]
    res = engine.serve(requests, n_slots=4, max_new_tokens=BUDGETS,
                       burst_len="auto", beam=2)
    want = _reference("fp", 2)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert res.auto_burst and res.prefill_dispatches == 0


def test_mid_burst_group_finish():
    """Redefine eos_id to a token the model actually emits so whole groups
    finish *inside* a burst; outputs must still match the per-step path
    and the per-request reference, and freed groups must be refilled."""
    state = _module_state()
    model, params = state["model"], state["params"]
    requests = state["requests"]
    probe = state["engines"]["fp"].serve(requests, n_slots=2,
                                         max_new_tokens=8, burst_len=1)
    emitted = [t for r in probe.requests for t in r.tokens[1:]]
    assert emitted, "probe produced no tokens"
    fake_eos = int(np.bincount(emitted).argmax())

    eng = ServingEngine(model, params, eos_id=fake_eos, max_len=32)
    want = _beam_each(eng, requests, [8] * len(requests), 2)
    per_step = eng.serve(requests, n_slots=4, max_new_tokens=8,
                         burst_len=1, beam=2)
    burst = eng.serve(requests, n_slots=4, max_new_tokens=8,
                      burst_len=8, beam=2)
    # mid-burst group finish + same-burst-edge refill under UNFUSED
    # admission must agree too (the refill prefill replays PR 3 exactly)
    unfused = eng.serve(requests, n_slots=4, max_new_tokens=8,
                        burst_len=8, beam=2, fused_admission=False)
    stopped_early = 0
    for i in range(len(requests)):
        np.testing.assert_array_equal(per_step.tokens_for(i), want[i])
        np.testing.assert_array_equal(burst.tokens_for(i), want[i])
        np.testing.assert_array_equal(unfused.tokens_for(i), want[i])
        if len(want[i]) < 8:
            stopped_early += 1
    assert stopped_early > 0            # groups actually finished mid-run
    # bursts trade host syncs for frozen-group steps at burst edges
    assert burst.host_syncs < per_step.host_syncs
    # 8 requests through 2 groups: groups freed mid-serve were refilled
    assert burst.prefill_rounds >= 3 and burst.prefill_dispatches == 0
    assert burst.host_syncs < unfused.host_syncs


def test_serve_result_beam_group_aware():
    """Regression (ServeResult assumed one row per request): tokens_for
    returns the winning hypothesis, utilization counts all group rows,
    metrics expose beam/n_groups, winners carry their scores."""
    state = _module_state()
    engine, requests = state["engines"]["fp"], state["requests"]
    res = engine.serve(requests, n_slots=4, max_new_tokens=BUDGETS,
                       burst_len=2, beam=2)
    m = res.metrics()
    assert m["beam"] == 2.0 and m["n_groups"] == 2.0
    assert res.n_groups == 2
    # busy accounting is in rows: a 2-row group contributes 2 per step
    assert res.busy_slot_steps % res.beam == 0
    assert 0.0 < res.utilization <= 1.0
    assert res.busy_slot_steps <= res.n_slots * res.decode_steps
    want = _reference("fp", 2)
    for i, r in enumerate(res.requests):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
        if r.max_new_tokens > 0:
            assert r.score is not None  # winning length-penalized log-prob
        assert r.finish_step is not None and r.admitted_step is not None
        assert r.finish_step >= r.admitted_step
    # greedy results keep the one-row-per-request defaults
    greedy = engine.serve(requests[:2], n_slots=2, max_new_tokens=4)
    assert greedy.beam == 1 and greedy.n_groups == greedy.n_slots


def test_serve_beam_rejects_bad_config():
    state = _module_state()
    engine, requests = state["engines"]["fp"], state["requests"]
    with pytest.raises(ValueError):
        engine.serve(requests[:2], n_slots=2, beam=3)   # group can't fit
    with pytest.raises(ValueError):
        engine.serve(requests[:2], n_slots=4, beam=0)
    # non-dividing beam: grid shrinks to whole groups (stranded rows)
    res = engine.serve(requests[:2], n_slots=5, max_new_tokens=3, beam=2)
    assert res.n_slots == 4 and res.n_groups == 2


# --------------------------------------------------------------- property
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=20, deadline=None)
def test_property_scheduler_group_invariants(beam, n_groups, seed):
    """Random request mixes through random admit/release interleavings
    never violate the group lifecycle: no slot double-assignment, groups
    row-disjoint, freed rows multiples of ``beam``, every admitted request
    finishes exactly once."""
    rng = np.random.default_rng(seed)
    rows = beam * n_groups
    sched = ContinuousScheduler(rows, group_size=beam)
    n_req = int(rng.integers(1, 13))
    reqs = [Request(req_id=i,
                    src=np.arange(3, 4 + int(rng.integers(0, 5)),
                                  dtype=np.int32))
            for i in range(n_req)]
    sched.submit_many(reqs)
    finishes = {r.req_id: 0 for r in reqs}
    occupied = {}                                   # base row → req_id
    while not sched.all_done:
        for r in sched.admit(0.0):
            assert r.slot is not None and r.slot % beam == 0
            assert 0 <= r.slot <= rows - beam
            assert r.slot not in occupied           # no double assignment
            occupied[r.slot] = r.req_id
        running = list(sched.slot_map.values())
        assert running, "scheduler wedged: waiting but nothing running"
        k = int(rng.integers(1, len(running) + 1))
        for i in rng.choice(len(running), size=k, replace=False):
            req = running[int(i)]
            base = req.slot
            freed = sched.release(req)
            assert freed == base and freed % beam == 0
            assert occupied.pop(freed) == req.req_id
            finishes[req.req_id] += 1
    assert all(n == 1 for n in finishes.values())   # exactly once each
    assert sched.n_free == n_groups and not occupied


@given(st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=9))
@settings(max_examples=4, deadline=None)
def test_property_serve_beam_identity(burst_len, seed):
    """Random burst lengths × random budget mixes through the real engine:
    serve(beam=2) matches per-request generate_beam and every request
    finishes exactly once."""
    state = _module_state()
    engine, requests = state["engines"]["fp"], state["requests"][:6]
    rng = np.random.default_rng(seed)
    budgets = [int(b) for b in rng.integers(0, 8, size=len(requests))]
    res = engine.serve(requests, n_slots=4, max_new_tokens=budgets,
                       burst_len=burst_len, beam=2)
    want = _beam_each(engine, requests, budgets, 2)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    ids = [r.req_id for r in res.requests]
    assert sorted(ids) == list(range(len(requests)))
    assert all(r.status == "finished" for r in res.requests)
