"""Decode bursts (ISSUE 2): serve/generate/generate_beam fused into an
on-device ``lax.while_loop`` must stay token-identical to the per-step
path for every burst length — including mid-burst EOS, zero-budget
requests, slot refill, and beam reordering.

Fused admission (ISSUE 4): admissions ride the burst program — encode +
cross-KV splice + first token happen inside the same jitted dispatch as
the decode loop.  The identity matrix below pins fused output to both the
unfused (PR 3, separate-prefill) path and per-request ``generate``, and
``burst_len="auto"`` (the AdaptiveBurst controller) to the fixed-K
output."""

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.data import make_corpus
from repro.data.sorting import next_pow2
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ServingEngine

BURST_LENS = [1, 2, 7, 64]
BUDGETS = [3, 7, 0, 5, 7, 2, 6, 4, 7, 3]       # incl. zero-budget request


def _make_engine():
    """One tiny dispatch-dominated config for every test in this module."""
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=2, n_kv_heads=2, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, ServingEngine(model, params, max_len=32)


@pytest.fixture(scope="module")
def setup():
    cfg, model, params, engine = _make_engine()
    requests = make_corpus(10, cfg.vocab, seed=11, max_words=8)
    return cfg, model, params, requests, engine


_CACHED = {}


def _module_engine():
    """Engine accessor for property tests (the hypothesis-compat fallback
    wraps tests into zero-arg callables, so pytest fixtures are not
    available there)."""
    if "engine" not in _CACHED:
        cfg, _, _, engine = _make_engine()
        _CACHED["engine"] = engine
        _CACHED["requests"] = make_corpus(8, cfg.vocab, seed=3, max_words=8)
    return _CACHED["engine"], _CACHED["requests"]


def _generate_each(engine, requests, budgets):
    outs = []
    for s, cap in zip(requests, budgets):
        src, lens = pad_batch([s.src])
        res = engine.generate({"src_tokens": src, "src_lengths": lens},
                              max_new_tokens=int(cap), burst_len=1)
        outs.append(np.asarray(res.tokens[0])[:int(cap)])
    return outs


@pytest.fixture(scope="module")
def reference_outputs(setup):
    """Per-request per-step generate() outputs for BUDGETS (computed once —
    every swept burst length is compared against the same reference)."""
    cfg, model, params, requests, engine = setup
    return _generate_each(engine, requests, BUDGETS)


def test_next_pow2():
    assert [next_pow2(n) for n in (0, 1, 2, 3, 4, 5, 7, 8, 9, 64, 65)] == \
        [1, 1, 2, 4, 4, 8, 8, 8, 16, 64, 128]


@pytest.mark.parametrize("burst_len", BURST_LENS)
def test_serve_burst_token_identical_to_generate(setup, reference_outputs,
                                                 burst_len):
    """serve(burst_len=K) == per-request generate() for K ∈ {1, 2, 7, 64},
    over heterogeneous budgets (incl. zero-budget) with slot refill."""
    cfg, model, params, requests, engine = setup
    res = engine.serve(requests, n_slots=3, max_new_tokens=BUDGETS,
                       burst_len=burst_len)
    want = reference_outputs
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert all(r.status == "finished" for r in res.requests)
    assert res.tokens_for(2).size == 0          # zero-budget stayed empty
    assert res.burst_len == burst_len
    # slot refill happened: 10 requests through 3 slots needs ≥ 4 prefills
    assert res.prefill_rounds >= 4


def test_mid_burst_eos(setup):
    """Redefine eos_id to a token the model actually emits so sequences
    finish *inside* a burst; outputs must still match the per-step path
    and freed slots must be refilled at burst edges."""
    cfg, model, params, requests, engine = setup
    probe = engine.serve(requests, n_slots=2, max_new_tokens=8, burst_len=1)
    emitted = [t for r in probe.requests for t in r.tokens[1:]]
    assert emitted, "probe produced no tokens"
    fake_eos = int(np.bincount(emitted).argmax())

    eng = ServingEngine(model, params, eos_id=fake_eos, max_len=32)
    per_step = eng.serve(requests, n_slots=2, max_new_tokens=8, burst_len=1)
    burst = eng.serve(requests, n_slots=2, max_new_tokens=8, burst_len=8)
    stopped_early = 0
    for i in range(len(requests)):
        np.testing.assert_array_equal(burst.tokens_for(i),
                                      per_step.tokens_for(i))
        if len(per_step.tokens_for(i)) < 8:
            stopped_early += 1
    assert stopped_early > 0                    # EOS actually fired mid-run
    # bursts trade host syncs for wasted masked steps at burst edges
    assert burst.host_syncs < per_step.host_syncs
    assert burst.decode_steps >= per_step.decode_steps


def test_generate_burst_identity(setup):
    cfg, model, params, requests, engine = setup
    src, lens = pad_batch([s.src for s in requests[:4]], length=16)
    batch = {"src_tokens": src, "src_lengths": lens}
    ref = engine.generate(batch, max_new_tokens=12, burst_len=1)
    for k in [2, 7, 64]:
        got = engine.generate(batch, max_new_tokens=12, burst_len=k)
        assert len(got.tokens) == len(ref.tokens)
        for a, b in zip(ref.tokens, got.tokens):
            np.testing.assert_array_equal(a, b)
        assert got.host_syncs <= ref.host_syncs
    assert ref.tokens_per_s >= 0 and ref.decode_steps_per_s >= 0


@pytest.mark.parametrize("burst_len", [1, 4])
def test_beam_burst_identity(setup, burst_len):
    """Beam burst (top-k + cache gather inside the scanned body) matches
    the per-step beam path at K ∈ {1, 4}."""
    cfg, model, params, requests, engine = setup
    src, lens = pad_batch([s.src for s in requests[:3]], length=16)
    batch = {"src_tokens": src, "src_lengths": lens}
    ref = engine.generate_beam(batch, beam=3, max_new_tokens=8, burst_len=1)
    got = engine.generate_beam(batch, beam=3, max_new_tokens=8,
                               burst_len=burst_len)
    assert len(got.tokens) == len(ref.tokens)
    for a, b in zip(ref.tokens, got.tokens):
        np.testing.assert_array_equal(a, b)
    if burst_len > 1:
        assert got.host_syncs <= ref.host_syncs


def test_burst_metrics_and_syncs(setup):
    cfg, model, params, requests, engine = setup
    per_step = engine.serve(requests, n_slots=4, max_new_tokens=6,
                            burst_len=1)
    burst = engine.serve(requests, n_slots=4, max_new_tokens=6, burst_len=8)
    m1, m8 = per_step.metrics(), burst.metrics()
    for m in (m1, m8):
        assert m["host_syncs"] >= 1
        assert m["decode_steps_per_s"] > 0
        assert m["tokens_per_s"] > 0
    assert m1["burst_len"] == 1 and m8["burst_len"] == 8
    # per-step pays ≥ one sync per decode step; bursts amortize them
    assert per_step.host_syncs >= per_step.decode_steps
    assert burst.host_syncs < per_step.host_syncs
    # step attribution is exact even though wall latency is burst-edge
    for r in burst.requests:
        assert r.finish_step is not None and r.admitted_step is not None
        assert r.finish_step >= r.admitted_step


def test_burst_rejects_bad_length(setup):
    cfg, model, params, requests, engine = setup
    with pytest.raises(ValueError):
        engine.serve(requests[:2], n_slots=2, burst_len=0)
    with pytest.raises(ValueError):
        ServingEngine(model, params, max_len=32, burst_len=0)


@pytest.mark.parametrize("burst_len", BURST_LENS)
def test_fused_admission_identity(setup, reference_outputs, burst_len):
    """Fused admission (default) vs the PR 3 unfused path: token-identical
    for K ∈ {1, 2, 7, 64} over heterogeneous budgets (incl. zero-budget)
    with slot refill; the fused path dispatches zero host-side prefills
    and never encodes the zero-budget request."""
    cfg, model, params, requests, engine = setup
    fused = engine.serve(requests, n_slots=3, max_new_tokens=BUDGETS,
                         burst_len=burst_len)
    unfused = engine.serve(requests, n_slots=3, max_new_tokens=BUDGETS,
                           burst_len=burst_len, fused_admission=False)
    for i in range(len(requests)):
        np.testing.assert_array_equal(fused.tokens_for(i),
                                      unfused.tokens_for(i))
        np.testing.assert_array_equal(fused.tokens_for(i),
                                      reference_outputs[i])
    assert fused.fused_admission and not unfused.fused_admission
    assert fused.prefill_dispatches == 0
    assert unfused.prefill_dispatches == unfused.prefill_rounds >= 4
    # the zero-budget request finishes at admission, unencoded
    assert 0 < fused.encoder_tokens < unfused.encoder_tokens
    if burst_len > 1:
        # admission rounds no longer pay a separate prefill drain
        assert fused.host_syncs < unfused.host_syncs
    assert all(r.status == "finished" for r in fused.requests)
    assert all(r.first_token_s is not None for r in fused.requests)


def test_fused_zero_budget_only(setup):
    """An all-zero-budget stream under fused admission: finished at
    admission with empty outputs, no device work at all."""
    cfg, model, params, requests, engine = setup
    res = engine.serve(requests[:4], n_slots=2, max_new_tokens=0)
    assert all(r.status == "finished" and not r.tokens
               for r in res.requests)
    assert all(r.first_token_s is not None for r in res.requests)
    assert res.decode_steps == 0
    assert res.prefill_dispatches == 0 and res.encoder_tokens == 0


def test_adaptive_burst_controller_unit():
    """AdaptiveBurst: pow2 caps in [1, max_burst], grows on zero waste,
    shrinks when waste exceeds the estimated sync cost, burn-in ignored."""
    from repro.serving.burst_control import AdaptiveBurst
    ctrl = AdaptiveBurst(start=8, max_burst=32)
    assert ctrl.k == 8 and ctrl.max_burst == 32
    ctrl.observe(5.0, 8, 0, 4)               # burn-in (compile pass)
    assert ctrl.k == 8
    for _ in range(4):                       # no mid-burst waste → grow
        ctrl.observe(0.08, 8, 0, 4)
    assert ctrl.k == 32 and ctrl.grows >= 2
    for _ in range(8):                       # waste ≫ sync cost → shrink
        ctrl.observe(0.32, 32, 64, 4)
    assert ctrl.k == 1 and ctrl.shrinks >= 5
    # caps always pow2 and bounded
    assert ctrl.max_burst == 32
    with pytest.raises(ValueError):
        AdaptiveBurst(max_burst=0)


def test_adaptive_burst_no_spurious_shrink_on_first_eos():
    """Regression: the first *measured* burst's per-step time carries the
    full sync overhead, so seeding ``t_sync = wall − steps·t_step ≈ 0``
    from it made ANY mid-burst EOS in the next bursts look more expensive
    than a sync and shrink ``k`` spuriously.  The controller must not
    adapt until both estimates are grounded."""
    from repro.serving.burst_control import AdaptiveBurst
    ctrl = AdaptiveBurst(start=8, max_burst=32)
    ctrl.observe(5.0, 8, 0, 4)               # burn-in 1: compile pass
    assert ctrl.k == 8 and ctrl.shrinks == 0 and ctrl.grows == 0
    # burn-in 2 (first measured burst): seeds estimates, must NOT adapt —
    # even though it reports mid-burst waste
    ctrl.observe(1.0, 8, 8, 4)
    assert ctrl.k == 8 and ctrl.shrinks == 0 and ctrl.grows == 0
    assert ctrl.t_step_s > 0.0 and ctrl.t_sync_s > 0.0
    # the old controller shrank HERE: waste_s = (8/4)·t_step > t_sync ≈ 0.
    # With t_sync seeded from a wall fraction, modest one-off waste
    # (2 whole-grid steps ≈ 0.25 s vs seeded sync 0.1 s) may still shrink
    # once on overwhelming evidence, but a *sync-dominated* trace with a
    # stray EOS must not collapse: waste far below the sync estimate.
    before = ctrl.k
    ctrl.observe(1.0, 8, 1, 4)               # one row finished 1 step early
    assert ctrl.k >= before // 2             # at worst one halving…
    for _ in range(6):                       # …and a clean trace re-grows
        ctrl.observe(1.0, 8, 0, 4)
    assert ctrl.k >= before
    # invariants: k stays pow2 in [1, max_burst] through arbitrary traces
    seen = set()
    for wall, steps, waste in [(0.01, 1, 0), (9.0, 32, 128), (0.5, 8, 3),
                               (2.0, 16, 64), (0.001, 1, 0), (3.0, 32, 0)]:
        k = ctrl.observe(wall, steps, waste, 4)
        seen.add(k)
    assert all(1 <= k <= 32 and (k & (k - 1)) == 0 for k in seen)


def test_adaptive_burst_sync_dominated_trace_never_collapses():
    """With bursts whose wall time is dominated by the fixed sync cost
    (true step cost 1 ms, sync ~0.1 s) and a little EOS waste every
    burst, the controller must not collapse to k=1: the spurious-shrink
    bug (t_sync seeded ≈0 from the first measured burst) drove exactly
    this trace to the floor, paying a full sync per decoded token."""
    from repro.serving.burst_control import AdaptiveBurst
    ctrl = AdaptiveBurst(start=4, max_burst=64)
    ctrl.observe(5.0, 4, 0, 8)               # compile
    ctrl.observe(0.2, 4, 2, 8)               # first measured: seeds only
    assert ctrl.k == 4 and ctrl.shrinks == 0 and ctrl.grows == 0
    for _ in range(24):
        k = ctrl.k
        ctrl.observe(0.1 + 0.001 * k, k, 2, 8)
        assert 1 < ctrl.k <= 64 and (ctrl.k & (ctrl.k - 1)) == 0
    # shrink/grow may oscillate while the estimates settle, but the cap
    # must end no lower than it started in a sync-dominated regime
    assert ctrl.k >= 4


def test_serve_auto_burst_identity(setup, reference_outputs):
    """burst_len='auto' (controller-paced caps under one compiled ring
    bucket) stays token-identical to the fixed-K/per-request output."""
    cfg, model, params, requests, engine = setup
    res = engine.serve(requests, n_slots=3, max_new_tokens=BUDGETS,
                       burst_len="auto")
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i),
                                      reference_outputs[i])
    assert res.auto_burst
    k = res.burst_len
    assert k >= 1 and (k & (k - 1)) == 0          # pow2 cap
    with pytest.raises(ValueError):
        engine.serve(requests[:2], n_slots=2, burst_len="bogus")


@given(st.integers(min_value=1, max_value=11),
       st.integers(min_value=0, max_value=9))
@settings(max_examples=8, deadline=None)
def test_property_serve_burst_identity(burst_len, seed):
    """Random burst lengths × random budget mixes: serve(burst_len=K) is
    token-identical to the per-step per-request path."""
    engine, requests = _module_engine()
    rng = np.random.default_rng(seed)
    budgets = [int(b) for b in rng.integers(0, 9, size=len(requests))]
    res = engine.serve(requests, n_slots=3, max_new_tokens=budgets,
                       burst_len=burst_len)
    want = _generate_each(engine, requests, budgets)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
