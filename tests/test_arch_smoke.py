"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates its REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.  The
FULL configs are exercised only via the dry-run (abstract, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.optim import AdamW
from repro.train import make_train_step

ARCHS = [a for a in list_archs()]


def _batch_for(cfg, rng, B=2, S=32):
    if cfg.enc_dec:
        b = {"tgt_tokens": jnp.asarray(rng.integers(3, cfg.vocab, (B, S))),
             "tgt_lengths": jnp.asarray([S, S - 4], jnp.int32)}
        if cfg.input_kind == "embeddings":
            b["src_embeds"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        else:
            b["src_tokens"] = jnp.asarray(rng.integers(3, cfg.vocab, (B, S)))
        b["src_lengths"] = jnp.asarray([S, S], jnp.int32)
        return b
    if cfg.input_kind == "embeddings":
        return {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32),
                "labels": jnp.asarray(rng.integers(3, cfg.vocab, (B, S)))}
    return {"tokens": jnp.asarray(rng.integers(3, cfg.vocab, (B, S))),
            "labels": jnp.asarray(rng.integers(3, cfg.vocab, (B, S)))}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # spot-check the published numbers survived
    assert cfg.n_layers >= 6 and cfg.d_model >= 512 and cfg.vocab > 30_000


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, rng, B, S)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab), (arch, logits.shape)
    assert not np.any(np.isnan(np.asarray(logits))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch_for(cfg, rng)
    (params2, _), metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # parameters actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pair: acc or bool(pair),
        jax.tree_util.tree_map(
            lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
            params, params2),
        False)
    assert moved, arch


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "transformer-base"])
def test_reduced_serve_step(arch, rng):
    """One prefill + one decode step with the INT8 path (paper technique)."""
    from repro.core import QuantPolicy, quantize_model
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp, qctx = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"))
    B, S = 2, 16
    batch = _batch_for(cfg, rng, B, S)
    batch.pop("labels", None)
    extra = {"enc_len": S} if cfg.enc_dec else {}
    state = model.init_decode_state(B, 48, quantized=True, **extra)
    logits, state = model.prefill(qp, batch, state, quant=qctx)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, axis=-1)
    logits2, state = model.decode_step(qp, tok, state, quant=qctx)
    assert logits2.shape == (B, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits2))), arch
