"""Paged INT8 KV cache (ISSUE 5): block tables end to end.

Three layers of coverage:

* **Allocator / scheduler properties** (hypothesis-compat): no page is
  ever double-assigned, refcounts return to zero after release, freed
  requests' pages are fully reclaimed, and mixed-beam admission churn
  never deadlocks against a page budget.
* **Cache-op units**: paged append/linearize round-trips against the
  contiguous cache, the zero-copy beam reorder (`gather_beams_paged`)
  agrees logically with the slab gather, freed rows' writes drop, and the
  paged Pallas flash-decode kernel (interpret mode) matches the pure-jnp
  oracle including sentinel table entries.
* **Engine identity matrix**: `serve(paged=True)` — greedy and beam,
  beam ∈ {1, 4} and per-request mixed widths, FP and INT8 cache, fused
  and unfused admission, several burst lengths incl. ``auto`` — is
  token-identical to the unpaged engine (and therefore to per-request
  ``generate``/``generate_beam``), with every page returned by the end,
  even when the page pool is smaller than contiguous-equivalent capacity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.kernels import ops, ref
from repro.models import build_model
from repro.models import kv_cache as kvc
from repro.serving import ContinuousScheduler, Request, ServingEngine

MAX_LEN = 32
PAGE_SIZE = 8
BUDGETS = [3, 7, 0, 5, 6, 2]
MIXED_WIDTHS = [4, 2, 1, 3, 4, 2]


# ------------------------------------------------------------------ fixtures
_CACHED = {}


def _module_state():
    if "engines" not in _CACHED:
        cfg = get_config("transformer-base").reduced(
            vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
            n_heads=2, n_kv_heads=2, head_dim=24)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams, qctx = quantize_model(params, {},
                                       QuantPolicy(act_quant="dynamic"))
        engines = {
            "fp": ServingEngine(model, params, max_len=MAX_LEN),
            "int8": ServingEngine(model, qparams, quant=qctx,
                                  max_len=MAX_LEN),
            "fp_paged": ServingEngine(model, params, max_len=MAX_LEN,
                                      paged=True, page_size=PAGE_SIZE),
            "int8_paged": ServingEngine(model, qparams, quant=qctx,
                                        max_len=MAX_LEN, paged=True,
                                        page_size=PAGE_SIZE),
        }
        assert engines["int8_paged"].quant.quantize_kv
        _CACHED.update(
            cfg=cfg, model=model, params=params, engines=engines,
            requests=make_corpus(len(BUDGETS), cfg.vocab, seed=11,
                                 max_words=8),
            refs={})
    return _CACHED


def _reference(quant, beam):
    """Per-request reference streams, computed once per (engine, beam)."""
    state = _module_state()
    key = (quant, tuple(beam) if isinstance(beam, list) else beam)
    if key not in state["refs"]:
        eng = state["engines"][quant]
        outs = []
        widths = beam if isinstance(beam, list) else [beam] * len(BUDGETS)
        for s, cap, b in zip(state["requests"], BUDGETS, widths):
            src, lens = pad_batch([s.src])
            if beam is None:
                res = eng.generate({"src_tokens": src, "src_lengths": lens},
                                   max_new_tokens=int(cap), burst_len=1)
            else:
                res = eng.generate_beam(
                    {"src_tokens": src, "src_lengths": lens}, beam=int(b),
                    max_new_tokens=int(cap), burst_len=1)
            outs.append(np.asarray(res.tokens[0])[:int(cap)])
        state["refs"][key] = outs
    return state["refs"][key]


# ---------------------------------------------------------------- allocator
def test_allocator_basics():
    al = kvc.PageAllocator(8, 4)
    a = al.alloc(3)
    b = al.alloc(5)
    assert sorted(a + b) == list(range(8))
    assert al.alloc(1) is None and al.n_free == 0 and al.in_use == 8
    al.release(a)
    assert al.n_free == 3 and al.hwm == 8
    c = al.alloc(2)
    assert not set(c) & set(b)          # no double assignment
    al.release(b)
    al.release(c)
    assert al.in_use == 0
    assert all(al.refcount(p) == 0 for p in range(8))


def test_allocator_refcounts():
    al = kvc.PageAllocator(4, 4)
    pages = al.alloc(2)
    al.retain(pages)                     # rc = 2
    al.release(pages)                    # rc = 1: still held
    assert al.in_use == 2
    al.release(pages)                    # rc = 0: reclaimed
    assert al.in_use == 0
    with pytest.raises(ValueError):
        al.release(pages)
    with pytest.raises(ValueError):
        al.retain(pages)


@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=20, deadline=None)
def test_property_allocator_churn(n_pages, seed):
    """Random alloc/retain/release interleavings: pages are exclusive
    while held, every refcount returns to zero, the free list is exactly
    the complement of live pages, and the pool is whole at the end."""
    rng = np.random.default_rng(seed)
    al = kvc.PageAllocator(n_pages, 4)
    live = []                            # list of (pages, extra_refs)
    for _ in range(40):
        op = rng.integers(0, 3)
        if op == 0:
            n = int(rng.integers(0, n_pages + 1))
            free_before = al.n_free
            got = al.alloc(n)
            if n > free_before:
                assert got is None       # over-ask must fail, not oversell
            if got is not None:
                flat = [p for ps, _ in live for p in ps]
                assert not set(got) & set(flat)      # exclusivity
                live.append((got, 0))
        elif op == 1 and live:
            i = int(rng.integers(0, len(live)))
            al.retain(live[i][0])
            live[i] = (live[i][0], live[i][1] + 1)
        elif op == 2 and live:
            i = int(rng.integers(0, len(live)))
            pages, extra = live.pop(i)
            for _ in range(extra + 1):
                al.release(pages)
        held = sum(len(ps) for ps, _ in live)
        assert al.in_use == held and al.n_free == n_pages - held
    for pages, extra in live:
        for _ in range(extra + 1):
            al.release(pages)
    assert al.in_use == 0
    assert all(al.refcount(p) == 0 for p in range(n_pages))


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=15, deadline=None)
def test_property_mixed_beam_admission_never_deadlocks(max_beam, seed):
    """Scheduler + allocator churn with random mixed beam widths and
    budgets against a page pool: admission must always make progress
    (never wedge with work waiting and nothing running), freed requests'
    pages must be fully reclaimed, and every request finishes once."""
    rng = np.random.default_rng(seed)
    page_size = 4
    n_groups = int(rng.integers(1, 4))
    rows = max_beam * n_groups
    # pool just big enough for the worst single request, so the gate binds
    worst = max_beam * kvc.pages_per_row(16, page_size)
    n_pages = int(rng.integers(worst, 2 * worst + 1))
    al = kvc.PageAllocator(n_pages, page_size)

    def cost(req):
        return req.beam * al.pages_for_tokens(req.max_new_tokens)

    sched = ContinuousScheduler(rows, group_size=max_beam, allocator=al,
                                pages_per_request=cost)
    reqs = [Request(req_id=i, src=np.arange(3, dtype=np.int32),
                    max_new_tokens=int(rng.integers(1, 17)),
                    beam=int(rng.integers(1, max_beam + 1)))
            for i in range(int(rng.integers(1, 13)))]
    sched.submit_many(reqs)
    finishes = {r.req_id: 0 for r in reqs}
    for _ in range(10 ** 4):
        if sched.all_done:
            break
        sched.admit(0.0)
        running = list(sched.slot_map.values())
        assert running, "admission wedged with requests waiting"
        held = [p for r in running for p in r.pages]
        assert len(held) == len(set(held))           # exclusive while held
        assert al.in_use == len(held)
        k = int(rng.integers(1, len(running) + 1))
        for i in rng.choice(len(running), size=k, replace=False):
            finishes[running[int(i)].req_id] += 1
            sched.release(running[int(i)])
    assert sched.all_done
    assert all(n == 1 for n in finishes.values())
    assert al.in_use == 0                            # fully reclaimed
    assert all(al.refcount(p) == 0 for p in range(n_pages))


def test_allocator_release_is_atomic():
    """A bad release (double free, out-of-pool id, duplicate ids whose
    combined drop exceeds the refcount) raises WITHOUT mutating: the
    regression was validate-while-mutating, which returned a prefix of
    the list before raising and left the pool inconsistent."""
    al = kvc.PageAllocator(8, 4)
    a = al.alloc(3)
    b = al.alloc(2)
    al.release(b)

    def snapshot():
        return ([al.refcount(p) for p in range(8)], al.n_free, al.in_use)

    before = snapshot()
    with pytest.raises(ValueError):
        al.release(a + b)            # b already free: would drop a first
    assert snapshot() == before      # ...but must not have
    with pytest.raises(ValueError):
        al.release([a[0], a[0]])     # duplicate ids vs refcount 1
    assert snapshot() == before
    with pytest.raises(ValueError):
        al.release([a[0], 99])       # out-of-pool id after a valid one
    assert snapshot() == before
    al.release(a)                    # the valid release still works
    assert al.in_use == 0


def test_allocator_alloc_raises_on_corrupt_pool():
    """Double-assignment detection is a raised exception (not a bare
    assert that vanishes under ``python -O``), and alloc validates before
    popping so the free list survives the error."""
    al = kvc.PageAllocator(4, 4)
    with pytest.raises(ValueError):
        al.alloc(-1)
    held = al.alloc(2)
    # white-box corruption: a free-listed page with a live refcount
    victim = next(p for p in range(4) if p not in held)
    al._refcount[victim] = 1
    free_before = al.n_free
    with pytest.raises(RuntimeError):
        al.alloc(4 - len(held))
    assert al.n_free == free_before  # peek-validate: nothing left the list
    al._refcount[victim] = 0
    got = al.alloc(2)
    assert sorted(held + got) == list(range(4))


@given(st.integers(min_value=2, max_value=32),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=20, deadline=None)
def test_property_shared_reservation_churn(n_pages, seed):
    """Chains with refcounts > 1 (one owner + independent readers, the
    prefix-cache shape): random retain/release interleavings keep
    ``in_use`` equal to the pages with any live reference, never free a
    page early, and fully reclaim once every reference drops."""
    rng = np.random.default_rng(seed)
    al = kvc.PageAllocator(n_pages, 4)
    chains = []                          # (pages, n_refs) — owner + readers
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:
            got = al.alloc(int(rng.integers(1, n_pages + 1)))
            if got is not None:
                chains.append([got, 1])
        elif op == 1 and chains:
            c = chains[int(rng.integers(0, len(chains)))]
            al.retain(c[0])              # a reader joins
            c[1] += 1
        elif op == 2 and chains:
            i = int(rng.integers(0, len(chains)))
            chains[i][1] -= 1            # one reference drops
            al.release(chains[i][0])
            if chains[i][1] == 0:
                pages = chains.pop(i)[0]
                assert all(al.refcount(p) == 0 for p in pages)
        live = {p for c in chains for p in c[0]}
        assert al.in_use == len(live)
        for pages, refs in chains:
            assert all(al.refcount(p) == refs for p in pages)
    for pages, refs in chains:
        for _ in range(refs):
            al.release(pages)
    assert al.in_use == 0
    assert all(al.refcount(p) == 0 for p in range(n_pages))


@pytest.mark.parametrize("quantized", [False, True])
def test_cow_never_writes_shared_page(rng, quantized):
    """Copy-on-write invariant: resolving a row's write slot never writes
    a page with refcount > 1 — the shared source page's payload is
    bit-unchanged and the copy lands in the row's own reservation."""
    paged, _ = _paged_with_rows(rng, quantized=quantized, n_rows=2,
                                lengths=(6, 6))
    sentinel = paged.n_pages
    al = kvc.PageAllocator(paged.n_pages, 4)
    shared = al.alloc(2)                 # both rows read these
    own0 = al.alloc(2)                   # each row's private reservation
    own1 = al.alloc(2)
    al.retain(shared)                    # rc 2: a second reader joined
    sp = 6 // 4                          # the partial write slot
    tables = np.full((2, 4), sentinel, np.int32)
    own = np.full((2, 4), sentinel, np.int32)
    tables[0, :2] = tables[1, :2] = shared
    own[0, :2], own[1, :2] = own0, own1
    cache = kvc.PagedKVCache(
        k=paged.k, v=paged.v, k_scale=paged.k_scale, v_scale=paged.v_scale,
        block_tables=jnp.asarray(tables), own_pages=jnp.asarray(own),
        lengths=paged.lengths)
    out = kvc.cow_write_slot(cache)
    tab_after = np.asarray(out.block_tables)
    for r in range(2):
        dst = int(tab_after[r, sp])
        assert al.refcount(dst) == 1, (
            f"CoW wrote page {dst} with refcount {al.refcount(dst)}")
        assert dst == int(own[r, sp])    # the row's own reservation
    # shared page payload bit-unchanged; the copy carries its history
    src = int(tables[1, sp])
    np.testing.assert_array_equal(np.asarray(out.k[:, src]),
                                  np.asarray(cache.k[:, src]))
    np.testing.assert_array_equal(
        np.asarray(out.k[:, int(tab_after[1, sp])]),
        np.asarray(cache.k[:, src]))
    # full (pre-slot) shared pages stay shared — no copy amplification
    np.testing.assert_array_equal(tab_after[:, :sp], tables[:, :sp])


@given(st.integers(min_value=1, max_value=12),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=15, deadline=None)
def test_property_prefix_admission_never_deadlocks(pool_pages, seed):
    """Prefix-cache admission against an arbitrarily small chain pool
    always makes progress: every admit() returns hit/insert/skip (skip =
    serve uncached), eviction only touches unreferenced chains, and after
    every reader finishes + clear() the pool is fully reclaimed."""
    from repro.serving.prefix_cache import PrefixCache
    rng = np.random.default_rng(seed)
    pc = PrefixCache(kvc.PageAllocator(pool_pages, 4))
    sources = [np.asarray(rng.integers(1, 9, size=rng.integers(1, 13)),
                          np.int32) for _ in range(6)]
    open_chains = []
    for _ in range(80):
        if open_chains and rng.random() < 0.4:
            pc.finish(open_chains.pop(int(rng.integers(0,
                                                       len(open_chains)))))
            continue
        src = sources[int(rng.integers(0, len(sources)))]
        role, chain = pc.admit(src)
        assert role in ("hit", "insert", "skip")
        if role == "skip":
            assert chain is None         # uncached but never wedged
        else:
            assert chain.src_len == len(src)
            open_chains.append(chain)
    for chain in open_chains:
        pc.finish(chain)
    pc.clear()
    assert pc.n_chains == 0
    assert pc.allocator.in_use == 0
    assert all(pc.allocator.refcount(p) == 0 for p in range(pool_pages))


# ------------------------------------------------------------- cache units
def _paged_with_rows(rng, *, quantized, n_rows=3, lengths=(5, 8, 0)):
    """A paged cache with per-row reservations + the contiguous cache
    holding the same logical contents, built by appending tokens."""
    L, HKV, DH = 2, 2, 4
    ps, max_len = 4, 16
    al = kvc.PageAllocator(n_rows * max_len // ps, ps)
    paged = kvc.init_paged_cache(L, n_rows, max_len, HKV, DH, page_size=ps,
                                 quantized=quantized, dtype=jnp.float32)
    flat = kvc.init_cache(L, n_rows, max_len, HKV, DH, quantized=quantized,
                          dtype=jnp.float32)
    pages = np.full((n_rows, max_len // ps), paged.n_pages, np.int32)
    for r in range(n_rows):
        got = al.alloc(max_len // ps)
        pages[r] = got
    paged = kvc.assign_pages(paged, jnp.arange(n_rows), jnp.asarray(pages))
    for t in range(max(lengths)):
        k_new = jnp.asarray(rng.normal(size=(n_rows, 1, HKV, DH)),
                            jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(n_rows, 1, HKV, DH)),
                            jnp.float32)
        cur = jnp.asarray([min(t, n) for n in lengths], jnp.int32)
        live = np.asarray([t < n for n in lengths])
        # contiguous append (drop rows already at their target length by
        # pointing their cursor past capacity — mirrors finished rows)
        cur_flat = jnp.where(jnp.asarray(live), cur, flat.capacity)
        k_c, v_c, ks_c, vs_c = kvc.append_token(
            flat.k[0], flat.v[0],
            None if not quantized else flat.k_scale[0],
            None if not quantized else flat.v_scale[0],
            k_new, v_new, cur_flat)
        flat = kvc.KVCache(k=flat.k.at[0].set(k_c), v=flat.v.at[0].set(v_c),
                           k_scale=(None if not quantized
                                    else flat.k_scale.at[0].set(ks_c)),
                           v_scale=(None if not quantized
                                    else flat.v_scale.at[0].set(vs_c)),
                           lengths=flat.lengths)
        cur_paged = jnp.where(jnp.asarray(live), cur, paged.capacity)
        kp, vp, ksp, vsp = kvc.append_token_paged(
            paged.k[0], paged.v[0],
            None if not quantized else paged.k_scale[0],
            None if not quantized else paged.v_scale[0],
            paged.block_tables, k_new, v_new, cur_paged)
        paged = kvc.PagedKVCache(
            k=paged.k.at[0].set(kp), v=paged.v.at[0].set(vp),
            k_scale=(None if not quantized
                     else paged.k_scale.at[0].set(ksp)),
            v_scale=(None if not quantized
                     else paged.v_scale.at[0].set(vsp)),
            block_tables=paged.block_tables, own_pages=paged.own_pages,
            lengths=paged.lengths)
    lengths = jnp.asarray(lengths, jnp.int32)
    paged = kvc.PagedKVCache(k=paged.k, v=paged.v, k_scale=paged.k_scale,
                             v_scale=paged.v_scale,
                             block_tables=paged.block_tables,
                             own_pages=paged.own_pages, lengths=lengths)
    flat = kvc.KVCache(k=flat.k, v=flat.v, k_scale=flat.k_scale,
                       v_scale=flat.v_scale, lengths=lengths)
    return paged, flat


@pytest.mark.parametrize("quantized", [False, True])
def test_paged_append_linearizes_to_contiguous(rng, quantized):
    """Tokens appended through block tables read back (linearized) exactly
    as the contiguous cache's rows, for every valid position."""
    paged, flat = _paged_with_rows(rng, quantized=quantized)
    lin_k = np.asarray(kvc.linearize_pages(paged.k[0], paged.block_tables))
    lin_v = np.asarray(kvc.linearize_pages(paged.v[0], paged.block_tables))
    for r, n in enumerate(np.asarray(paged.lengths)):
        np.testing.assert_array_equal(lin_k[r, :n],
                                      np.asarray(flat.k[0, r, :n]))
        np.testing.assert_array_equal(lin_v[r, :n],
                                      np.asarray(flat.v[0, r, :n]))
    if quantized:
        lin_ks = np.asarray(kvc.linearize_pages(paged.k_scale[0],
                                                paged.block_tables))
        for r, n in enumerate(np.asarray(paged.lengths)):
            np.testing.assert_array_equal(
                lin_ks[r, :n], np.asarray(flat.k_scale[0, r, :n]))


@pytest.mark.parametrize("quantized", [False, True])
def test_gather_beams_paged_matches_slab_gather(rng, quantized):
    """The block-table permutation + partial-page copy produces the same
    *logical* rows as the full slab gather, and the next append after the
    reorder lands in a privately-owned page (no cross-row corruption)."""
    paged, flat = _paged_with_rows(rng, quantized=quantized, n_rows=4,
                                   lengths=(6, 6, 6, 6))
    idx = jnp.asarray([2, 2, 0, 1], jnp.int32)
    g_flat = kvc.gather_beams(flat, idx)
    g_paged = kvc.gather_beams_paged(paged, idx)
    np.testing.assert_array_equal(np.asarray(g_paged.lengths),
                                  np.asarray(g_flat.lengths))
    lin = np.asarray(kvc.linearize_pages(g_paged.k[0],
                                         g_paged.block_tables))
    for r in range(4):
        np.testing.assert_array_equal(lin[r, :6],
                                      np.asarray(g_flat.k[0, r, :6]))
    # rows 0 and 1 both gathered row 2: appending different tokens next
    # must not collide (each row's write slot points into its own pages)
    k_new = jnp.asarray(rng.normal(size=(4, 1, 2, 4)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(4, 1, 2, 4)), jnp.float32)
    kp, vp, _, _ = kvc.append_token_paged(
        g_paged.k[0], g_paged.v[0],
        None if not quantized else g_paged.k_scale[0],
        None if not quantized else g_paged.v_scale[0],
        g_paged.block_tables, k_new, v_new, g_paged.lengths)
    lin2 = np.asarray(kvc.linearize_pages(kp, g_paged.block_tables))
    for r in range(4):
        np.testing.assert_array_equal(lin2[r, :6], lin[r, :6])  # history kept
        if quantized:
            continue                     # int8 rounding covered via engine
        np.testing.assert_allclose(lin2[r, 6], np.asarray(k_new[r, 0]),
                                   rtol=1e-6)


def test_free_slots_paged_drops_writes(rng):
    """A freed row's table goes to sentinel: its later appends vanish
    instead of landing in (possibly reallocated) pages."""
    paged, _ = _paged_with_rows(rng, quantized=False)
    freed = kvc.free_slots_paged(paged, jnp.asarray([0, 1, 2], jnp.int32))
    assert np.all(np.asarray(freed.lengths) == 0)
    assert np.all(np.asarray(freed.block_tables) == paged.n_pages)
    k_new = jnp.asarray(rng.normal(size=(3, 1, 2, 4)), jnp.float32)
    kp, _, _, _ = kvc.append_token_paged(
        freed.k[0], freed.v[0], None, None, freed.block_tables,
        k_new, k_new, jnp.zeros((3,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(freed.k[0]))
    # reserved rows' appends (same cursors) do land
    assert not np.array_equal(
        np.asarray(kvc.append_token_paged(
            paged.k[0], paged.v[0], None, None, paged.block_tables,
            k_new, k_new, jnp.zeros((3,), jnp.int32))[0]),
        np.asarray(paged.k[0]))


def test_paged_kernel_interpret_matches_oracle(rng):
    """Pallas paged flash-decode (scalar-prefetched block-table walk) vs
    the pure-jnp oracle, including a sentinel table entry."""
    B, H, HKV, dh, P, ps, maxP = 3, 4, 2, 8, 16, 4, 4
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, (P, ps, HKV, dh)), jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (P, ps, HKV, dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.02, (P, ps, HKV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.02, (P, ps, HKV)), jnp.float32)
    tab = jnp.asarray(rng.permutation(P)[:B * maxP].reshape(B, maxP),
                      jnp.int32)
    tab = tab.at[0, 3].set(P)                        # unreserved tail
    lengths = jnp.asarray([11, 16, 5], jnp.int32)
    want = ref.ref_decode_attention_paged(q, kp, ks, vp, vs, tab, lengths,
                                          0.35)
    got = ops.decode_attention_paged(q, kp, ks, vp, vs, tab, lengths,
                                     sm_scale=0.35, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("ps,maxP", [(2, 5), (2, 8), (4, 3)])
def test_paged_kernel_multi_page_blocks_interpret(rng, ps, maxP):
    """``page_size < 8`` pools fetch SUBLANE//ps consecutive slots per
    grid step (multi-page sublane blocks) — parity vs the oracle must
    hold including odd slot counts (sentinel-padded to a block multiple)
    and an explicit ``pages_per_block`` override."""
    from repro.kernels.decode_attention import decode_attention_paged_pallas

    B, H, HKV, dh, P = 3, 4, 2, 8, 32
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, (P, ps, HKV, dh)), jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (P, ps, HKV, dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.02, (P, ps, HKV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.02, (P, ps, HKV)), jnp.float32)
    tab = np.full((B, maxP), P, np.int32)
    perm = rng.permutation(P)
    c = 0
    lengths = np.zeros((B,), np.int32)
    for b in range(B):                    # dense-prefix tables, ragged tails
        n = int(rng.integers(1, maxP + 1))
        tab[b, :n] = perm[c:c + n]
        c += n
        lengths[b] = int(rng.integers(1, n * ps + 1))
    tab, lengths = jnp.asarray(tab), jnp.asarray(lengths)
    want = ref.ref_decode_attention_paged(q, kp, ks, vp, vs, tab, lengths,
                                          0.35)
    got = decode_attention_paged_pallas(q, kp, ks, vp, vs, tab, lengths,
                                        sm_scale=0.35, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # explicit override and the single-page path agree with auto
    for f in (1, 2):
        forced = decode_attention_paged_pallas(
            q, kp, ks, vp, vs, tab, lengths, sm_scale=0.35, interpret=True,
            pages_per_block=f)
        np.testing.assert_allclose(np.asarray(forced), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_init_paged_cache_validates_page_multiple():
    with pytest.raises(ValueError):
        kvc.init_paged_cache(1, 2, 30, 2, 4, page_size=8, quantized=False)
    with pytest.raises(ValueError):
        ServingEngine(object(), {}, max_len=30, paged=True, page_size=8)


# ------------------------------------------------------- engine identity
@pytest.mark.parametrize("quant", ["fp", "int8"])
@pytest.mark.parametrize("burst_len", [1, 3])
@pytest.mark.parametrize("fused", [True, False])
def test_paged_greedy_identity(quant, burst_len, fused):
    """Paged greedy serve == unpaged serve == per-request generate, and
    every page comes back to the pool."""
    state = _module_state()
    requests = state["requests"]
    res = state["engines"][f"{quant}_paged"].serve(
        requests, n_slots=3, max_new_tokens=BUDGETS, burst_len=burst_len,
        fused_admission=fused)
    want = _reference(quant, None)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert res.paged and res.page_size == PAGE_SIZE
    assert res.pages_in_use == 0
    assert 0 < res.page_hwm <= 3 * (MAX_LEN // PAGE_SIZE)
    assert res.reorder_bytes == 0        # greedy: nothing to reorder


@pytest.mark.parametrize("quant", ["fp", "int8"])
@pytest.mark.parametrize("burst_len", [1, 3])
@pytest.mark.parametrize("beam", [1, 4])
@pytest.mark.parametrize("fused", [True, False])
def test_paged_beam_identity(quant, burst_len, beam, fused):
    """Paged beam serve (zero-copy block-table reorder) is token-identical
    to per-request generate_beam for beam ∈ {1, 4}, FP and INT8 cache,
    fused and unfused admission."""
    state = _module_state()
    requests = state["requests"]
    res = state["engines"][f"{quant}_paged"].serve(
        requests, n_slots=2 * beam, max_new_tokens=BUDGETS,
        burst_len=burst_len, beam=beam, fused_admission=fused)
    want = _reference(quant, beam)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert res.pages_in_use == 0 and res.paged
    assert res.reorder_bytes > 0


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("fused", [True, False])
def test_mixed_beam_widths_identity(paged, fused):
    """Mixed per-request beam widths in ONE grid: every request matches
    its own generate_beam(beam=width) stream — parked rows never leak a
    hypothesis — on both the paged and unpaged engines."""
    state = _module_state()
    requests = state["requests"]
    eng = state["engines"]["fp_paged" if paged else "fp"]
    res = eng.serve(requests, n_slots=8, max_new_tokens=BUDGETS,
                    burst_len=3, beam=MIXED_WIDTHS, fused_admission=fused)
    want = _reference("fp", MIXED_WIDTHS)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert res.beam == max(MIXED_WIDTHS)
    assert all(r.status == "finished" for r in res.requests)


def test_paged_auto_burst_identity():
    """burst_len='auto' (adaptive cap) over the paged cache stays
    token-identical for greedy and beam serving."""
    state = _module_state()
    requests = state["requests"]
    eng = state["engines"]["fp_paged"]
    res = eng.serve(requests, n_slots=3, max_new_tokens=BUDGETS,
                    burst_len="auto")
    for i, w in enumerate(_reference("fp", None)):
        np.testing.assert_array_equal(res.tokens_for(i), w)
    res = eng.serve(requests, n_slots=4, max_new_tokens=BUDGETS,
                    burst_len="auto", beam=2)
    for i, w in enumerate(_reference("fp", 2)):
        np.testing.assert_array_equal(res.tokens_for(i), w)
    assert res.auto_burst and res.paged and res.pages_in_use == 0


def test_request_reuse_does_not_pin_beam():
    """Regression: serve() must not write its default width into the
    caller's Request objects — a reused Request once served with beam=2
    must follow a later serve's beam=4, not silently stay 2-wide."""
    state = _module_state()
    eng = state["engines"]["fp"]
    reqs = [Request(req_id=i, src=np.asarray(s.src, np.int32),
                    max_new_tokens=int(b))
            for i, (s, b) in enumerate(zip(state["requests"], BUDGETS))]
    eng.serve(reqs, n_slots=4, max_new_tokens=BUDGETS, beam=2)
    assert all(r.beam is None for r in reqs)         # caller-owned field
    res = eng.serve(reqs, n_slots=8, max_new_tokens=BUDGETS, beam=4)
    want = _reference("fp", 4)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])


def test_paged_admission_against_page_budget():
    """A pool smaller than contiguous-equivalent capacity throttles
    admission instead of deadlocking or corrupting: identity holds, the
    high-water mark respects the budget, and narrow-beam requests reserve
    fewer pages than the grid width would."""
    state = _module_state()
    model, params = state["model"], state["params"]
    requests = state["requests"]
    # 2 pages: only 2 of the 3 grid rows can hold requests at once — the
    # page gate (not row capacity) paces admission
    eng = ServingEngine(model, params, max_len=MAX_LEN, paged=True,
                        page_size=PAGE_SIZE, n_pages=2)
    res = eng.serve(requests, n_slots=3, max_new_tokens=BUDGETS,
                    burst_len=2)
    want = _reference("fp", None)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert res.pages_in_use == 0 and res.page_hwm <= 2
    # a request whose reservation exceeds the pool is rejected up front
    with pytest.raises(ValueError):
        eng.serve(requests, n_slots=3, max_new_tokens=MAX_LEN)


def test_paged_result_metrics_exposed():
    state = _module_state()
    res = state["engines"]["fp_paged"].serve(
        state["requests"], n_slots=4, max_new_tokens=BUDGETS, beam=2)
    m = res.metrics()
    assert m["paged"] == 1.0 and m["pages_in_use"] == 0.0
    assert m["page_hwm"] > 0 and m["reorder_bytes"] > 0
    unpaged = state["engines"]["fp"].serve(
        state["requests"], n_slots=4, max_new_tokens=BUDGETS, beam=2)
    # the whole point: the paged reorder moves a fraction of the slab
    assert res.reorder_bytes * 2 < unpaged.reorder_bytes
