"""Slot-based KV-cache insert/evict round-trips (ISSUE 1): quantized and
unquantized caches, interaction with gather_beams."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import kv_cache as kvc

L, B, S, HKV, DH = 2, 6, 8, 2, 4


def _rand_cache(rng, batch, *, quantized, lengths=None):
    cache = kvc.init_cache(L, batch, S, HKV, DH, quantized=quantized,
                           dtype=jnp.float32)
    shape = (L, batch, S, HKV, DH)
    if quantized:
        k = rng.integers(-127, 128, shape).astype(np.int8)
        v = rng.integers(-127, 128, shape).astype(np.int8)
        ks = rng.uniform(1e-3, 0.1, shape[:-1]).astype(np.float32)
        vs = rng.uniform(1e-3, 0.1, shape[:-1]).astype(np.float32)
        cache = kvc.KVCache(k=jnp.asarray(k), v=jnp.asarray(v),
                            k_scale=jnp.asarray(ks), v_scale=jnp.asarray(vs),
                            lengths=cache.lengths)
    else:
        k = rng.normal(size=shape).astype(np.float32)
        v = rng.normal(size=shape).astype(np.float32)
        cache = kvc.KVCache(k=jnp.asarray(k), v=jnp.asarray(v),
                            k_scale=None, v_scale=None, lengths=cache.lengths)
    if lengths is not None:
        cache = kvc.KVCache(k=cache.k, v=cache.v, k_scale=cache.k_scale,
                            v_scale=cache.v_scale,
                            lengths=jnp.asarray(lengths, jnp.int32))
    return cache


@pytest.mark.parametrize("quantized", [False, True])
def test_insert_at_slots_round_trip(rng, quantized):
    main = _rand_cache(rng, B, quantized=quantized,
                       lengths=np.arange(B) + 1)
    sub = _rand_cache(rng, 2, quantized=quantized, lengths=[5, 7])
    slots = np.asarray([1, 4], np.int32)

    out = kvc.insert_at_slots(main, sub, jnp.asarray(slots))

    for j, s in enumerate(slots):
        np.testing.assert_array_equal(np.asarray(out.k[:, s]),
                                      np.asarray(sub.k[:, j]))
        np.testing.assert_array_equal(np.asarray(out.v[:, s]),
                                      np.asarray(sub.v[:, j]))
        if quantized:
            np.testing.assert_array_equal(np.asarray(out.k_scale[:, s]),
                                          np.asarray(sub.k_scale[:, j]))
            np.testing.assert_array_equal(np.asarray(out.v_scale[:, s]),
                                          np.asarray(sub.v_scale[:, j]))
        assert int(out.lengths[s]) == int(sub.lengths[j])
    untouched = [b for b in range(B) if b not in slots]
    for b in untouched:
        np.testing.assert_array_equal(np.asarray(out.k[:, b]),
                                      np.asarray(main.k[:, b]))
        assert int(out.lengths[b]) == int(main.lengths[b])


@pytest.mark.parametrize("quantized", [False, True])
def test_free_slots_resets_cursors_only(rng, quantized):
    main = _rand_cache(rng, B, quantized=quantized,
                       lengths=np.arange(B) + 1)
    out = kvc.free_slots(main, jnp.asarray([0, 3], jnp.int32))
    want = np.arange(B) + 1
    want[[0, 3]] = 0
    np.testing.assert_array_equal(np.asarray(out.lengths), want)
    # payload untouched — reads are masked by lengths
    np.testing.assert_array_equal(np.asarray(out.k), np.asarray(main.k))
    np.testing.assert_array_equal(np.asarray(out.v), np.asarray(main.v))


@pytest.mark.parametrize("quantized", [False, True])
def test_insert_free_reinsert_cycle(rng, quantized):
    """The engine's slot lifecycle: fill → evict → refill the same slot."""
    main = _rand_cache(rng, B, quantized=quantized, lengths=[2] * B)
    first = _rand_cache(rng, 1, quantized=quantized, lengths=[4])
    second = _rand_cache(rng, 1, quantized=quantized, lengths=[6])
    slot = jnp.asarray([2], jnp.int32)

    main = kvc.insert_at_slots(main, first, slot)
    assert int(main.lengths[2]) == 4
    main = kvc.free_slots(main, slot)
    assert int(main.lengths[2]) == 0
    main = kvc.insert_at_slots(main, second, slot)
    assert int(main.lengths[2]) == 6
    np.testing.assert_array_equal(np.asarray(main.k[:, 2]),
                                  np.asarray(second.k[:, 0]))


def test_insert_out_of_range_slot_is_dropped(rng):
    """The engine pads admission groups with an OOB sentinel slot."""
    main = _rand_cache(rng, B, quantized=False, lengths=[1] * B)
    sub = _rand_cache(rng, 2, quantized=False, lengths=[5, 9])
    out = kvc.insert_at_slots(main, sub, jnp.asarray([3, B], jnp.int32))
    assert int(out.lengths[3]) == 5
    np.testing.assert_array_equal(
        np.asarray(out.lengths)[[0, 1, 2, 4, 5]], [1, 1, 1, 1, 1])


def test_insert_rejects_mixed_quantization_and_capacity(rng):
    fp = _rand_cache(rng, B, quantized=False)
    q = _rand_cache(rng, 2, quantized=True)
    with pytest.raises(ValueError):
        kvc.insert_at_slots(fp, q, jnp.asarray([0, 1], jnp.int32))
    small = kvc.init_cache(L, 2, S // 2, HKV, DH, quantized=False,
                           dtype=jnp.float32)
    with pytest.raises(ValueError):
        kvc.insert_at_slots(fp, small, jnp.asarray([0, 1], jnp.int32))


@pytest.mark.parametrize("quantized", [False, True])
def test_insert_then_gather_beams(rng, quantized):
    """Beam reorder composes with slot insertion: gather after insert sees
    the inserted rows."""
    main = _rand_cache(rng, B, quantized=quantized,
                       lengths=np.arange(B) + 1)
    sub = _rand_cache(rng, 2, quantized=quantized, lengths=[3, 5])
    out = kvc.insert_at_slots(main, sub, jnp.asarray([0, 5], jnp.int32))
    idx = jnp.asarray([5, 5, 1, 0, 2, 4], jnp.int32)
    g = kvc.gather_beams(out, idx)
    np.testing.assert_array_equal(np.asarray(g.k[:, 0]),
                                  np.asarray(sub.k[:, 1]))
    np.testing.assert_array_equal(np.asarray(g.k[:, 3]),
                                  np.asarray(sub.k[:, 0]))
    np.testing.assert_array_equal(np.asarray(g.k[:, 2]),
                                  np.asarray(main.k[:, 1]))
    np.testing.assert_array_equal(
        np.asarray(g.lengths), [5, 5, 2, 3, 3, 5])
    if quantized:
        np.testing.assert_array_equal(np.asarray(g.k_scale[:, 0]),
                                      np.asarray(sub.k_scale[:, 1]))


@pytest.mark.parametrize("quantized", [False, True])
def test_group_strided_insert_and_free(rng, quantized):
    """Beam groups (ISSUE 3): insert_at_groups splices `beam` contiguous
    rows per base slot; free_groups frees all of a group's rows
    atomically; OOB sentinel bases drop whole groups."""
    beam = 2
    main = _rand_cache(rng, B, quantized=quantized,
                       lengths=np.arange(B) + 1)
    sub = _rand_cache(rng, 2 * beam, quantized=quantized,
                      lengths=[3, 3, 5, 5])
    bases = np.asarray([0, 4], np.int32)

    rows = np.asarray(kvc.group_rows(bases, beam))
    np.testing.assert_array_equal(rows, [0, 1, 4, 5])

    out = kvc.insert_at_groups(main, sub, bases, beam)
    for j, r in enumerate(rows):
        np.testing.assert_array_equal(np.asarray(out.k[:, r]),
                                      np.asarray(sub.k[:, j]))
        assert int(out.lengths[r]) == int(sub.lengths[j])
    for b in (2, 3):                               # untouched group
        np.testing.assert_array_equal(np.asarray(out.k[:, b]),
                                      np.asarray(main.k[:, b]))

    freed = kvc.free_groups(out, np.asarray([4], np.int32), beam)
    assert [int(x) for x in freed.lengths] == \
        [3, 3, int(main.lengths[2]), int(main.lengths[3]), 0, 0]

    # sentinel base B expands to OOB rows → the whole group is dropped
    same = kvc.insert_at_groups(out, sub, np.asarray([0, B], np.int32), beam)
    for b in range(2, B):
        np.testing.assert_array_equal(np.asarray(same.k[:, b]),
                                      np.asarray(out.k[:, b]))
