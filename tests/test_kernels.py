"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
with shape/dtype sweeps + hypothesis-generated shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.qtensor import QTensor
from repro.kernels import ops, ref


def _mk_qt(rng, shape, scale_shape):
    data = jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
    scale = jnp.asarray(rng.uniform(1e-3, 0.1, scale_shape), jnp.float32)
    return QTensor(data, scale, jnp.zeros((), jnp.float32), None)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [
    (8, 128, 128), (37, 100, 65), (128, 512, 384), (1, 256, 32),
    (130, 96, 200),
])
def test_int8_matmul_shapes(rng, M, K, N):
    a = _mk_qt(rng, (M, K), (M, 1))
    b = _mk_qt(rng, (K, N), (1, N))
    bias = jnp.asarray(rng.normal(size=N), jnp.float32)
    got = ops.int8_matmul(a, b, bias, impl="interpret")
    want = ops.int8_matmul(a, b, bias, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_out_dtypes(rng, out_dtype):
    a = _mk_qt(rng, (16, 64), (16, 1))
    b = _mk_qt(rng, (64, 32), (1, 32))
    got = ops.int8_matmul(a, b, out_dtype=out_dtype, impl="interpret")
    assert got.dtype == out_dtype


def test_int8_matmul_zero_point(rng):
    a = QTensor(jnp.asarray(rng.integers(-127, 128, (24, 48)), jnp.int8),
                jnp.float32(0.03), jnp.float32(5.0), None)
    b = _mk_qt(rng, (48, 40), (1, 40))
    got = ops.int8_matmul(a, b, impl="interpret")
    want = ops.int8_matmul(a, b, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_int8_matmul_exact_vs_float_reference(rng):
    """Int8 kernel must equal float math on exactly-representable values."""
    a_f = rng.integers(-50, 50, (16, 32)).astype(np.float32)
    b_f = rng.integers(-50, 50, (32, 24)).astype(np.float32)
    a = QTensor(jnp.asarray(a_f.astype(np.int8)), jnp.float32(1.0),
                jnp.zeros(()), None)
    b = QTensor(jnp.asarray(b_f.astype(np.int8)), jnp.float32(1.0),
                jnp.zeros(()), None)
    got = np.asarray(ops.int8_matmul(a, b, impl="interpret"))
    np.testing.assert_allclose(got, a_f @ b_f, rtol=0, atol=0)


def test_int8_matmul_batched(rng):
    a = _mk_qt(rng, (4, 24, 64), (4, 24, 1))
    b = _mk_qt(rng, (4, 64, 48), (4, 1, 48))
    got = ops.int8_matmul_batched(a, b, impl="interpret")
    want = ops.int8_matmul_batched(a, b, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 64))
@settings(max_examples=12, deadline=None)
def test_prop_int8_matmul_any_shape(M, K, N):
    rng = np.random.default_rng(M * 1000 + K * 10 + N)
    a = _mk_qt(rng, (M, K), (M, 1))
    b = _mk_qt(rng, (K, N), (1, N))
    got = ops.int8_matmul(a, b, impl="interpret")
    want = ops.int8_matmul(a, b, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# quantize kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K", [(8, 64), (50, 300), (1, 128), (129, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_rowwise(rng, M, K, dtype):
    x = jnp.asarray(rng.normal(size=(M, K)) * 10, dtype)
    got = ops.quantize_rowwise(x, impl="interpret")
    want = ops.quantize_rowwise(x, impl="xla")
    # bf16 inputs can land exactly on rounding boundaries: allow ±1 quantum
    diff = np.abs(np.asarray(got.data, np.int32)
                  - np.asarray(want.data, np.int32))
    assert diff.max() <= 1
    np.testing.assert_allclose(np.asarray(got.scale), np.asarray(want.scale),
                               rtol=1e-6)


def test_quantize_static(rng):
    x = jnp.asarray(rng.normal(size=(40, 100)) * 5, jnp.float32)
    got = ops.quantize_static(x, 3.0, impl="interpret")
    want = ops.quantize_static(x, 3.0, impl="xla")
    np.testing.assert_array_equal(np.asarray(got.data), np.asarray(want.data))
    # clipping: all values map within [-127, 127]
    assert int(jnp.max(jnp.abs(got.data))) <= 127


# Awkward row counts: 8 < M < block_rows with M % 8 != 0 used to pick a
# sublane-misaligned Pallas block (bm = M) — interpret mode accepted it
# but real TPU lowering rejects non-multiple-of-8 block rows.  The sweep
# pins the rounded-up block shape to reference-quantizer parity.
AWKWARD_M = [9, 12, 17, 100, 127, 129, 250, 255, 257]


@pytest.mark.parametrize("M", AWKWARD_M)
def test_quantize_rowwise_awkward_rows(rng, M):
    from repro.kernels.quantize import quantize_rowwise_pallas
    x = jnp.asarray(rng.normal(size=(M, 64)) * 7, jnp.float32)
    q, scale = quantize_rowwise_pallas(x, interpret=True)
    want = ops.quantize_rowwise(x, impl="xla")
    assert q.shape == (M, 64) and scale.shape == (M, 1)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want.data))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(want.scale),
                               rtol=1e-6)


@pytest.mark.parametrize("M", AWKWARD_M)
def test_quantize_static_awkward_rows(rng, M):
    from repro.kernels.quantize import quantize_static_pallas
    x = jnp.asarray(rng.normal(size=(M, 48)) * 5, jnp.float32)
    q = quantize_static_pallas(x, jnp.float32(3.0), interpret=True)
    want = ops.quantize_static(x, 3.0, impl="xla")
    assert q.shape == (M, 48)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want.data))


# ---------------------------------------------------------------------------
# decode attention (int8 KV cache)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,HKV,dh,S", [
    (2, 8, 4, 64, 300), (1, 4, 1, 128, 64), (3, 8, 8, 32, 513),
    (2, 16, 2, 64, 128),
])
def test_decode_attention(rng, B, H, HKV, dh, S):
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kq = jnp.asarray(rng.integers(-127, 128, (B, S, HKV, dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (B, S, HKV, dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(1e-3, 2e-2, (B, S, HKV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(1e-3, 2e-2, (B, S, HKV)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    sm = 1.0 / np.sqrt(dh)
    got = ops.decode_attention(q, kq, ks, vq, vs, lengths, sm_scale=sm,
                               impl="interpret")
    want = ops.decode_attention(q, kq, ks, vq, vs, lengths, sm_scale=sm,
                                impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_decode_attention_respects_lengths(rng):
    """Tokens beyond `lengths` must not influence the output."""
    B, H, dh, S = 1, 4, 32, 64
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kq = jnp.asarray(rng.integers(-127, 128, (B, S, H, dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (B, S, H, dh)), jnp.int8)
    ks = jnp.ones((B, S, H), jnp.float32) * 0.01
    vs = jnp.ones((B, S, H), jnp.float32) * 0.01
    lengths = jnp.asarray([20], jnp.int32)
    out1 = ops.decode_attention(q, kq, ks, vq, vs, lengths,
                                sm_scale=0.1, impl="interpret")
    # poison the out-of-range region
    kq2 = kq.at[:, 20:].set(127)
    vq2 = vq.at[:, 20:].set(-127)
    out2 = ops.decode_attention(q, kq2, ks, vq2, vs, lengths,
                                sm_scale=0.1, impl="interpret")
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)
