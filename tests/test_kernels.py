"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
with shape/dtype sweeps + hypothesis-generated shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.qtensor import QTensor
from repro.kernels import ops, ref


def _mk_qt(rng, shape, scale_shape):
    data = jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
    scale = jnp.asarray(rng.uniform(1e-3, 0.1, scale_shape), jnp.float32)
    return QTensor(data, scale, jnp.zeros((), jnp.float32), None)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [
    (8, 128, 128), (37, 100, 65), (128, 512, 384), (1, 256, 32),
    (130, 96, 200),
])
def test_int8_matmul_shapes(rng, M, K, N):
    a = _mk_qt(rng, (M, K), (M, 1))
    b = _mk_qt(rng, (K, N), (1, N))
    bias = jnp.asarray(rng.normal(size=N), jnp.float32)
    got = ops.int8_matmul(a, b, bias, impl="interpret")
    want = ops.int8_matmul(a, b, bias, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_out_dtypes(rng, out_dtype):
    a = _mk_qt(rng, (16, 64), (16, 1))
    b = _mk_qt(rng, (64, 32), (1, 32))
    got = ops.int8_matmul(a, b, out_dtype=out_dtype, impl="interpret")
    assert got.dtype == out_dtype


def test_int8_matmul_zero_point(rng):
    a = QTensor(jnp.asarray(rng.integers(-127, 128, (24, 48)), jnp.int8),
                jnp.float32(0.03), jnp.float32(5.0), None)
    b = _mk_qt(rng, (48, 40), (1, 40))
    got = ops.int8_matmul(a, b, impl="interpret")
    want = ops.int8_matmul(a, b, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_int8_matmul_exact_vs_float_reference(rng):
    """Int8 kernel must equal float math on exactly-representable values."""
    a_f = rng.integers(-50, 50, (16, 32)).astype(np.float32)
    b_f = rng.integers(-50, 50, (32, 24)).astype(np.float32)
    a = QTensor(jnp.asarray(a_f.astype(np.int8)), jnp.float32(1.0),
                jnp.zeros(()), None)
    b = QTensor(jnp.asarray(b_f.astype(np.int8)), jnp.float32(1.0),
                jnp.zeros(()), None)
    got = np.asarray(ops.int8_matmul(a, b, impl="interpret"))
    np.testing.assert_allclose(got, a_f @ b_f, rtol=0, atol=0)


def test_int8_matmul_batched(rng):
    a = _mk_qt(rng, (4, 24, 64), (4, 24, 1))
    b = _mk_qt(rng, (4, 64, 48), (4, 1, 48))
    got = ops.int8_matmul_batched(a, b, impl="interpret")
    want = ops.int8_matmul_batched(a, b, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 64))
@settings(max_examples=12, deadline=None)
def test_prop_int8_matmul_any_shape(M, K, N):
    rng = np.random.default_rng(M * 1000 + K * 10 + N)
    a = _mk_qt(rng, (M, K), (M, 1))
    b = _mk_qt(rng, (K, N), (1, N))
    got = ops.int8_matmul(a, b, impl="interpret")
    want = ops.int8_matmul(a, b, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# quantize kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K", [(8, 64), (50, 300), (1, 128), (129, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_rowwise(rng, M, K, dtype):
    x = jnp.asarray(rng.normal(size=(M, K)) * 10, dtype)
    got = ops.quantize_rowwise(x, impl="interpret")
    want = ops.quantize_rowwise(x, impl="xla")
    # bf16 inputs can land exactly on rounding boundaries: allow ±1 quantum
    diff = np.abs(np.asarray(got.data, np.int32)
                  - np.asarray(want.data, np.int32))
    assert diff.max() <= 1
    np.testing.assert_allclose(np.asarray(got.scale), np.asarray(want.scale),
                               rtol=1e-6)


def test_quantize_static(rng):
    x = jnp.asarray(rng.normal(size=(40, 100)) * 5, jnp.float32)
    got = ops.quantize_static(x, 3.0, impl="interpret")
    want = ops.quantize_static(x, 3.0, impl="xla")
    np.testing.assert_array_equal(np.asarray(got.data), np.asarray(want.data))
    # clipping: all values map within [-127, 127]
    assert int(jnp.max(jnp.abs(got.data))) <= 127


# Awkward row counts: 8 < M < block_rows with M % 8 != 0 used to pick a
# sublane-misaligned Pallas block (bm = M) — interpret mode accepted it
# but real TPU lowering rejects non-multiple-of-8 block rows.  The sweep
# pins the rounded-up block shape to reference-quantizer parity.
AWKWARD_M = [9, 12, 17, 100, 127, 129, 250, 255, 257]


@pytest.mark.parametrize("M", AWKWARD_M)
def test_quantize_rowwise_awkward_rows(rng, M):
    from repro.kernels.quantize import quantize_rowwise_pallas
    x = jnp.asarray(rng.normal(size=(M, 64)) * 7, jnp.float32)
    q, scale = quantize_rowwise_pallas(x, interpret=True)
    want = ops.quantize_rowwise(x, impl="xla")
    assert q.shape == (M, 64) and scale.shape == (M, 1)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want.data))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(want.scale),
                               rtol=1e-6)


@pytest.mark.parametrize("M", AWKWARD_M)
def test_quantize_static_awkward_rows(rng, M):
    from repro.kernels.quantize import quantize_static_pallas
    x = jnp.asarray(rng.normal(size=(M, 48)) * 5, jnp.float32)
    q = quantize_static_pallas(x, jnp.float32(3.0), interpret=True)
    want = ops.quantize_static(x, 3.0, impl="xla")
    assert q.shape == (M, 48)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want.data))


# ---------------------------------------------------------------------------
# decode attention (int8 KV cache)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,HKV,dh,S", [
    (2, 8, 4, 64, 300), (1, 4, 1, 128, 64), (3, 8, 8, 32, 513),
    (2, 16, 2, 64, 128),
])
def test_decode_attention(rng, B, H, HKV, dh, S):
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kq = jnp.asarray(rng.integers(-127, 128, (B, S, HKV, dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (B, S, HKV, dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(1e-3, 2e-2, (B, S, HKV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(1e-3, 2e-2, (B, S, HKV)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    sm = 1.0 / np.sqrt(dh)
    got = ops.decode_attention(q, kq, ks, vq, vs, lengths, sm_scale=sm,
                               impl="interpret")
    want = ops.decode_attention(q, kq, ks, vq, vs, lengths, sm_scale=sm,
                                impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_decode_attention_respects_lengths(rng):
    """Tokens beyond `lengths` must not influence the output."""
    B, H, dh, S = 1, 4, 32, 64
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kq = jnp.asarray(rng.integers(-127, 128, (B, S, H, dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (B, S, H, dh)), jnp.int8)
    ks = jnp.ones((B, S, H), jnp.float32) * 0.01
    vs = jnp.ones((B, S, H), jnp.float32) * 0.01
    lengths = jnp.asarray([20], jnp.int32)
    out1 = ops.decode_attention(q, kq, ks, vq, vs, lengths,
                                sm_scale=0.1, impl="interpret")
    # poison the out-of-range region
    kq2 = kq.at[:, 20:].set(127)
    vq2 = vq.at[:, 20:].set(-127)
    out2 = ops.decode_attention(q, kq2, ks, vq2, vs, lengths,
                                sm_scale=0.1, impl="interpret")
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# int4 block-quantized matmul (dequant fused in-kernel)
# ---------------------------------------------------------------------------

import functools

from repro.core.qtensor import (
    BlockQTensor, pack_nibbles, quantize_block, unpack_nibbles,
)
from repro.kernels.int4_matmul import _pick_bk, int4_matmul_pallas


def _mk_bqt(rng, K, N, G, scale_dtype=jnp.float16):
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    return quantize_block(w, group_size=G, scale_dtype=scale_dtype)


def _mk_act(rng, M, K, zp=None):
    data = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    scale = jnp.asarray(rng.uniform(1e-3, 0.05, (M, 1)), jnp.float32)
    zp_arr = jnp.float32(zp) if zp is not None else jnp.zeros((), jnp.float32)
    return QTensor(data, scale, zp_arr, None)


def _jit_oracle(G):
    """Bit-identity needs *both* paths XLA-compiled: the interpret-mode
    kernel body is traced/compiled (FMA contraction applies), so the oracle
    must be jitted too — an eager ref call differs in the last ulp."""
    return jax.jit(functools.partial(ref.ref_int4_matmul, group_size=G))


# The sweep deliberately includes: group_size not dividing the default bk
# (G=48 → bk=480), K not a multiple of the group (tail-group edge padding),
# multi-k-step grids with a padded grid tail (K=700/2048), sublane-awkward M,
# and lane-awkward N.
INT4_CASES = [
    #  M,    K,   N,   G, scale_dtype
    (8,    64, 128,  32, jnp.float32),
    (3,   100, 130,  32, jnp.float16),
    (12,  700, 257,  48, jnp.float16),
    (1,    16, 128,  16, jnp.float32),
    (5,  1000,  64, 128, jnp.float16),
    (17, 2048, 512, 128, jnp.float16),
    (9,   130,  96,  64, jnp.float16),
]


@pytest.mark.parametrize("M,K,N,G,scale_dtype", INT4_CASES)
@pytest.mark.parametrize("zp", [None, 3.0])
def test_int4_matmul_bit_identical_to_reference(rng, M, K, N, G, scale_dtype,
                                                zp):
    """Interpret-mode kernel must be bit-identical to the jitted group-wise
    oracle — same int32 MXU dots, same ascending-group f32 combination."""
    b = _mk_bqt(rng, K, N, G, scale_dtype)
    a = _mk_act(rng, M, K, zp)
    zp_arr = jnp.float32(zp) if zp is not None else None
    got = int4_matmul_pallas(a.data, a.scale, b.data, b.scale, b.vmin,
                             zp_arr, None, group_size=G, interpret=True)
    want = _jit_oracle(G)(a.data, a.scale, b.data, b.scale, b.vmin,
                          zp_arr, None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("M,K,N,G,scale_dtype", INT4_CASES[:4])
def test_int4_matmul_matches_float_dequant(rng, M, K, N, G, scale_dtype):
    """Kernel ≈ dense float matmul against the reference dequantized weights
    (validates the whole integer decomposition, not just oracle agreement)."""
    b = _mk_bqt(rng, K, N, G, scale_dtype)
    a = _mk_act(rng, M, K)
    got = ops.int4_matmul(a, b, impl="interpret")
    want = a.dequantize() @ b.dequantize()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_int4_matmul_exact_vs_float_reference(rng):
    """Power-of-two scale/min and integer activations are exactly
    representable: kernel must equal float math with zero tolerance."""
    M, K, N, G = 16, 64, 32, 32
    codes = jnp.asarray(rng.integers(0, 16, (K, N)), jnp.int32)
    b = BlockQTensor(data=pack_nibbles(codes),
                     scale=jnp.full((K // G, N), 0.5, jnp.float32),
                     vmin=jnp.full((K // G, N), -4.0, jnp.float32),
                     group_size=G, k_dim=K)
    a_f = rng.integers(-50, 50, (M, K)).astype(np.float32)
    a = QTensor(jnp.asarray(a_f.astype(np.int8)), jnp.float32(1.0),
                jnp.zeros((), jnp.float32), None)
    got = ops.int4_matmul(a, b, impl="interpret")
    want = a_f @ np.asarray(b.dequantize())
    np.testing.assert_array_equal(np.asarray(got), want)


def test_int4_matmul_padding_contributes_zero(rng):
    """Stored rows beyond k_dim must not leak into the result: poisoning the
    padded tail nibbles (0x0 → 0xF) leaves the output bit-identical."""
    K, G, N, M = 70, 32, 64, 5          # k_store = 96, 26 padded rows
    n_g, k_store = 3, 96
    codes = np.asarray(rng.integers(0, 16, (k_store, N)), np.int32)
    scale = jnp.asarray(rng.uniform(0.01, 0.1, (n_g, N)), jnp.float16)
    vmin = jnp.asarray(rng.uniform(-1.0, 0.0, (n_g, N)), jnp.float16)
    a = _mk_act(rng, M, K, zp=2.0)

    outs = []
    for fill in (0, 15):
        poisoned = codes.copy()
        poisoned[K:, :] = fill
        b = BlockQTensor(data=pack_nibbles(jnp.asarray(poisoned)),
                         scale=scale, vmin=vmin, group_size=G, k_dim=K)
        outs.append(np.asarray(ops.int4_matmul(a, b, impl="interpret")))
    np.testing.assert_array_equal(outs[0], outs[1])


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int4_matmul_out_dtypes(rng, out_dtype):
    b = _mk_bqt(rng, 64, 128, 32)
    a = _mk_act(rng, 8, 64)
    got = ops.int4_matmul(a, b, out_dtype=out_dtype, impl="interpret")
    assert got.dtype == out_dtype
    want = ops.int4_matmul(a, b, out_dtype=out_dtype, impl="xla")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_int4_matmul_leading_batch_dims(rng):
    """ops.int4_matmul flattens (..., K) activations like int8_matmul."""
    B, T, K, N, G = 2, 3, 64, 128, 32
    b = _mk_bqt(rng, K, N, G)
    data = jnp.asarray(rng.integers(-127, 128, (B, T, K)), jnp.int8)
    scale = jnp.asarray(rng.uniform(1e-3, 0.05, (B, T, 1)), jnp.float32)
    a = QTensor(data, scale, jnp.zeros((), jnp.float32), None)
    got = ops.int4_matmul(a, b, impl="interpret")
    assert got.shape == (B, T, N)
    flat = QTensor(data.reshape(-1, K), scale.reshape(-1, 1),
                   jnp.zeros((), jnp.float32), None)
    want = ops.int4_matmul(flat, b, impl="interpret").reshape(B, T, N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int4_matmul_bias(rng):
    b = _mk_bqt(rng, 96, 64, 48)
    a = _mk_act(rng, 7, 96)
    bias = jnp.asarray(rng.normal(size=64), jnp.float32)
    got = ops.int4_matmul(a, b, bias, impl="interpret")
    want = jnp.asarray(
        _jit_oracle(48)(a.data, _row_scale_for_test(a.scale, 7),
                        b.data, b.scale, b.vmin, None, bias))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _row_scale_for_test(scale, M):
    return jnp.reshape(jnp.asarray(scale, jnp.float32), (M, 1))


def test_pick_bk_invariants():
    """bk must be a multiple of group_size (a block's scale/min never
    straddles two k-tiles) and never exceed the padded store."""
    for k_store, G in [(96, 32), (512, 48), (4096, 128), (64, 64), (32, 128)]:
        bk = _pick_bk(k_store, G, 512)
        assert bk % G == 0 and bk >= G
        assert bk <= max(k_store, G)


@given(st.integers(1, 33), st.integers(1, 200), st.integers(1, 150),
       st.sampled_from([16, 32, 48, 64]))
@settings(max_examples=12, deadline=None)
def test_int4_matmul_property(M, K, N, G):
    r = np.random.default_rng(M * 7919 + K * 131 + N * 17 + G)
    b = _mk_bqt(r, K, N, G)
    a = _mk_act(r, M, K)
    got = int4_matmul_pallas(a.data, a.scale, b.data, b.scale, b.vmin,
                             None, None, group_size=G, interpret=True)
    want = _jit_oracle(G)(a.data, a.scale, b.data, b.scale, b.vmin,
                          None, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
