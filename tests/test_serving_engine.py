"""Continuous serve() vs per-request generate(): token identity, EOS at
slot boundaries, and latency-metric sanity (ISSUE 1)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=2, n_kv_heads=2, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    requests = make_corpus(10, cfg.vocab, seed=11, max_words=8)
    return cfg, model, params, qparams, qctx, requests


def _generate_each(engine, requests, budgets):
    outs = []
    for s, cap in zip(requests, budgets):
        src, lens = pad_batch([s.src])
        res = engine.generate({"src_tokens": src, "src_lengths": lens},
                              max_new_tokens=int(cap))
        outs.append(np.asarray(res.tokens[0])[:int(cap)])
    return outs


@pytest.mark.parametrize("quantized", [False, True])
def test_serve_token_identical_to_generate(setup, quantized):
    cfg, model, params, qparams, qctx, requests = setup
    if quantized:
        engine = ServingEngine(model, qparams, quant=qctx, max_len=32)
        assert qctx.quantize_kv                     # INT8 KV cache in play
    else:
        engine = ServingEngine(model, params, max_len=32)
    budgets = [3, 7, 1, 5, 7, 2, 6, 4, 7, 3]        # heterogeneous lengths
    res = engine.serve(requests, n_slots=3, max_new_tokens=budgets)
    want = _generate_each(engine, requests, budgets)
    for i in range(len(requests)):
        np.testing.assert_array_equal(res.tokens_for(i), want[i])
    assert all(r.status == "finished" for r in res.requests)
    assert all(len(r.tokens) <= b for r, b in zip(res.requests, budgets))


def test_eos_at_slot_boundaries(setup):
    """Force EOS mid-serve by redefining eos_id to a token the model emits:
    the slot must be released and refilled, and outputs must still match
    per-request generate() with the same eos."""
    cfg, model, params, _, _, requests = setup
    probe = ServingEngine(model, params, max_len=32)
    probe_res = probe.serve(requests, n_slots=2, max_new_tokens=8)
    emitted = [t for r in probe_res.requests for t in r.tokens[1:]]
    assert emitted, "probe produced no tokens"
    # the most common non-first token becomes the new EOS → guaranteed to
    # fire mid-sequence for at least one request
    fake_eos = int(np.bincount(emitted).argmax())

    engine = ServingEngine(model, params, eos_id=fake_eos, max_len=32)
    res = engine.serve(requests, n_slots=2, max_new_tokens=8)
    want = _generate_each(engine, requests, [8] * len(requests))
    stopped_early = 0
    for i, w in enumerate(want):
        np.testing.assert_array_equal(res.tokens_for(i), w)
        if len(w) < 8:
            stopped_early += 1
    assert stopped_early > 0                        # EOS actually fired
    # early EOS freed slots that later requests then reused
    assert res.busy_slot_steps < res.n_slots * res.decode_steps \
        or res.utilization == 1.0


def test_metrics_sanity(setup):
    cfg, model, params, _, _, requests = setup
    engine = ServingEngine(model, params, max_len=32)
    res = engine.serve(requests, n_slots=4, max_new_tokens=6)
    met = res.metrics()
    for r in res.requests:
        assert r.first_token_s is not None and r.finish_s is not None
        assert r.first_token_latency_s <= r.total_latency_s + 1e-9
        assert r.admitted_s <= r.first_token_s
    assert 0 < res.utilization <= 1.0 + 1e-9
    assert met["n_requests"] == len(requests)
    assert met["n_tokens"] == res.n_tokens > 0
    assert met["first_token_latency_p95_s"] <= met["total_latency_p95_s"] + 1e-9
    assert res.decode_steps >= 1


def test_serve_request_objects_and_empty(setup):
    cfg, model, params, _, _, requests = setup
    engine = ServingEngine(model, params, max_len=32)
    assert engine.serve([], n_slots=2).n_tokens == 0
    reqs = [Request(req_id=7, src=requests[0].src, max_new_tokens=4)]
    res = engine.serve(reqs, n_slots=2)
    assert res.tokens_for(7).shape[0] <= 4


def test_serve_same_requests_twice(setup):
    """Re-serving the same Request objects resets their lifecycle."""
    cfg, model, params, _, _, requests = setup
    engine = ServingEngine(model, params, max_len=32)
    reqs = [Request(req_id=i, src=s.src, max_new_tokens=5)
            for i, s in enumerate(requests[:6])]
    first = engine.serve(reqs, n_slots=2)
    want = [np.asarray(r.tokens) for r in first.requests]
    second = engine.serve(reqs, n_slots=2)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(second.tokens_for(i), want[i])
    assert all(len(r.tokens) <= 5 for r in second.requests)


def test_serve_zero_budget_and_duplicate_ids(setup):
    cfg, model, params, _, _, requests = setup
    engine = ServingEngine(model, params, max_len=32)
    res = engine.serve(requests[:3], n_slots=2, max_new_tokens=[0, 2, 0])
    assert [len(r.tokens) for r in res.requests] == [0, 2, 0] or \
        len(res.requests[1].tokens) <= 2      # early EOS may shorten row 1
    assert res.tokens_for(0).size == 0
    with pytest.raises(ValueError):
        engine.serve([requests[0],
                      Request(req_id=0, src=requests[1].src)], n_slots=2)


def test_serve_rejects_budget_over_capacity(setup):
    cfg, model, params, _, _, requests = setup
    engine = ServingEngine(model, params, max_len=8)
    with pytest.raises(ValueError):
        engine.serve(requests, n_slots=2, max_new_tokens=64)
