"""Multi-chip serving (ISSUE 9): tensor-parallel bursts + replica router.

Token identity is the contract: a serve on a ``("data","model")`` mesh —
weights split by the training sharding rules, paged K/V pools split on
the heads axis, everything host-facing replicated — must emit the exact
tokens of the unsharded engine with UNCHANGED ``host_syncs`` (GSPMD's
all-reduces live inside the burst ``while_loop``; they never add a
round trip).

The tier-1 run sees ONE CPU device (conftest mandate), so every tp > 1
case skips itself; CI's multi-device leg re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where the full
matrix executes.  Everything mesh-free — the GQA fallback rule, the
PartitionSpec assignment, mesh validation, and the router (replicas are
plain engines) — runs everywhere.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.serving import ReplicaRouter, ServingEngine, make_chaos
from repro.serving.sharding import (decode_state_specs, kv_pools_shardable,
                                    mesh_axis_sizes, tp_degree)

MAX_LEN = 32
PAGE_SIZE = 8
N_SLOTS = 8
BUDGETS = [3, 7, 24, 5, 16, 2, 4, 9]
MIXED_WIDTHS = [4, 2, 1, 3, 4, 2, 1, 4]

_CACHED = {}


def _state():
    if "model" not in _CACHED:
        cfg = get_config("transformer-base").reduced(
            vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
            n_heads=4, n_kv_heads=4, head_dim=16)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams, qctx = quantize_model(params, {},
                                       QuantPolicy(act_quant="dynamic"))
        _CACHED.update(
            cfg=cfg, model=model, params=params, qparams=qparams, qctx=qctx,
            srcs=make_corpus(len(BUDGETS), cfg.vocab, seed=3, max_words=6),
            ref={}, mesh={})
    return _CACHED


def _mesh(tp: int):
    s = _state()
    if tp not in s["mesh"]:
        s["mesh"][tp] = make_host_mesh(data=1, model=tp)
    return s["mesh"][tp]


def _engine(quant: str, mesh=None, **kw):
    s = _state()
    params = s["qparams"] if quant == "int8" else s["params"]
    qctx = {"int8": s["qctx"]}.get(quant)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", PAGE_SIZE)
    return ServingEngine(s["model"], params, max_len=MAX_LEN, mesh=mesh,
                         **({"quant": qctx} if qctx else {}), **kw)


def _toks(res):
    return [np.asarray(r.tokens, np.int32) for r in res.requests]


def _assert_identical(ref, res):
    assert len(ref.requests) == len(res.requests)
    for a, b in zip(_toks(ref), _toks(res)):
        np.testing.assert_array_equal(a, b)
    assert res.host_syncs == ref.host_syncs, "sharding added host syncs"


def _need_devices(tp: int):
    if len(jax.devices()) < tp:
        pytest.skip(f"needs {tp} devices, have {len(jax.devices())} "
                    "(CI multi-device leg runs this)")


# -------------------------------------------------------- mesh validation
def test_make_host_mesh_raises_past_device_count():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_host_mesh(data=1, model=n + 1)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_host_mesh(data=2, model=n)


def test_make_production_mesh_raises_on_host():
    # 256 chips never exist in a test process
    with pytest.raises(ValueError, match="256 devices"):
        make_production_mesh()
    with pytest.raises(ValueError, match="512 devices"):
        make_production_mesh(multi_pod=True)


def test_make_host_mesh_within_devices_ok():
    mesh = make_host_mesh(data=1, model=1)
    assert mesh_axis_sizes(mesh) == (1, 1)
    assert tp_degree(mesh) == 1
    assert tp_degree(None) == 1


# ----------------------------------------------------- GQA guard (no mesh)
class _FakeMesh:
    axis_names = ("data", "model")

    def __init__(self, tp):
        self.shape = {"data": 1, "model": tp}


def test_kv_pools_shardable_divisibility_rule():
    assert kv_pools_shardable(_FakeMesh(2), kv_heads=4)
    assert kv_pools_shardable(_FakeMesh(4), kv_heads=4)
    assert not kv_pools_shardable(_FakeMesh(4), kv_heads=2)   # GQA fallback
    assert not kv_pools_shardable(_FakeMesh(3), kv_heads=4)
    assert not kv_pools_shardable(_FakeMesh(1), kv_heads=4)   # no tp
    assert not kv_pools_shardable(None, kv_heads=4)


@pytest.mark.parametrize("paged", [False, True])
def test_decode_state_specs_target_pools_only(paged):
    s = _state()
    cfg = s["cfg"]
    state = s["model"].init_decode_state(
        4, MAX_LEN, quantized=True, enc_len=16, paged=paged,
        page_size=PAGE_SIZE, n_pages=16 if paged else None)
    specs = decode_state_specs(state, kv_heads=cfg.n_kv_heads,
                               head_dim=cfg.hd, shard_kv=True)
    kv = P(None, None, None, "model", None)
    assert specs["cache"].k == kv and specs["cache"].v == kv
    assert specs["cache"].k_scale == P(None, None, None, "model")
    assert specs["cross_k"] == kv and specs["cross_v"] == kv
    assert specs["src_lengths"] == P()
    if paged:
        assert specs["cache"].block_tables == P()
        assert specs["cache"].own_pages == P()
    # GQA fallback: everything replicated
    rep = decode_state_specs(state, kv_heads=cfg.n_kv_heads,
                             head_dim=cfg.hd, shard_kv=False)
    assert all(spec == P() for spec in jax.tree_util.tree_leaves(
        rep, is_leaf=lambda x: isinstance(x, P)))


# --------------------------------------------- identity matrix (tp ∈ 1,2,4)
GREEDY_CASES = [
    ("fp", True, 8, 0),
    ("fp", False, "auto", 0),
    ("int8", True, "auto", 0),
    ("int8", False, 1, 0),
    ("fp", True, 4, 2),
    ("int8", True, 8, 2),
]


@pytest.mark.parametrize("tp", [1, 2, 4])
@pytest.mark.parametrize("quant,fused,burst,spec", GREEDY_CASES)
def test_sharded_greedy_identity(tp, quant, fused, burst, spec):
    _need_devices(tp)
    s = _state()
    key = ("greedy", quant, fused, burst, spec)
    if key not in s["ref"]:
        s["ref"][key] = _engine(quant).serve(
            s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS,
            fused_admission=fused, burst_len=burst, speculative_k=spec)
    eng = _engine(quant, mesh=_mesh(tp))
    res = eng.serve(s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS,
                    fused_admission=fused, burst_len=burst,
                    speculative_k=spec)
    _assert_identical(s["ref"][key], res)
    assert res.tp_degree == tp
    assert res.mesh_shape == (1, tp)
    assert (res.collective_bytes_per_step > 0) == (tp > 1)


BEAM_CASES = [
    (1, "fp", True, 2),
    (4, "fp", True, 2),
    (4, "int8", False, 2),
    ("mixed", "int8", True, 2),
    (4, "fp", True, 4),
    ("mixed", "fp", False, 4),
]


@pytest.mark.parametrize("beam,quant,fused,tp", BEAM_CASES)
def test_sharded_beam_identity(beam, quant, fused, tp):
    _need_devices(tp)
    s = _state()
    kw = dict(n_slots=N_SLOTS, max_new_tokens=BUDGETS,
              fused_admission=fused, burst_len=4)
    kw.update(beam=MIXED_WIDTHS if beam == "mixed" else beam)
    key = ("beam", beam, quant, fused)
    if key not in s["ref"]:
        s["ref"][key] = _engine(quant).serve(s["srcs"], **kw)
    res = _engine(quant, mesh=_mesh(tp)).serve(s["srcs"], **kw)
    _assert_identical(s["ref"][key], res)


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_unpaged_identity(tp):
    # the contiguous (L,B,S,HKV,dh) cache shards on heads just the same
    _need_devices(tp)
    s = _state()
    key = ("greedy-unpaged",)
    if key not in s["ref"]:
        s["ref"][key] = _engine("fp", paged=False).serve(
            s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS)
    res = _engine("fp", mesh=_mesh(tp), paged=False).serve(
        s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS)
    _assert_identical(s["ref"][key], res)


def test_gqa_non_dividing_heads_fall_back_replicated():
    # HKV=2 on a model=4 axis: pools replicate (weights' k/v_proj already
    # do via _base_spec) — serve must still be token-identical, not crash
    _need_devices(4)
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=4, n_kv_heads=2, head_dim=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    srcs = make_corpus(4, cfg.vocab, seed=5, max_words=6)
    kw = dict(n_slots=4, max_new_tokens=8)
    ref = ServingEngine(model, params, max_len=MAX_LEN, paged=True,
                        page_size=PAGE_SIZE).serve(srcs, **kw)
    mesh = make_host_mesh(data=1, model=4)
    assert not kv_pools_shardable(mesh, cfg.n_kv_heads)
    res = ServingEngine(model, params, max_len=MAX_LEN, paged=True,
                        page_size=PAGE_SIZE, mesh=mesh).serve(srcs, **kw)
    _assert_identical(ref, res)


@pytest.mark.parametrize("tp", [2])
def test_sharded_serve_with_prefix_cache_and_overcommit(tp):
    _need_devices(tp)
    s = _state()
    # repeated sources: the second serve must all-hit on the sharded pool
    srcs = [s["srcs"][i % 3] for i in range(6)]
    kw = dict(n_slots=4, max_new_tokens=6)
    ref_eng = _engine("fp", prefix_cache=True)
    ref = ref_eng.serve(srcs, **kw)
    eng = _engine("fp", mesh=_mesh(tp), prefix_cache=True)
    cold = eng.serve(srcs, **kw)
    _assert_identical(ref, cold)
    warm = eng.serve(srcs, **kw)
    assert warm.prefix_hits == len(srcs)
    for a, b in zip(_toks(cold), _toks(warm)):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------ router
def test_router_balances_and_matches_single_engine():
    s = _state()
    ref = _engine("fp").serve(s["srcs"], n_slots=N_SLOTS,
                              max_new_tokens=BUDGETS)
    router = ReplicaRouter([_engine("fp"), _engine("fp")])
    res = router.serve(s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS)
    for r in res.requests:
        np.testing.assert_array_equal(ref.tokens_for(r.req_id),
                                      res.tokens_for(r.req_id))
    counts = [res.assignment.count(i) for i in range(2)]
    even = len(s["srcs"]) / 2
    assert abs(counts[0] - counts[1]) <= 1
    assert all(abs(p - even) <= 1 for p in res.peak_running_per_replica)
    assert all(r.replicas == 2 for r in res.results)
    assert res.metrics()["replicas"] == 2.0


def test_router_chaos_per_replica_token_identity():
    # preemption chaos inside each replica must not change merged tokens
    s = _state()
    ref = _engine("int8").serve(s["srcs"], n_slots=N_SLOTS,
                                max_new_tokens=BUDGETS)
    router = ReplicaRouter([_engine("int8"), _engine("int8")])
    res = router.serve(
        s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS,
        overcommit=1.5,
        chaos=[make_chaos(2, n_rounds=64, preempt_every=2),
               make_chaos(7, n_rounds=64, preempt_every=3)])
    for r in res.requests:
        np.testing.assert_array_equal(ref.tokens_for(r.req_id),
                                      res.tokens_for(r.req_id))
    assert sum(r.preemptions for r in res.results) > 0
    # chaos'd pools still reclaim fully per replica
    assert all(r.pages_in_use == 0 for r in res.results)


def test_router_prefix_cache_per_replica():
    s = _state()
    srcs = [s["srcs"][i % 2] for i in range(6)]
    router = ReplicaRouter([_engine("fp", prefix_cache=True)
                            for _ in range(2)])
    cold = router.serve(srcs, n_slots=4, max_new_tokens=6)
    warm = router.serve(srcs, n_slots=4, max_new_tokens=6)
    for r in warm.requests:
        np.testing.assert_array_equal(cold.tokens_for(r.req_id),
                                      warm.tokens_for(r.req_id))
    assert sum(r.prefix_hits for r in warm.results) == len(srcs)


def test_router_rejects_empty_and_mismatched_chaos():
    with pytest.raises(ValueError, match="at least one"):
        ReplicaRouter([])
    router = ReplicaRouter([_engine("fp"), _engine("fp")])
    with pytest.raises(ValueError, match="chaos"):
        router.serve(_state()["srcs"], chaos=[None])


def test_router_serial_matches_parallel():
    s = _state()
    par = ReplicaRouter([_engine("fp"), _engine("fp")]).serve(
        s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS)
    ser = ReplicaRouter([_engine("fp"), _engine("fp")]).serve(
        s["srcs"], n_slots=N_SLOTS, max_new_tokens=BUDGETS, parallel=False)
    assert par.assignment == ser.assignment
    for r in par.requests:
        np.testing.assert_array_equal(par.tokens_for(r.req_id),
                                      ser.tokens_for(r.req_id))


# ------------------------------------------------- ServeResult mesh fields
def test_serve_result_mesh_fields_default_off():
    s = _state()
    res = _engine("fp").serve(s["srcs"][:2], n_slots=2, max_new_tokens=4)
    assert res.mesh_shape == () and res.tp_degree == 1
    assert res.replicas == 1 and res.collective_bytes_per_step == 0
    m = res.metrics()
    assert m["tp_degree"] == 1.0 and m["collective_bytes_per_step"] == 0.0


def test_serve_result_mesh_fields_on_mesh_tp1():
    # a (1,1) mesh exercises the whole placement path on one device
    s = _state()
    res = _engine("fp", mesh=_mesh(1)).serve(
        s["srcs"][:2], n_slots=2, max_new_tokens=4)
    assert res.mesh_shape == (1, 1)
    assert res.tp_degree == 1
    assert res.collective_bytes_per_step == 0
