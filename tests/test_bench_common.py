"""benchmarks/common.py: the measure() warmup guard.

``measure(warmup=0)`` used to fold jit compile into the first measured
pass — every downstream throughput/hit-rate number quietly included
compile time.  Now it raises unless nothing is measured.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import measure  # noqa: E402


def test_measure_rejects_unwarmed_measurement():
    calls = []
    with pytest.raises(ValueError, match="warmup"):
        measure(lambda: calls.append(1), warmup=0, passes=3)
    assert not calls                     # rejected before any call ran
    with pytest.raises(ValueError):
        measure(lambda: calls.append(1), warmup=-1, passes=1)
    assert not calls


def test_measure_allows_compile_only_use():
    """warmup≥1 with passes=0 is the sanctioned unmeasured call shape
    (bench_continuous uses it to report compile cost as its own row)."""
    calls = []
    out, times, warm_s = measure(lambda: calls.append(1) or "r",
                                 warmup=1, passes=0)
    assert calls == [1] and times == [] and out is None
    assert warm_s >= 0.0
    # warmup=0, passes=0 measures nothing: also fine
    out, times, _ = measure(lambda: calls.append(1), warmup=0, passes=0)
    assert len(calls) == 1 and times == []


def test_measure_counts_and_returns_last_result():
    calls = []

    def fn():
        calls.append(len(calls))
        return len(calls)

    out, times, warm_s = measure(fn, warmup=2, passes=3)
    assert len(calls) == 5               # 2 warmup + 3 measured
    assert out == 5                      # last measured result
    assert len(times) == 3 and all(t >= 0.0 for t in times)
