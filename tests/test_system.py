"""End-to-end system test — the paper's full workflow at miniature scale:

train a tiny Transformer NMT model on the synthetic corpus → calibrate on
held-out sentences → PTQ (symmetric mode) → serve with the batched engine →
BLEU of INT8 vs FP stays within tolerance (Table-1 behaviour).

The trained model comes from the session-scoped ``trained_nmt`` fixture in
``conftest.py`` (shared with ``test_int8_parity.py`` — trained once).
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Calibrator, QuantMode, QuantPolicy, Taps, quantize_model
from repro.data import corpus_bleu
from repro.serving import ServingEngine, TokenSortedScheduler


def _translate(model, params, qctx, requests, max_len=20):
    from repro.core.ptq import FP_CONTEXT
    engine = ServingEngine(model, params, quant=qctx or FP_CONTEXT,
                           max_len=64)
    sched = TokenSortedScheduler(batch_size=16)
    items = sched.plan(requests)
    hyps = {}
    for item in items:
        res = engine.generate(item.batch, max_new_tokens=max_len)
        for local, global_idx in enumerate(item.indices):
            hyps[global_idx] = list(res.tokens[local])
    return [hyps[i] for i in range(len(requests))]


def test_training_converged(trained_nmt):
    _, _, _, _, loss = trained_nmt
    assert loss < 1.2, f"tiny NMT failed to learn (loss={loss})"


def test_fp_vs_int8_bleu(trained_nmt):
    cfg, model, params, corpus, _ = trained_nmt
    test_set = corpus[:48]
    refs = [list(s.tgt) for s in test_set]

    fp_hyps = _translate(model, params, None, test_set)
    bleu_fp = corpus_bleu(fp_hyps, refs)
    assert bleu_fp > 10.0, f"FP32 model should translate (BLEU={bleu_fp})"

    # calibrate on a disjoint slice (the paper used 600/3003 sentences)
    cal = Calibrator()
    for s in corpus[100:140]:
        taps = Taps()
        batch = {"src_tokens": jnp.asarray(s.src[None, :]),
                 "tgt_tokens": jnp.asarray(np.concatenate(
                     [[1], s.tgt, [2]])[None, :])}
        model.forward(params, batch, taps=taps)
        cal.observe_taps(taps)
    recs = cal.compute("symmetric")
    qp, qctx = quantize_model(
        params, recs, QuantPolicy(mode=QuantMode.SYMMETRIC,
                                  act_quant="static"))
    q_hyps = _translate(model, qp, qctx, test_set)
    bleu_q = corpus_bleu(q_hyps, refs)

    # the paper's acceptance bar: small drop (we allow a few BLEU at this
    # miniature scale; exact-match tasks amplify single-token flips)
    assert bleu_q >= bleu_fp - 5.0, (bleu_fp, bleu_q)


def test_beam_search_runs(trained_nmt):
    cfg, model, params, corpus, _ = trained_nmt
    from repro.core.ptq import FP_CONTEXT
    engine = ServingEngine(model, params, max_len=64)
    sched = TokenSortedScheduler(batch_size=8)
    item = sched.plan(corpus[:8])[0]
    res = engine.generate_beam(item.batch, beam=3, max_new_tokens=10)
    assert len(res.tokens) == len(item.indices)
