"""Hypothesis import shim so the suite collects without ``hypothesis``.

When the real library is installed (see ``requirements-dev.txt``) this module
re-exports it unchanged and the property tests get full shrinking/coverage.
When it is missing — the common case in the hermetic benchmark container —
a minimal deterministic fallback generates ``max_examples`` pseudo-random
samples per strategy from a seed derived from the test name, so every
``@given`` property still executes with real (repeatable) inputs instead of
erroring at collection.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """Base: a strategy is anything with ``sample(rng) -> value``."""

        def sample(self, rng):  # pragma: no cover - abstract
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value=None, max_value=None):
            self.lo = -(2 ** 31) if min_value is None else int(min_value)
            self.hi = 2 ** 31 if max_value is None else int(max_value)

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, min_value=None, max_value=None, allow_nan=None,
                     allow_infinity=None, width=64):
            self.lo = -1e6 if min_value is None else float(min_value)
            self.hi = 1e6 if max_value is None else float(max_value)

        def sample(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=None):
            self.elements = elements
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 16

        def sample(self, rng):
            n = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elements.sample(rng) for _ in range(n)]

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng):
            return self.elements[int(rng.integers(0, len(self.elements)))]

    class _StrategiesNamespace:
        @staticmethod
        def integers(min_value=None, max_value=None):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def floats(min_value=None, max_value=None, **kw):
            return _Floats(min_value, max_value, **kw)

        @staticmethod
        def lists(elements, min_size=0, max_size=None, **kw):
            return _Lists(elements, min_size, max_size)

    st = _StrategiesNamespace()

    class settings:  # noqa: N801 - mirrors hypothesis API
        """Records ``max_examples``; all other knobs are ignored."""

        def __init__(self, max_examples=_DEFAULT_EXAMPLES, **kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._compat_max_examples = self.max_examples
            return fn

    def given(*strategies):
        def deco(fn):
            def runner():
                # read max_examples at call time so both decorator orders
                # work: @settings above @given sets it on `runner`,
                # @given above @settings sets it on `fn`
                n = getattr(runner, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _DEFAULT_EXAMPLES))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    vals = [s.sample(rng) for s in strategies]
                    fn(*vals)

            # No functools.wraps: the wrapper must expose a zero-arg
            # signature or pytest would treat the generated parameters as
            # fixture requests.  (All @given tests in this suite take only
            # strategy-generated arguments.)
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
