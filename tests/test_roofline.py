"""First direct unit tests for launch/roofline.py + hlo_analysis plumbing.

Term assembly is pure arithmetic over probe/dry-run inputs and the
collective-bytes pipeline is pure string parsing — both testable without
devices.  The sharded-serve sanity bound (prediction vs *measured*, on
the dim the host backend models faithfully) lives in
``benchmarks/bench_sharded_serve.py``; here we lock the algebra those
comparisons rest on.
"""

import json

import pytest

from repro.configs import get_config
from repro.launch import roofline
from repro.launch.hlo_analysis import analyze_collectives, shape_bytes
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_BF16, PEAK_INT8,
                                   decode_collective_bytes, model_flops,
                                   sharded_decode_cell)


# --------------------------------------------------------------- model_flops
def test_model_flops_kinds_scale_as_documented():
    cfg = get_config("transformer-base")
    n = cfg.n_active_params
    assert model_flops("transformer-base", "train_4k") == \
        pytest.approx(6.0 * n * 256 * 4096)
    assert model_flops("transformer-base", "prefill_32k") == \
        pytest.approx(2.0 * n * 32 * 32768)
    # decode: per emitted token — no seq_len factor
    assert model_flops("transformer-base", "decode_32k") == \
        pytest.approx(2.0 * n * 128)


# ------------------------------------------------- decode_collective_bytes
def test_collective_bytes_zero_without_tensor_parallelism():
    assert decode_collective_bytes(n_layers=6, d_model=512, rows=8,
                                   tp=1) == 0
    assert decode_collective_bytes(n_layers=6, d_model=512, rows=8,
                                   tp=0) == 0


def test_collective_bytes_ring_formula():
    # 3 all-reduces per decoder layer, ring wire bytes 2·b·(g-1)/g, plus
    # one logits all-gather b·(g-1)/g
    got = decode_collective_bytes(n_layers=2, d_model=128, rows=4, tp=2,
                                  act_bytes=4, vocab=64)
    act = 4 * 128 * 4
    want = 2 * 3 * (2 * act * 1 // 2) + 4 * 64 * 4 * 1 // 2
    assert got == want


def test_collective_bytes_monotone_in_layers_and_rows():
    base = dict(d_model=256, rows=4, tp=4, act_bytes=2)
    one = decode_collective_bytes(n_layers=1, **base)
    assert decode_collective_bytes(n_layers=5, **base) == 5 * one
    assert decode_collective_bytes(
        n_layers=1, d_model=256, rows=8, tp=4, act_bytes=2) == 2 * one


def test_collective_bytes_ring_factor_saturates():
    # 2(g-1)/g → 2 as g grows: tp=8 wire bytes < 2× tp=2 wire bytes
    kw = dict(n_layers=2, d_model=128, rows=4)
    assert decode_collective_bytes(tp=8, **kw) < \
        2 * decode_collective_bytes(tp=2, **kw)


# ------------------------------------------------------ sharded_decode_cell
def test_cell_terms_and_bound():
    cfg = get_config("transformer-base")
    cell = sharded_decode_cell(cfg, rows=8, tp=4, quantized=True)
    t = cell["terms_s"]
    assert set(t) == {"compute_s", "memory_s", "collective_s"}
    assert cell["step_time_bound_s"] == max(t.values())
    assert cell["dominant"] == max(t, key=t.get)
    assert t["compute_s"] == pytest.approx(
        2.0 * cfg.n_active_params * 8 / (4 * PEAK_INT8))
    assert t["collective_s"] == pytest.approx(
        cell["collective_bytes_per_device"] / ICI_BW)


def test_cell_compute_and_weights_shard_with_tp():
    cfg = get_config("transformer-base")
    c2 = sharded_decode_cell(cfg, rows=8, tp=2)["terms_s"]
    c4 = sharded_decode_cell(cfg, rows=8, tp=4)["terms_s"]
    assert c4["compute_s"] == pytest.approx(c2["compute_s"] / 2)
    assert c4["memory_s"] < c2["memory_s"]          # weights/tp stream
    assert c4["collective_s"] > c2["collective_s"]  # more ring hops


def test_cell_unsharded_has_no_collective_term():
    cfg = get_config("transformer-base")
    cell = sharded_decode_cell(cfg, rows=4, tp=1, quantized=False)
    assert cell["terms_s"]["collective_s"] == 0.0
    assert cell["collective_bytes_per_device"] == 0
    assert cell["terms_s"]["compute_s"] == pytest.approx(
        2.0 * cfg.n_active_params * 4 / PEAK_BF16)


# ------------------------------------------- collective-bytes HLO plumbing
HLO = """\
ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128] parameter(0)
  %w = f32[8,128] while(%p0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[8,128] copy(%w)
}

%body (bp: f32[8,128]) -> f32[8,128] {
  %bp = f32[8,128] parameter(0)
  %ar = f32[8,128] all-reduce(%bp), replica_groups=[1,4], to_apply=%add
  ROOT %br = f32[8,128] copy(%ar)
}

%cond (cp: f32[8,128]) -> pred[] {
  %cp = f32[8,128] parameter(0)
  ROOT %lt = pred[] constant(1)
}

%other (op: f32[16,64]) -> f32[16,64] {
  %op = f32[16,64] parameter(0)
  ROOT %ag = f32[16,64] all-gather(%op), replica_groups=[1,2], dimensions={0}
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert shape_bytes("s8[4,16,2,32]") == 4 * 16 * 2 * 32
    assert shape_bytes("bf16[10]") == 20


def test_analyze_collectives_while_multiplier_and_ring_bytes():
    rec = analyze_collectives(HLO)
    # ring all-reduce of 4096B over g=4: 2·4096·3/4 = 6144, ×10 loop trips
    ar = 2 * 8 * 128 * 4 * 3 // 4
    # all-gather of 4096B over g=2 outside any loop: 4096·1/2 = 2048, ×1
    ag = 16 * 64 * 4 * 1 // 2
    assert rec["by_kind"]["all-reduce"] == ar * 10
    assert rec["by_kind"]["all-gather"] == ag
    assert rec["total_bytes"] == ar * 10 + ag
    assert rec["n_ops"] == 2
    assert rec["loop_multipliers"].get("body") == 10


def test_analyze_collectives_empty_module():
    rec = analyze_collectives("ENTRY %main () -> f32[] {\n  ROOT %c = "
                              "f32[] constant(0)\n}\n")
    assert rec["total_bytes"] == 0 and rec["n_ops"] == 0


# ------------------------------------------------- build_cell term assembly
def test_build_cell_assembles_terms_from_record_and_probe(tmp_path,
                                                          monkeypatch):
    arch, shape = "transformer-base", "decode_32k"
    rec = {"n_devices": 8, "mesh": "data=1,model=8",
           "memory": {"argument_bytes": 2 * HBM_BW,     # memory_s = 2.0
                      "peak_per_device_gib": 1.5},
           "collectives": {"total_bytes": 3 * ICI_BW}}  # collective_s = 3.0
    d = tmp_path / "dryrun"
    d.mkdir()
    (d / f"{arch}__{shape}__1pod__int8.json").write_text(json.dumps(rec))
    monkeypatch.setattr(roofline, "DRYRUN_DIR", str(d))

    flops = 8 * PEAK_INT8                               # compute_s = 1.0
    import repro.launch.costs as costs
    monkeypatch.setattr(costs, "probe",
                        lambda *a, **kw: {"flops": flops, "bytes": 0})

    cell = roofline.build_cell(arch, shape, quantized=True)
    t = cell["terms_s"]
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(3.0)
    assert cell["dominant"] == "collective_s"
    assert cell["step_time_bound_s"] == pytest.approx(3.0)
    assert cell["chips"] == 8
    assert cell["useful_compute_ratio"] == pytest.approx(
        model_flops(arch, shape) / flops)


def test_build_cell_skips_without_record(tmp_path, monkeypatch):
    monkeypatch.setattr(roofline, "DRYRUN_DIR", str(tmp_path / "none"))
    cell = roofline.build_cell("transformer-base", "decode_32k")
    assert "skipped" in cell


# ------------------------------------------------------- weight_stream_bytes
def test_weight_stream_bytes_exact_assembly():
    n = 1_000_000
    ws = roofline.weight_stream_bytes
    # FP streams act_bytes per weight; INT8 streams exactly one byte
    assert ws(n, quantized=False, act_bytes=4) == 4 * n
    assert ws(n, quantized=False, act_bytes=2) == 2 * n
    assert ws(n, weight_bits=8) == n
    # INT4 default layout: nibble + (scale, min) f16 pair per 128 weights
    # → 0.5 + 2·2/128 = 0.53125 bytes/weight
    assert ws(n, weight_bits=4) == int(n * (0.5 + 4.0 / 128))
    assert n / ws(n, weight_bits=4) == pytest.approx(1.0 / 0.53125)
    assert n / ws(n, weight_bits=4) >= 1.88  # the bench's byte-cut floor
    # group/scale knobs move the metadata overhead exactly
    assert ws(n, weight_bits=4, group_size=32, scale_bytes=4) == \
        int(n * (0.5 + 8.0 / 32))
    # fraction mixes linearly between INT8 and full-INT4
    assert ws(n, weight_bits=4, int4_fraction=0.0) == n
    half = ws(n, weight_bits=4, int4_fraction=0.5)
    assert half == int(n * (0.5 + 0.5 * 0.53125))
    with pytest.raises(ValueError):
        ws(n, weight_bits=3)


def test_cell_int4_memory_term():
    cfg = get_config("transformer-base")
    n = cfg.n_active_params
    c8 = sharded_decode_cell(cfg, rows=8, tp=2, kv_bytes_per_step=1000)
    c4 = sharded_decode_cell(cfg, rows=8, tp=2, kv_bytes_per_step=1000,
                             weight_bits=4)
    # memory term assembles exactly from weight_stream_bytes
    w4 = roofline.weight_stream_bytes(n, weight_bits=4)
    assert c4["weight_bytes_per_step"] == w4
    assert c4["terms_s"]["memory_s"] == \
        pytest.approx((w4 / 2 + 1000) / HBM_BW)
    # compute + collective terms are untouched (nibbles feed the same
    # s8×s8 MXU path); only the weight-stream bytes shrink ≥ 1.88×
    assert c4["terms_s"]["compute_s"] == c8["terms_s"]["compute_s"]
    assert c4["terms_s"]["collective_s"] == c8["terms_s"]["collective_s"]
    assert c8["weight_bytes_per_step"] / c4["weight_bytes_per_step"] >= 1.88
    assert c4["weight_bits"] == 4 and c8["weight_bits"] == 8


def test_cell_int4_fraction_interpolates():
    cfg = get_config("transformer-base")
    cells = [sharded_decode_cell(cfg, rows=4, tp=1, weight_bits=4,
                                 int4_fraction=f)
             for f in (0.0, 0.5, 1.0)]
    b = [c["weight_bytes_per_step"] for c in cells]
    assert b[0] > b[1] > b[2]
    assert b[1] == pytest.approx((b[0] + b[2]) / 2, abs=1)


def test_cell_unquantized_ignores_weight_bits():
    cfg = get_config("transformer-base")
    c = sharded_decode_cell(cfg, rows=4, tp=1, quantized=False,
                            weight_bits=4)
    act_bytes = int(cfg.activation_dtype.itemsize)
    assert c["weight_bytes_per_step"] == cfg.n_active_params * act_bytes
    assert c["weight_bits"] == 8 * act_bytes
