"""System-level PTQ behaviour: calibrate → quantize → compare (the paper's
full workflow at laptop scale), policy routing, graph-level properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Calibrator,
    QuantMode,
    QuantPolicy,
    Taps,
    count_quantized,
    quantize_model,
    summarize,
)
from repro.configs import get_config
from repro.models import build_model


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("yi-9b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _calibrate(cfg, model, params, n_batches=4):
    rng = np.random.default_rng(0)
    cal = Calibrator()
    for _ in range(n_batches):
        taps = Taps()
        batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab, (2, 24)))}
        model.forward(params, batch, taps=taps)
        cal.observe_taps(taps)
    return cal


def test_taps_cover_every_linear(small_model):
    cfg, model, params = small_model
    taps = Taps()
    model.forward(params, {"tokens": jnp.zeros((1, 8), jnp.int32)},
                  taps=taps)
    names = set(taps.values)
    # every block records its attention + ffn matmul inputs
    for i in range(cfg.n_layers):
        for site in ("attn/q_proj", "attn/k_proj", "attn/v_proj",
                     "attn/o_proj", "ffn/gate", "ffn/up", "ffn/down"):
            assert f"blocks.{i}/{site}" in names


def test_calibrated_ptq_end_to_end(small_model, rng):
    cfg, model, params = small_model
    cal = _calibrate(cfg, model, params)
    recs = cal.compute("symmetric")
    policy = QuantPolicy(mode=QuantMode.SYMMETRIC, act_quant="static")
    qparams, qctx = quantize_model(params, recs, policy)

    stats = count_quantized(qparams)
    assert stats["quantized_linears"] > 0

    batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab, (2, 24)))}
    fp, _ = model.forward(params, batch)
    q8, _ = model.forward(qparams, batch, quant=qctx)
    rel = np.abs(np.asarray(q8) - np.asarray(fp)).max() / \
        (np.abs(np.asarray(fp)).max() + 1e-9)
    assert rel < 0.15, f"calibrated INT8 diverged: {rel}"


def test_policy_denies_router_and_sparse(small_model):
    cfg, model, params = small_model
    policy = QuantPolicy()
    assert not policy.should_quantize("blocks.0/moe/router")
    assert policy.should_quantize("blocks.0/ffn/gate", None) \
        == (policy.act_quant == "dynamic")


def test_summarize_counts(small_model):
    cfg, model, params = small_model
    cal = _calibrate(cfg, model, params, n_batches=2)
    recs = cal.compute("symmetric")
    stats = summarize(QuantPolicy(), recs)
    assert stats["total"] == len(recs)
    assert stats["quantized"] + stats["sparse_skipped"] + stats["denied"] \
        <= stats["total"]


def test_quantized_bytes_shrink(small_model):
    cfg, model, params = small_model
    qparams, _ = quantize_model(params, {},
                                QuantPolicy(act_quant="dynamic"))
    stats = count_quantized(qparams)
    fp_bytes = sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(params))
    q_bytes = stats["int8_bytes"] + stats["fp_bytes"]
    assert q_bytes < fp_bytes * 0.6        # linears dominate → ~4× smaller


def test_mode_accuracy_ordering(small_model, rng):
    """Calibrated modes must beat naive quantization on logit fidelity —
    the Table-1 relationship at unit-test scale."""
    cfg, model, params = small_model
    cal = _calibrate(cfg, model, params)
    batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab, (2, 24)))}
    fp, _ = model.forward(params, batch)

    errs = {}
    for mode in ("naive", "symmetric", "independent", "conjugate"):
        recs = cal.compute(mode)
        policy = QuantPolicy(mode=QuantMode(mode), act_quant="static")
        qp, qctx = quantize_model(params, recs, policy)
        q8, _ = model.forward(qp, batch, quant=qctx)
        errs[mode] = float(np.abs(np.asarray(q8) - np.asarray(fp)).mean())
    # random-init activations are well-behaved, so differences are small —
    # but calibrated symmetric must never be materially worse than naive.
    assert errs["symmetric"] <= errs["naive"] * 1.5
