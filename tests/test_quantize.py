"""Unit + property tests for the core quantization math (paper §4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    QuantMode,
    Thresholds,
    fake_quant,
    quantize_dynamic,
    quantize_naive,
    quantize_weight,
    quantize_with_thresholds,
)
from repro.core.qtensor import quantize_affine, quantize_symmetric


def test_symmetric_round_trip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    qt = quantize_dynamic(x)
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(x))
    # quantization error is at most half a quantization step (per row)
    step = np.asarray(qt.scale)
    assert np.all(err <= step * 0.5 + 1e-7)


def test_symmetric_zero_point_is_zero(rng):
    x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    qt = quantize_symmetric(x, jnp.float32(np.abs(x).max()))
    assert float(jnp.max(jnp.abs(qt.zero_point))) == 0.0


def test_affine_maps_extremes(rng):
    x = jnp.asarray(rng.uniform(-3.0, 9.0, size=(100,)).astype(np.float32))
    x = x.at[0].set(-3.0).at[1].set(9.0)
    qt = quantize_affine(x, -3.0, 9.0)
    assert int(qt.data[0]) == -127
    assert int(qt.data[1]) == 127


def test_clipping_behaviour():
    x = jnp.asarray([-100.0, -1.0, 0.0, 1.0, 100.0], jnp.float32)
    thr = Thresholds(-2.0, 2.0)
    y = np.asarray(fake_quant(x, thr))
    assert y[0] == pytest.approx(-2.0, abs=0.02)
    assert y[-1] == pytest.approx(2.0, abs=0.02)
    assert y[2] == pytest.approx(0.0, abs=0.02)


def test_weight_quantization_per_channel(rng):
    # columns with very different scales must quantize independently
    w = rng.normal(size=(64, 8)).astype(np.float32)
    w[:, 0] *= 100.0
    w[:, 7] *= 0.01
    qw = quantize_weight(jnp.asarray(w))
    rel = np.abs(np.asarray(qw.dequantize()) - w) / (np.abs(w).max(0) + 1e-12)
    assert rel.max() < 0.01


def test_naive_quantization_outlier_failure_mode(rng):
    """Paper §4.1: one outlier destroys naive min/max precision."""
    x = rng.normal(size=10_000).astype(np.float32)
    x[0] = 1000.0
    naive = np.asarray(quantize_naive(jnp.asarray(x)).dequantize())
    clipped = np.asarray(
        fake_quant(jnp.asarray(x), Thresholds(-4.0, 4.0)))
    bulk = slice(1, None)
    naive_err = np.abs(naive[bulk] - x[bulk]).mean()
    clip_err = np.abs(clipped[bulk] - x[bulk]).mean()
    assert clip_err < naive_err / 20  # calibrated clipping ≫ naive


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

finite_arrays = st.lists(
    st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
              width=32),
    min_size=4, max_size=256)


@given(finite_arrays)
@settings(max_examples=50, deadline=None)
def test_prop_quantized_values_in_range(vals):
    x = jnp.asarray(np.asarray(vals, np.float32).reshape(1, -1))
    qt = quantize_dynamic(x)
    assert int(jnp.max(qt.data)) <= 127
    assert int(jnp.min(qt.data)) >= -127


@given(finite_arrays)
@settings(max_examples=50, deadline=None)
def test_prop_round_trip_monotone_error(vals):
    """Dequantized values never exceed the observed max magnitude."""
    x = jnp.asarray(np.asarray(vals, np.float32).reshape(1, -1))
    qt = quantize_dynamic(x)
    back = np.asarray(qt.dequantize())
    assert np.all(np.abs(back) <= np.abs(np.asarray(x)).max() + 1e-6)


@given(finite_arrays, st.floats(min_value=0.1, max_value=100.0))
@settings(max_examples=50, deadline=None)
def test_prop_scale_invariance(vals, scale):
    """quant(s·x) ≈ s·quant(x) for symmetric dynamic quantization."""
    x = np.asarray(vals, np.float32).reshape(1, -1)
    q1 = np.asarray(quantize_dynamic(jnp.asarray(x)).dequantize())
    q2 = np.asarray(quantize_dynamic(jnp.asarray(x * scale)).dequantize())
    np.testing.assert_allclose(q1 * scale, q2, rtol=1e-3, atol=1e-3)


@given(st.floats(min_value=0.01, max_value=100.0),
       st.floats(min_value=0.01, max_value=100.0))
@settings(max_examples=30, deadline=None)
def test_prop_threshold_modes(t_neg, t_pos):
    thr = Thresholds(-t_neg, t_pos)
    env = thr.symmetric_envelope()
    assert env.symmetric
    assert env.t_max == pytest.approx(max(t_neg, t_pos))
