"""Unit + property tests for the core quantization math (paper §4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    QuantMode,
    Thresholds,
    fake_quant,
    quantize_dynamic,
    quantize_naive,
    quantize_weight,
    quantize_with_thresholds,
)
from repro.core.qtensor import quantize_affine, quantize_symmetric


def test_symmetric_round_trip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    qt = quantize_dynamic(x)
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(x))
    # quantization error is at most half a quantization step (per row)
    step = np.asarray(qt.scale)
    assert np.all(err <= step * 0.5 + 1e-7)


def test_symmetric_zero_point_is_zero(rng):
    x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    qt = quantize_symmetric(x, jnp.float32(np.abs(x).max()))
    assert float(jnp.max(jnp.abs(qt.zero_point))) == 0.0


def test_affine_maps_extremes(rng):
    x = jnp.asarray(rng.uniform(-3.0, 9.0, size=(100,)).astype(np.float32))
    x = x.at[0].set(-3.0).at[1].set(9.0)
    qt = quantize_affine(x, -3.0, 9.0)
    assert int(qt.data[0]) == -127
    assert int(qt.data[1]) == 127


def test_clipping_behaviour():
    x = jnp.asarray([-100.0, -1.0, 0.0, 1.0, 100.0], jnp.float32)
    thr = Thresholds(-2.0, 2.0)
    y = np.asarray(fake_quant(x, thr))
    assert y[0] == pytest.approx(-2.0, abs=0.02)
    assert y[-1] == pytest.approx(2.0, abs=0.02)
    assert y[2] == pytest.approx(0.0, abs=0.02)


def test_weight_quantization_per_channel(rng):
    # columns with very different scales must quantize independently
    w = rng.normal(size=(64, 8)).astype(np.float32)
    w[:, 0] *= 100.0
    w[:, 7] *= 0.01
    qw = quantize_weight(jnp.asarray(w))
    rel = np.abs(np.asarray(qw.dequantize()) - w) / (np.abs(w).max(0) + 1e-12)
    assert rel.max() < 0.01


def test_naive_quantization_outlier_failure_mode(rng):
    """Paper §4.1: one outlier destroys naive min/max precision."""
    x = rng.normal(size=10_000).astype(np.float32)
    x[0] = 1000.0
    naive = np.asarray(quantize_naive(jnp.asarray(x)).dequantize())
    clipped = np.asarray(
        fake_quant(jnp.asarray(x), Thresholds(-4.0, 4.0)))
    bulk = slice(1, None)
    naive_err = np.abs(naive[bulk] - x[bulk]).mean()
    clip_err = np.abs(clipped[bulk] - x[bulk]).mean()
    assert clip_err < naive_err / 20  # calibrated clipping ≫ naive


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

finite_arrays = st.lists(
    st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
              width=32),
    min_size=4, max_size=256)


@given(finite_arrays)
@settings(max_examples=50, deadline=None)
def test_prop_quantized_values_in_range(vals):
    x = jnp.asarray(np.asarray(vals, np.float32).reshape(1, -1))
    qt = quantize_dynamic(x)
    assert int(jnp.max(qt.data)) <= 127
    assert int(jnp.min(qt.data)) >= -127


@given(finite_arrays)
@settings(max_examples=50, deadline=None)
def test_prop_round_trip_monotone_error(vals):
    """Dequantized values never exceed the observed max magnitude."""
    x = jnp.asarray(np.asarray(vals, np.float32).reshape(1, -1))
    qt = quantize_dynamic(x)
    back = np.asarray(qt.dequantize())
    assert np.all(np.abs(back) <= np.abs(np.asarray(x)).max() + 1e-6)


@given(finite_arrays, st.floats(min_value=0.1, max_value=100.0))
@settings(max_examples=50, deadline=None)
def test_prop_scale_invariance(vals, scale):
    """quant(s·x) ≈ s·quant(x) for symmetric dynamic quantization."""
    x = np.asarray(vals, np.float32).reshape(1, -1)
    q1 = np.asarray(quantize_dynamic(jnp.asarray(x)).dequantize())
    q2 = np.asarray(quantize_dynamic(jnp.asarray(x * scale)).dequantize())
    np.testing.assert_allclose(q1 * scale, q2, rtol=1e-3, atol=1e-3)


@given(st.floats(min_value=0.01, max_value=100.0),
       st.floats(min_value=0.01, max_value=100.0))
@settings(max_examples=30, deadline=None)
def test_prop_threshold_modes(t_neg, t_pos):
    thr = Thresholds(-t_neg, t_pos)
    env = thr.symmetric_envelope()
    assert env.symmetric
    assert env.t_max == pytest.approx(max(t_neg, t_pos))


# ---------------------------------------------------------------------------
# byte accounting + block-wise INT4 (BlockQTensor)
# ---------------------------------------------------------------------------

from repro.core import (
    BlockQTensor,
    int4_eligible_site,
    quantize_block,
    quantize_model,
    weight_bytes_by_site,
)
from repro.core.policy import QuantPolicy
from repro.core.qtensor import QTensor, pack_nibbles, unpack_nibbles


def test_qtensor_nbytes_dtype_aware(rng):
    """nbytes must follow the stored dtypes, not assume 1-byte data and
    4-byte scales (the bug this test pins down)."""
    K, N = 64, 32
    data = jnp.zeros((K, N), jnp.int8)
    qt32 = QTensor(data, jnp.zeros((1, N), jnp.float32),
                   jnp.zeros((), jnp.float32), None)
    assert qt32.nbytes() == K * N + N * 4 + 4
    qt16 = QTensor(data, jnp.zeros((1, N), jnp.float16),
                   jnp.zeros((), jnp.float16), None)
    assert qt16.nbytes() == K * N + N * 2 + 2


@pytest.mark.parametrize("scale_dtype,scale_bytes", [
    (jnp.float32, 4), (jnp.float16, 2),
])
def test_block_qtensor_nbytes(rng, scale_dtype, scale_bytes):
    K, N, G = 256, 64, 128
    bq = quantize_block(jnp.asarray(rng.normal(size=(K, N)), jnp.float32),
                        group_size=G, scale_dtype=scale_dtype)
    n_g = K // G
    assert bq.nbytes() == K * N // 2 + 2 * n_g * N * scale_bytes
    # the headline claim: ≥ 1.9× fewer bytes than per-channel INT8 at the
    # default layout (G=128, f16 scale/min pairs)
    int8_bytes = K * N + N * 4 + N * 4
    if scale_dtype == jnp.float16:
        assert int8_bytes / bq.nbytes() >= 1.9


def test_pack_unpack_round_trip(rng):
    codes = jnp.asarray(rng.integers(0, 16, (2, 64, 32)), jnp.int32)
    packed = pack_nibbles(codes)
    assert packed.shape == (2, 32, 32) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_nibbles(packed)),
                                  np.asarray(codes))


def test_pack_nibbles_rejects_odd_rows():
    with pytest.raises(ValueError):
        pack_nibbles(jnp.zeros((3, 8), jnp.int32))


def test_block_round_trip_error_bound(rng):
    """Min/max fit (refine_iters=0): error ≤ half a step per element.  The
    refined default trades this worst-case bound for lower MSE (clipped
    extremes may exceed half a step), so the bound is pinned at iters=0."""
    K, N, G = 256, 48, 32
    w = jnp.asarray(rng.normal(size=(K, N)) * 3, jnp.float32)
    bq = quantize_block(w, group_size=G, scale_dtype=jnp.float32,
                        refine_iters=0)
    err = np.abs(np.asarray(bq.dequantize()) - np.asarray(w))
    step = np.repeat(np.asarray(bq.scale, np.float32), G, axis=0)
    assert err.shape == (K, N)
    assert np.all(err <= step * 0.5 + 1e-6)


def test_block_refinement_reduces_mse(rng):
    """The alternating-least-squares fit must not be worse than the raw
    min/max fit (it is what holds the end-to-end BLEU bar at G=128)."""
    K, N, G = 256, 48, 128
    w = jnp.asarray(rng.normal(size=(K, N)) * 3, jnp.float32)
    raw = quantize_block(w, group_size=G, scale_dtype=jnp.float32,
                         refine_iters=0)
    ref = quantize_block(w, group_size=G, scale_dtype=jnp.float32)
    mse_raw = float(jnp.mean((raw.dequantize() - w) ** 2))
    mse_ref = float(jnp.mean((ref.dequantize() - w) ** 2))
    assert mse_ref <= mse_raw
    assert mse_ref < mse_raw * 0.95    # a real cut, not a tie
    # the refit moves scale/min but never the byte layout
    assert ref.nbytes() == raw.nbytes()
    assert ref.data.shape == raw.data.shape


def test_block_constant_group_is_exact(rng):
    """A constant group has span 0 → scale 0 → vmin reproduces it exactly."""
    K, N, G = 64, 16, 32
    w = jnp.broadcast_to(jnp.asarray(rng.normal(size=(1, N)), jnp.float32),
                         (K, N))
    bq = quantize_block(w, group_size=G, scale_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(bq.dequantize()), np.asarray(w))


def test_block_tail_padding_keeps_scale(rng):
    """K % G != 0: edge padding must not disturb the tail group's scale, and
    dequantize() must return the logical (unpadded) shape."""
    K, N, G = 70, 24, 32
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    bq = quantize_block(w, group_size=G, scale_dtype=jnp.float32,
                        refine_iters=0)
    assert bq.shape == (K, N) and bq.dequantize().shape == (K, N)
    tail = np.asarray(w[64:70])
    span = tail.max(axis=0) - tail.min(axis=0)
    np.testing.assert_allclose(np.asarray(bq.scale[2]), span / 15, rtol=1e-6)
    err = np.abs(np.asarray(bq.dequantize()[64:]) - tail)
    assert np.all(err <= span / 15 * 0.5 + 1e-6)
    # the refined default also keeps logical shapes/padding behaviour
    ref = quantize_block(w, group_size=G, scale_dtype=jnp.float32)
    assert ref.shape == (K, N) and ref.dequantize().shape == (K, N)


def test_block_stacked_leading_dims(rng):
    """Stacked (scan-layout) weights quantize along axis -2 per slice."""
    L, K, N, G = 3, 64, 16, 32
    w = jnp.asarray(rng.normal(size=(L, K, N)), jnp.float32)
    bq = quantize_block(w, group_size=G, scale_dtype=jnp.float32)
    assert bq.data.shape == (L, K // 2, N)
    per_layer = [quantize_block(w[i], group_size=G,
                                scale_dtype=jnp.float32) for i in range(L)]
    for i in range(L):
        np.testing.assert_array_equal(np.asarray(bq.data[i]),
                                      np.asarray(per_layer[i].data))


def test_int4_eligible_site():
    yes = [
        "dec_blocks.0/ffn/in", "dec_blocks.3/ffn/out",
        "dec_blocks.1/self_attn/o_proj", "dec_blocks.2/cross_attn/o_proj",
        "dec_blocks/ffn/gate", "dec_blocks.5/ffn/up",
    ]
    no = [
        "enc_blocks.0/ffn/in",              # encoder stays INT8
        "dec_blocks.0/self_attn/q_proj",    # score path stays INT8
        "dec_blocks.0/self_attn/k_proj", "dec_blocks.0/self_attn/v_proj",
        "logits", "embed", "ffn/in",        # no decoder-block segment
    ]
    assert all(int4_eligible_site(s) for s in yes)
    assert not any(int4_eligible_site(s) for s in no)


def test_quantize_model_weight_bits4_routing(rng):
    params = {
        "dec_blocks.0": {
            "ffn": {"in": {"w": jnp.asarray(rng.normal(size=(64, 128)),
                                            jnp.float32)}},
            "self_attn": {
                "o_proj": {"w": jnp.asarray(rng.normal(size=(64, 64)),
                                            jnp.float32)},
                "q_proj": {"w": jnp.asarray(rng.normal(size=(64, 64)),
                                            jnp.float32)},
            },
        },
        "enc_blocks.0": {
            "ffn": {"in": {"w": jnp.asarray(rng.normal(size=(64, 128)),
                                            jnp.float32)}},
        },
    }
    qp, _ = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"),
                           weight_bits=4, weight_group_size=32)
    assert isinstance(qp["dec_blocks.0"]["ffn"]["in"]["w"], BlockQTensor)
    assert isinstance(qp["dec_blocks.0"]["self_attn"]["o_proj"]["w"],
                      BlockQTensor)
    # score-path and encoder weights stay per-channel INT8
    assert isinstance(qp["dec_blocks.0"]["self_attn"]["q_proj"]["w"], QTensor)
    assert isinstance(qp["enc_blocks.0"]["ffn"]["in"]["w"], QTensor)

    per_site = weight_bytes_by_site(qp)
    assert set(per_site) == {
        "dec_blocks.0/ffn/in", "dec_blocks.0/self_attn/o_proj",
        "dec_blocks.0/self_attn/q_proj", "enc_blocks.0/ffn/in",
    }
    qp8, _ = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"),
                            weight_bits=8)
    per_site8 = weight_bytes_by_site(qp8)
    ratio = per_site8["dec_blocks.0/ffn/in"] / per_site["dec_blocks.0/ffn/in"]
    assert ratio > 1.5  # small G=32 here; the default G=128 clears 1.9


def test_quantize_model_rejects_bad_bits(rng):
    with pytest.raises(ValueError):
        quantize_model({}, {}, QuantPolicy(), weight_bits=3)
