"""Model-zoo behaviour: attention oracle, MoE routing, SSM/xLSTM chunked
forms vs sequential references, scan/unrolled equivalence, decode
consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.models import build_model
from repro.models.attention import chunked_attention
from repro.models.ssm import SSMState, ssm_block, ssm_decode_step, ssm_init
from repro.models.xlstm import (
    mlstm_block,
    mlstm_block_sequential,
    mlstm_init,
)


def test_chunked_attention_matches_dense(rng):
    B, S, H, HKV, dh = 2, 60, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, HKV, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, HKV, dh)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, q_chunk=16)
    G = H // HKV
    kr, vr = jnp.repeat(k, G, 2), jnp.repeat(v, G, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(dh)
    mask = np.tril(np.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_unroll_matches_scan(rng):
    B, S, H, dh = 1, 48, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, q_chunk=16, unroll=False)
    b = chunked_attention(q, k, v, causal=True, q_chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_mamba2_chunked_matches_stepwise(rng):
    """SSD chunked scan == naive per-step recurrence."""
    cfg = get_config("zamba2-2.7b").reduced()
    params = ssm_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 40
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    y_chunk, st = ssm_block(params, x, cfg=cfg, site="t", return_state=True)

    # per-step decode over the same sequence
    s_cfg = cfg.ssm
    d_inner = s_cfg.expand * cfg.d_model
    H = d_inner // s_cfg.head_dim
    state = SSMState(
        h=jnp.zeros((B, H, s_cfg.state, s_cfg.head_dim), jnp.float32),
        conv=jnp.zeros((B, s_cfg.conv_width - 1, d_inner), x.dtype))
    outs = []
    for t in range(S):
        y_t, state = ssm_decode_step(params, x[:, t:t + 1], state, cfg=cfg,
                                     site="t")
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(state.h),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_matches_sequential(rng):
    cfg = get_config("xlstm-1.3b").reduced()
    params = mlstm_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(2, 50, cfg.d_model)), jnp.float32)
    y_c, st_c = mlstm_block(params, x, cfg=cfg, site="t", return_state=True)
    y_s, st_s = mlstm_block_sequential(params, x, cfg=cfg, site="t",
                                       return_state=True)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=2e-4, atol=2e-4)
    # states match after rescaling by the log-stabilizer
    np.testing.assert_allclose(
        np.asarray(st_c.C * np.exp(st_c.m)[..., None, None]),
        np.asarray(st_s.C * np.exp(st_s.m)[..., None, None]),
        rtol=2e-4, atol=1e-5)


def test_moe_routing_selects_topk(rng):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)))}
    logits, aux = model.forward(params, batch)
    assert float(aux["load_balance_loss"]) > 0.0
    assert not np.any(np.isnan(np.asarray(logits)))


def test_scan_equals_unrolled_decoder(rng):
    cfg_u = get_config("yi-9b").reduced(n_layers=2)
    cfg_s = dataclasses.replace(cfg_u, scan_layers=True)
    mu, ms = build_model(cfg_u), build_model(cfg_s)
    pu = mu.init(jax.random.PRNGKey(1))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     pu["blocks.0"], pu["blocks.1"])
    ps = {"embed": pu["embed"], "final_norm": pu["final_norm"],
          "blocks": stacked}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg_u.vocab, (2, 16)))}
    lu, _ = mu.forward(pu, batch)
    ls, _ = ms.forward(ps, batch)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ls),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_forward(rng):
    """Greedy decode logits must equal teacher-forced forward logits."""
    cfg = get_config("yi-9b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 12
    tokens = jnp.asarray(rng.integers(3, cfg.vocab, (B, S)))
    full_logits, _ = model.forward(params, {"tokens": tokens})

    state = model.init_decode_state(B, 32, quantized=False)
    pre_logits, state = model.prefill(
        params, {"tokens": tokens[:, :S - 1]}, state)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-3, atol=2e-3)
    step_logits, state = model.decode_step(params, tokens[:, S - 1], state)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode_close_to_fp(rng):
    """Paper §5.3: int8 KV cache ≈ fp cache within quantization tolerance."""
    cfg = get_config("yi-9b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 2, 10
    tokens = jnp.asarray(rng.integers(3, cfg.vocab, (B, S)))

    outs = {}
    for quantized in (False, True):
        state = model.init_decode_state(B, 32, quantized=quantized)
        logits, state = model.prefill(params, {"tokens": tokens}, state)
        outs[quantized] = np.asarray(logits)
    rel = np.abs(outs[True] - outs[False]).max() / \
        (np.abs(outs[False]).max() + 1e-9)
    assert rel < 0.05
