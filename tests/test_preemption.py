"""Overload survival (ISSUE 7): preempt-by-page-spill, deadline-aware
admission, chunked prefill, and the serving chaos harness.

Three layers of coverage:

* **Host-side units**: ``SpillStore`` accounting, ``pick_victims``
  urgency/anti-thrash semantics, ``ChaosSchedule`` determinism, the step
  watchdog's straggler flag + misuse error, and the scheduler's
  EDF/priority/shedding order.
* **Chaos identity matrix** (the harness's reason to exist): a serve run
  under forced preemptions — greedy and beam, FP and INT8, fused and
  unfused admission, fixed and auto bursts, prefix-cache-hit victims,
  mid-stage chunked-prefill victims, overcommitted pools — must emit
  tokens *bit-identical* to an uninterrupted serve, never deadlock, and
  end with every page reclaimed and the spill store empty.
* **Properties** (hypothesis-compat): scheduler lifecycle under random
  preempt/release churn ends with every request in exactly one terminal
  state and the allocator fully reclaimed; the queueing simulation
  terminates under any preemption schedule with conserved useful work.
"""

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.distributed.fault import StepWatchdog
from repro.models import build_model
from repro.models import kv_cache as kvc
from repro.serving import (ChaosSchedule, ContinuousScheduler, Request,
                           ServingEngine, SpilledRequest, SpillStore,
                           make_chaos, pick_victims, simulate_continuous)

MAX_LEN = 32
PAGE_SIZE = 8
BUDGETS = [13, 17, 0, 15, 16, 12]


# ------------------------------------------------------------------ fixtures
_CACHED = {}


def _module_state():
    if "engines" not in _CACHED:
        cfg = get_config("transformer-base").reduced(
            vocab=32, d_model=48, n_layers=1, n_enc_layers=2, d_ff=96,
            n_heads=2, n_kv_heads=2, head_dim=24)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams, qctx = quantize_model(params, {},
                                       QuantPolicy(act_quant="dynamic"))
        engines = {
            "fp_paged": ServingEngine(model, params, max_len=MAX_LEN,
                                      paged=True, page_size=PAGE_SIZE),
            "int8_paged": ServingEngine(model, qparams, quant=qctx,
                                        max_len=MAX_LEN, paged=True,
                                        page_size=PAGE_SIZE),
        }
        _CACHED.update(
            cfg=cfg, model=model, params=params, qparams=qparams, qctx=qctx,
            engines=engines,
            srcs=[r.src for r in make_corpus(len(BUDGETS), cfg.vocab,
                                             seed=11, max_words=8)],
            long_srcs=[r.src for r in make_corpus(4, cfg.vocab, seed=7,
                                                  max_words=14)])
    return _CACHED


def _assert_identity(base, res):
    for a, b in zip(base.requests, res.requests):
        assert a.tokens == b.tokens, (a.req_id, a.tokens, b.tokens)
        if a.score is not None:
            assert abs(a.score - b.score) < 1e-5


def _assert_reclaimed(res):
    assert res.pages_in_use == 0
    assert res.spill_events == res.restore_events   # spill store drained


# ----------------------------------------------------------- chaos identity
MATRIX = [
    ("fp_paged", None, True), ("fp_paged", None, False),
    ("int8_paged", None, True),
    ("fp_paged", 2, True), ("fp_paged", 2, False),
    ("int8_paged", 2, True),
]


@pytest.mark.parametrize("quant,beam,fused", MATRIX)
def test_chaos_identity(quant, beam, fused):
    s = _module_state()
    eng = s["engines"][quant]
    kw = dict(n_slots=4, max_new_tokens=BUDGETS, burst_len=4,
              fused_admission=fused)
    if beam:
        kw["beam"] = beam
    base = eng.serve(s["srcs"], **kw)
    chaos = make_chaos(4, n_rounds=64, preempt_every=1)
    res = eng.serve(s["srcs"], chaos=chaos, **kw)
    assert res.preemptions > 0          # the schedule actually fired
    _assert_identity(base, res)
    _assert_reclaimed(res)


def test_chaos_identity_mixed_beam_widths():
    s = _module_state()
    eng = s["engines"]["int8_paged"]
    widths = [2, 1, 3, 2, 1, 2]
    kw = dict(n_slots=6, max_new_tokens=BUDGETS, burst_len=4, beam=widths)
    base = eng.serve(s["srcs"], **kw)
    res = eng.serve(s["srcs"], chaos=make_chaos(2, n_rounds=64,
                                                preempt_every=1), **kw)
    assert res.preemptions > 0
    _assert_identity(base, res)
    _assert_reclaimed(res)


def test_chaos_identity_auto_burst():
    s = _module_state()
    eng = s["engines"]["int8_paged"]
    kw = dict(n_slots=4, max_new_tokens=BUDGETS, burst_len="auto")
    base = eng.serve(s["srcs"], **kw)
    res = eng.serve(s["srcs"], chaos=make_chaos(6, n_rounds=64,
                                                preempt_every=1), **kw)
    assert res.preemptions > 0
    _assert_identity(base, res)
    _assert_reclaimed(res)


def test_chaos_preempts_prefix_cache_hit():
    """A victim admitted through a prefix-cache hit spills chain-backed
    cross-K/V and must restore bit-identically."""
    s = _module_state()
    eng = ServingEngine(s["model"], s["params"], max_len=MAX_LEN,
                        paged=True, page_size=PAGE_SIZE)
    kw = dict(n_slots=4, max_new_tokens=BUDGETS, burst_len=4,
              prefix_cache=True)
    eng.serve(s["srcs"], **kw)                     # cold: inserts chains
    base = eng.serve(s["srcs"], **kw)              # warm: all hits
    assert base.prefix_hits > 0
    res = eng.serve(s["srcs"], chaos=make_chaos(4, n_rounds=64,
                                                preempt_every=1), **kw)
    assert res.prefix_hits > 0 and res.preemptions > 0
    _assert_identity(base, res)
    _assert_reclaimed(res)


@pytest.mark.parametrize("beam", [None, 2])
def test_chaos_preempts_staged_chunked_prefill(beam):
    """Victims caught mid-stage (chunked encode in flight) drop the stage
    and restage deterministically on re-admission."""
    s = _module_state()
    eng = s["engines"]["fp_paged"]
    srcs = s["long_srcs"] + s["srcs"][:2]
    kw = dict(n_slots=4, max_new_tokens=[8] * len(srcs), burst_len=4)
    if beam:
        kw["beam"] = beam
    base = eng.serve(srcs, **kw)
    res = eng.serve(srcs, prefill_chunk=6,
                    chaos=make_chaos(9, n_rounds=64, preempt_every=1), **kw)
    assert res.chunked_admissions > 0 and res.preemptions > 0
    _assert_identity(base, res)
    _assert_reclaimed(res)


@pytest.mark.parametrize("beam", [None, 2])
def test_overcommit_identity_and_concurrency(beam):
    """Overcommit past worst-case reservation must (a) strictly raise
    admitted concurrency on a starved pool, (b) stay token-identical via
    growth + preempt-by-spill, (c) reclaim everything."""
    s = _module_state()
    eng = ServingEngine(s["model"], s["params"], max_len=MAX_LEN,
                        paged=True, page_size=PAGE_SIZE,
                        n_pages=6 * (beam or 1))
    kw = dict(n_slots=4 * (beam or 1), max_new_tokens=BUDGETS, burst_len=4)
    if beam:
        kw["beam"] = beam
    base = eng.serve(s["srcs"], **kw)
    res = eng.serve(s["srcs"], overcommit=1.5, **kw)
    assert res.peak_running > base.peak_running
    _assert_identity(base, res)
    _assert_reclaimed(res)


def test_chaos_plus_overcommit_plus_chunked():
    """All three overload mechanisms at once — the full storm."""
    s = _module_state()
    eng = ServingEngine(s["model"], s["params"], max_len=MAX_LEN,
                        paged=True, page_size=PAGE_SIZE, n_pages=8)
    srcs = s["long_srcs"] + s["srcs"][:2]
    kw = dict(n_slots=4, max_new_tokens=[8] * len(srcs), burst_len=4)
    base = eng.serve(srcs, **kw)
    res = eng.serve(srcs, overcommit=1.5, prefill_chunk=6,
                    chaos=make_chaos(9, n_rounds=64, preempt_every=2), **kw)
    assert res.preemptions > 0 and res.chunked_admissions > 0
    _assert_identity(base, res)
    _assert_reclaimed(res)


# -------------------------------------------------------- deadline admission
def test_expired_deadline_is_shed():
    s = _module_state()
    eng = s["engines"]["fp_paged"]
    rs = [Request(req_id=i, src=np.asarray(src, np.int32), max_new_tokens=6)
          for i, src in enumerate(s["srcs"][:3])]
    rs[1].deadline_s = -1.0            # provably unmeetable before start
    res = eng.serve(rs, n_slots=2, burst_len=4)
    assert [r.status for r in res.requests] == \
        ["finished", "rejected", "finished"]
    assert res.requests[1].reject_reason
    assert res.rejected == 1 and res.deadline_misses >= 1
    _assert_reclaimed(res)


def test_edf_priority_order():
    sched = ContinuousScheduler(1)
    a = Request(req_id=0, src=np.arange(3, dtype=np.int32),
                max_new_tokens=4)
    b = Request(req_id=1, src=np.arange(3, dtype=np.int32),
                max_new_tokens=4, deadline_s=5.0)
    c = Request(req_id=2, src=np.arange(3, dtype=np.int32),
                max_new_tokens=4, deadline_s=5.0, priority=1.0)
    sched.submit_many([a, b, c])
    got = sched.admit(0.0)
    assert [r.req_id for r in got] == [2]   # same deadline, higher priority
    sched.release(c, 1.0)
    assert [r.req_id for r in sched.admit(1.0)] == [1]   # EDF beats FIFO


def test_starvation_aging_promotes_best_effort():
    sched = ContinuousScheduler(1, starvation_aging=2.0)
    best_effort = Request(req_id=0, src=np.arange(2, dtype=np.int32),
                          max_new_tokens=4)
    sched.submit(best_effort)
    # a stream of slightly-more-urgent arrivals; each waiting round buys
    # the best-effort request 2 virtual seconds, so its wait is bounded
    deadline = ContinuousScheduler._NO_DEADLINE - 4.0
    for i in range(1, 8):
        late = Request(req_id=i, src=np.arange(2, dtype=np.int32),
                       max_new_tokens=4, deadline_s=deadline)
        sched.submit(late)
        got = sched.admit(float(i))
        if got and got[0].req_id == 0:
            return
        for r in got:
            sched.release(r, float(i))
    assert False, "best-effort request starved behind deadline traffic"


def test_victim_key_excludes_aging():
    sched = ContinuousScheduler(2, starvation_aging=10.0)
    r = Request(req_id=0, src=np.arange(2, dtype=np.int32),
                max_new_tokens=4)
    r.wait_rounds = 50
    assert sched.victim_key(r) == sched._NO_DEADLINE
    assert sched.urgency_key(r) < sched.victim_key(r)


# ------------------------------------------------------------- host units
def test_spill_store_accounting():
    store = SpillStore()
    sp = SpilledRequest(req_id=3, n_rows=1,
                        k=np.zeros((1, 1, 8, 1, 2), np.int8),
                        v=np.zeros((1, 1, 8, 1, 2), np.int8),
                        k_scale=None, v_scale=None,
                        lengths=np.asarray([5]),
                        tokens_row=np.asarray([7]),
                        cross_k=np.zeros((1, 1, 4, 1, 2), np.float32),
                        cross_v=np.zeros((1, 1, 4, 1, 2), np.float32),
                        src_lengths=np.asarray([4]), n_pages=1)
    store.put(sp)
    assert 3 in store and len(store) == 1
    assert store.spilled_bytes == sp.n_bytes > 0
    with pytest.raises(ValueError):
        store.put(sp)                   # double spill
    assert store.pop(3) is sp
    assert len(store) == 0
    with pytest.raises(ValueError):
        store.pop(3)                    # nothing to restore
    assert store.spill_events == 1 and store.restore_events == 1


def _mk_running(req_id, key, pages, step=0):
    r = Request(req_id=req_id, src=np.arange(2, dtype=np.int32),
                max_new_tokens=4, deadline_s=key)
    r.pages = list(range(pages))
    r.admitted_step = step
    return r


def test_pick_victims_least_urgent_first():
    key_fn = lambda r: r.deadline_s
    held = lambda r: len(r.pages)
    a = _mk_running(0, 1.0, 2, step=0)
    b = _mk_running(1, 9.0, 2, step=1)
    c = _mk_running(2, 5.0, 2, step=2)
    got, covered = pick_victims([a, b, c], pages_needed=3, key_fn=key_fn,
                                pages_held_fn=held)
    assert [r.req_id for r in got] == [1, 2]     # latest deadline evicted 1st
    assert covered
    # min_key (anti-thrash): equal urgency never evicts
    assert pick_victims([a, b], pages_needed=1, key_fn=key_fn,
                        pages_held_fn=held, min_key=9.0) == ([], False)
    got, covered = pick_victims([a, b], pages_needed=1, key_fn=key_fn,
                                pages_held_fn=held, min_key=5.0)
    assert [r.req_id for r in got] == [1] and covered
    # exclusion protects rows that must survive the round
    got, covered = pick_victims([a, b], pages_needed=1, key_fn=key_fn,
                                pages_held_fn=held, exclude=[b])
    assert got[0] is a and covered
    # nothing needed → no victims, trivially covered
    assert pick_victims([a, b], pages_needed=0, key_fn=key_fn,
                        pages_held_fn=held) == ([], True)


def test_pick_victims_insufficient_coverage_flagged():
    """Regression (wasted preemption): when no victim set can free enough
    pages, the caller must see ``covered=False`` — the old ``min_key=None``
    contract returned the insufficient list bare, so a caller that didn't
    re-check spilled every victim and still came up short."""
    key_fn = lambda r: r.deadline_s
    held = lambda r: len(r.pages)
    a = _mk_running(0, 1.0, 2, step=0)
    b = _mk_running(1, 9.0, 2, step=1)
    got, covered = pick_victims([a, b], pages_needed=99, key_fn=key_fn,
                                pages_held_fn=held)
    assert [r.req_id for r in got] == [1, 0] and not covered
    # min_key mode reports the same uniform contract
    got, covered = pick_victims([a, b], pages_needed=99, key_fn=key_fn,
                                pages_held_fn=held, min_key=5.0)
    assert [r.req_id for r in got] == [1] and not covered


def test_chaos_schedule_determinism():
    ch = make_chaos(5, n_rounds=12, preempt_every=3, victims_per_round=2,
                    slow_every=4, slow_s=1.5)
    ids = [11, 3, 7, 5]
    for rnd in range(12):
        v1 = ch.victims_for(rnd, ids)
        v2 = ch.victims_for(rnd, list(reversed(ids)))
        assert v1 == v2                          # order-independent
        assert set(v1) <= set(ids)
        assert len(v1) == (2 if rnd in ch.preempt_rounds else 0)
    assert ch.slow_for(4) == 1.5 and ch.slow_for(5) == 0.0
    assert ch.n_preemptions_planned == 2 * len(ch.preempt_rounds)
    assert make_chaos(5, n_rounds=12).preempt_rounds == \
        make_chaos(5, n_rounds=12).preempt_rounds
    with pytest.raises(ValueError):
        make_chaos(0, preempt_every=0)


def test_watchdog_straggler_and_misuse():
    wd = StepWatchdog(threshold=2.0)
    for _ in range(6):
        assert not wd.observe(0.1)
    assert wd.observe(1.0)                       # 10× the median
    assert wd.straggler_steps == [7]
    with pytest.raises(RuntimeError):
        StepWatchdog().stop()                    # stop without start


# ------------------------------------------------------------- properties
@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10), min_size=1,
                max_size=10),
       st.integers(min_value=0, max_value=10 ** 6))
def test_prop_scheduler_terminal_states_and_reclaim(budgets, seed):
    """Random admit/preempt/release churn against an overcommitted pool:
    every request ends in exactly one terminal state, nothing deadlocks,
    and pages/reservations/spill accounting all return to zero."""
    rng = np.random.default_rng(seed)
    alloc = kvc.PageAllocator(12, 4, overcommit_limit=1.5)
    sched = ContinuousScheduler(
        3, allocator=alloc,
        pages_per_request=lambda r: kvc.pages_per_row(
            min(r.max_new_tokens, 16), 4),
        initial_pages=lambda r: kvc.pages_per_row(
            min(4, max(r.max_new_tokens, 1)), 4))
    reqs = [Request(req_id=i, src=np.arange(1 + i % 3, dtype=np.int32),
                    max_new_tokens=m,
                    deadline_s=(None if i % 3 else 100.0 + i),
                    priority=float(i % 2))
            for i, m in enumerate(budgets)]
    sched.submit_many(reqs)
    for t in range(200):
        if sched.all_done:
            break
        sched.admit(float(t))
        running = list(sched.slot_map.values())
        if running and rng.random() < 0.4:
            victim = running[int(rng.integers(len(running)))]
            n_held = len(victim.pages or [])
            if rng.random() < 0.5 and victim.pages:
                victim.spill = object()          # engine copied KV to host
            sched.preempt(victim, float(t))
            if victim.spill is not None:
                # model the engine's restore half: spilled pages return
                # to the pool when the request is re-spliced
                alloc.unspill(n_held)
                victim.spill = None
            running = list(sched.slot_map.values())
        for r in running:
            if rng.random() < 0.6:
                sched.release(r, float(t))
    assert sched.all_done, "scheduler wedged"
    for r in reqs:
        assert r.status in ("finished", "rejected")
        assert r.slot is None and r.pages is None and r.reserved_pages == 0
    assert alloc.in_use == 0 and alloc.reserved == 0 and alloc.spilled == 0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=12), min_size=1,
                max_size=12),
       st.integers(min_value=0, max_value=10 ** 6))
def test_prop_simulation_survives_any_preempt_schedule(lens, seed):
    rng = np.random.default_rng(seed)
    schedule = {int(r): int(rng.integers(1, 3))
                for r in rng.integers(0, 30, size=6)}
    base = simulate_continuous(lens, 4, burst_len=2)
    out = simulate_continuous(lens, 4, burst_len=2,
                              preempt_rounds=schedule)
    assert out["useful_slot_steps"] == base["useful_slot_steps"]
    assert out["continuous_steps"] >= base["continuous_steps"]
    assert out["host_events"] >= base["host_events"] + out["preemptions"]


def test_simulation_chunked_and_deadlines():
    out = simulate_continuous([5, 3, 8], 4, burst_len=2, prefill_chunk=4,
                              src_lengths=[10, 2, 12], n_enc_layers=3)
    assert out["chunk_stage_rounds"] == 6
    d = simulate_continuous([5, 5, 5], 1, burst_len=1,
                            deadline_steps=[None, None, 3])
    assert d["shed"] == 1 and d["deadline_misses"] == 1
    with pytest.raises(ValueError):
        simulate_continuous([3], 2, prefill_chunk=2, src_lengths=[5],
                            fused_admission=False)


# ----------------------------------------------------------- arg validation
def test_overload_arg_validation():
    s = _module_state()
    eng = s["engines"]["fp_paged"]
    unpaged = ServingEngine(s["model"], s["params"], max_len=MAX_LEN)
    with pytest.raises(ValueError):
        eng.serve(s["srcs"][:1], max_new_tokens=2, overcommit=0.5)
    with pytest.raises(ValueError):
        unpaged.serve(s["srcs"][:1], max_new_tokens=2, overcommit=1.5)
    with pytest.raises(ValueError):
        unpaged.serve(s["srcs"][:1], max_new_tokens=2,
                      chaos=ChaosSchedule(seed=1))
    with pytest.raises(ValueError):
        eng.serve(s["srcs"][:1], max_new_tokens=2, prefill_chunk=0)
    with pytest.raises(ValueError):
        eng.serve(s["srcs"][:1], max_new_tokens=2, prefill_chunk=4,
                  fused_admission=False)
