"""End-to-end INT8 BLEU parity through the *continuous* engine (ISSUE 3).

The paper's Table-1 claim — INT8 inference within a fraction of a BLEU
point of FP32 — must hold on the serving path our throughput numbers come
from: ``ServingEngine.serve`` (greedy and beam groups), not just
teacher-forced scoring.  The tiny trained NMT model comes from the shared
session fixture (``conftest.trained_nmt``); the INT8 engine quantizes
weights per-channel + the KV cache per-token per-head via
``core/ptq.quantize_model`` (dynamic activation quantization; the
BLEU-sensitive logits head stays FP by the default deny-list, as the
paper keeps its 12/97 sensitive MatMuls in FP32).

Acceptance bar: the paper reports < 0.5% *relative* BLEU drop; at this
miniature scale single-token flips are amplified, so greedy/beam serve
must stay within the paper's bar against corpus references.
"""

import numpy as np
import pytest

from repro.core import QuantPolicy, quantize_model
from repro.data import corpus_bleu
from repro.serving import ServingEngine

REL_DROP = 0.005                 # the paper's < 0.5% relative BLEU bar
MAX_NEW = 16


@pytest.fixture(scope="module")
def parity(trained_nmt):
    cfg, model, params, corpus, _ = trained_nmt
    test_set = corpus[:48]
    refs = [list(s.tgt) for s in test_set]
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    assert qctx.quantize_kv           # beam reorder moves INT8 payloads
    fp = ServingEngine(model, params, max_len=64)
    q = ServingEngine(model, qparams, quant=qctx, max_len=64)
    return test_set, refs, fp, q


def _serve_hyps(engine, test_set, beam=None):
    res = engine.serve(test_set, n_slots=8, max_new_tokens=MAX_NEW,
                       burst_len=8, beam=beam)
    assert all(r.status == "finished" for r in res.requests)
    return [list(res.tokens_for(i)) for i in range(len(test_set))]


def test_int8_serve_greedy_bleu_parity(parity):
    test_set, refs, fp, q = parity
    bleu_fp = corpus_bleu(_serve_hyps(fp, test_set), refs)
    assert bleu_fp > 10.0, f"FP32 model should translate (BLEU={bleu_fp})"
    bleu_q = corpus_bleu(_serve_hyps(q, test_set), refs)
    assert bleu_q >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q)


def test_int8_serve_beam_bleu_parity(parity):
    test_set, refs, fp, q = parity
    bleu_fp = corpus_bleu(_serve_hyps(fp, test_set, beam=4), refs)
    assert bleu_fp > 10.0, f"FP32 beam should translate (BLEU={bleu_fp})"
    bleu_q = corpus_bleu(_serve_hyps(q, test_set, beam=4), refs)
    assert bleu_q >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q)
