"""End-to-end INT8 BLEU parity through the *continuous* engine (ISSUE 3).

The paper's Table-1 claim — INT8 inference within a fraction of a BLEU
point of FP32 — must hold on the serving path our throughput numbers come
from: ``ServingEngine.serve`` (greedy and beam groups), not just
teacher-forced scoring.  The tiny trained NMT model comes from the shared
session fixture (``conftest.trained_nmt``); the INT8 engine quantizes
weights per-channel + the KV cache per-token per-head via
``core/ptq.quantize_model`` (dynamic activation quantization; the
BLEU-sensitive logits head stays FP by the default deny-list, as the
paper keeps its 12/97 sensitive MatMuls in FP32).

Acceptance bar: the paper reports < 0.5% *relative* BLEU drop; at this
miniature scale single-token flips are amplified, so greedy/beam serve
must stay within the paper's bar against corpus references.
"""

import numpy as np
import pytest

from repro.core import QuantPolicy, quantize_model
from repro.data import corpus_bleu
from repro.serving import ServingEngine

REL_DROP = 0.005                 # the paper's < 0.5% relative BLEU bar
MAX_NEW = 16


@pytest.fixture(scope="module")
def parity(trained_nmt):
    cfg, model, params, corpus, _ = trained_nmt
    test_set = corpus[:48]
    refs = [list(s.tgt) for s in test_set]
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    assert qctx.quantize_kv           # beam reorder moves INT8 payloads
    fp = ServingEngine(model, params, max_len=64)
    q = ServingEngine(model, qparams, quant=qctx, max_len=64)
    return test_set, refs, fp, q


def _serve_hyps(engine, test_set, beam=None):
    res = engine.serve(test_set, n_slots=8, max_new_tokens=MAX_NEW,
                       burst_len=8, beam=beam)
    assert all(r.status == "finished" for r in res.requests)
    return [list(res.tokens_for(i)) for i in range(len(test_set))]


def test_int8_serve_greedy_bleu_parity(parity):
    test_set, refs, fp, q = parity
    bleu_fp = corpus_bleu(_serve_hyps(fp, test_set), refs)
    assert bleu_fp > 10.0, f"FP32 model should translate (BLEU={bleu_fp})"
    bleu_q = corpus_bleu(_serve_hyps(q, test_set), refs)
    assert bleu_q >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q)


def test_int8_serve_beam_bleu_parity(parity):
    test_set, refs, fp, q = parity
    bleu_fp = corpus_bleu(_serve_hyps(fp, test_set, beam=4), refs)
    assert bleu_fp > 10.0, f"FP32 beam should translate (BLEU={bleu_fp})"
    bleu_q = corpus_bleu(_serve_hyps(q, test_set, beam=4), refs)
    assert bleu_q >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q)


# ---------------------------------------------------------------------------
# INT4 weights (ISSUE 10): block-wise INT4 decoder FFN + o_proj through serve
# ---------------------------------------------------------------------------
#
# ``weight_bits=4`` drops only the INT4-eligible decoder weights (FFN and
# attention output projections) to block-wise INT4; activations, the
# attention score path, the KV cache and the encoder stay INT8/FP.  The
# paper's bar is unchanged: < 0.5% relative BLEU drop vs FP32, now with
# ~2× fewer weight bytes streamed per decode step on those sites.

from repro.core import count_quantized


@pytest.fixture(scope="module")
def parity4(trained_nmt):
    cfg, model, params, corpus, _ = trained_nmt
    test_set = corpus[:48]
    refs = [list(s.tgt) for s in test_set]
    q4params, q4ctx = quantize_model(
        params, {}, QuantPolicy(act_quant="dynamic"),
        weight_bits=4, weight_group_size=128)
    stats = count_quantized(q4params)
    # 2 decoder layers × {self o_proj, cross o_proj, ffn/in, ffn/out}
    assert stats["int4_linears"] == 4 * cfg.n_layers, stats
    fp = ServingEngine(model, params, max_len=64)
    q4 = ServingEngine(model, q4params, quant=q4ctx, max_len=64)
    fp_paged = ServingEngine(model, params, max_len=64, paged=True)
    q4_paged = ServingEngine(model, q4params, quant=q4ctx, max_len=64,
                             paged=True)
    return test_set, refs, fp, q4, fp_paged, q4_paged


def _bleu(engine, test_set, refs, **kw):
    res = engine.serve(test_set, n_slots=8, max_new_tokens=MAX_NEW,
                       burst_len=8, **kw)
    assert all(r.status == "finished" for r in res.requests)
    return corpus_bleu([list(res.tokens_for(i))
                        for i in range(len(test_set))], refs)


def test_int4_serve_greedy_bleu_parity(parity4):
    test_set, refs, fp, q4, _, _ = parity4
    bleu_fp = _bleu(fp, test_set, refs)
    assert bleu_fp > 10.0, f"FP32 model should translate (BLEU={bleu_fp})"
    bleu_q4 = _bleu(q4, test_set, refs)
    assert bleu_q4 >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q4)


def test_int4_serve_greedy_unfused_bleu_parity(parity4):
    test_set, refs, fp, q4, _, _ = parity4
    bleu_fp = _bleu(fp, test_set, refs, fused_admission=False)
    bleu_q4 = _bleu(q4, test_set, refs, fused_admission=False)
    assert bleu_fp > 10.0
    assert bleu_q4 >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q4)


def test_int4_serve_beam_bleu_parity(parity4):
    test_set, refs, fp, q4, _, _ = parity4
    bleu_fp = _bleu(fp, test_set, refs, beam=4)
    bleu_q4 = _bleu(q4, test_set, refs, beam=4)
    assert bleu_fp > 10.0
    assert bleu_q4 >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q4)


def test_int4_serve_paged_bleu_parity(parity4):
    test_set, refs, _, _, fp_paged, q4_paged = parity4
    bleu_fp = _bleu(fp_paged, test_set, refs)
    bleu_q4 = _bleu(q4_paged, test_set, refs)
    assert bleu_fp > 10.0
    assert bleu_q4 >= bleu_fp * (1.0 - REL_DROP), (bleu_fp, bleu_q4)


def test_int4_weight_bytes_cut_on_eligible_sites(trained_nmt):
    """The headline byte claim, measured on real trained params: INT4 sites
    stream ≥ 1.9× fewer weight bytes than their INT8 counterparts."""
    from repro.core import int4_eligible_site, weight_bytes_by_site
    _, _, params, _, _ = trained_nmt
    q8, _ = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"))
    q4, _ = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"),
                           weight_bits=4, weight_group_size=128)
    b8 = weight_bytes_by_site(q8)
    b4 = weight_bytes_by_site(q4)
    elig = [s for s in b8 if int4_eligible_site(s)]
    assert elig, "expected INT4-eligible sites on the decoder"
    tot8 = sum(b8[s] for s in elig)
    tot4 = sum(b4[s] for s in elig)
    assert tot8 / tot4 >= 1.9, (tot8, tot4)
    # non-eligible sites are byte-identical INT8
    for s in b8:
        if s not in elig:
            assert b4[s] == b8[s], s
