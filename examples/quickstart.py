"""Quickstart — the paper's INT8 PTQ workflow in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small decoder LM, calibrates activation histograms on random
batches, searches KL thresholds, quantizes, and compares INT8 vs FP32
outputs and memory.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    Calibrator,
    QuantMode,
    QuantPolicy,
    Taps,
    count_quantized,
    quantize_model,
    summarize,
)
from repro.models import build_model


def main() -> None:
    cfg = get_config("yi-9b").reduced(n_layers=4, d_model=128, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # 1. calibrate: stream activation histograms through taps
    cal = Calibrator()
    for _ in range(8):
        taps = Taps()
        batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab, (4, 64)))}
        model.forward(params, batch, taps=taps)
        cal.observe_taps(taps)
    recs = cal.compute(QuantMode.SYMMETRIC)       # KL-divergence thresholds
    print(f"calibrated {len(recs)} matmul sites")

    # 2. quantize (paper §4: symmetric mode, sparse sites stay FP32)
    policy = QuantPolicy(mode=QuantMode.SYMMETRIC, act_quant="static")
    qparams, qctx = quantize_model(params, recs, policy)
    print("site summary:", summarize(policy, recs))
    stats = count_quantized(qparams)
    fp_bytes = sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(params))
    print(f"params: {fp_bytes / 1e6:.1f} MB fp32 -> "
          f"{(stats['int8_bytes'] + stats['fp_bytes']) / 1e6:.1f} MB mixed "
          f"({stats['quantized_linears']} int8 linears)")

    # 3. compare outputs
    batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab, (4, 64)))}
    fp, _ = model.forward(params, batch)
    q8, _ = model.forward(qparams, batch, quant=qctx)
    rel = float(np.abs(np.asarray(q8) - np.asarray(fp)).max()
                / (np.abs(np.asarray(fp)).max() + 1e-9))
    agree = float(np.mean(np.argmax(np.asarray(q8), -1)
                          == np.argmax(np.asarray(fp), -1)))
    print(f"max relative logit error: {rel:.4f}; "
          f"argmax agreement: {agree:.1%}")


if __name__ == "__main__":
    main()
