"""End-to-end driver (paper workflow): TRAIN a small NMT transformer on the
synthetic corpus for a few hundred steps, CALIBRATE on held-out sentences,
QUANTIZE with every Table-1 mode, and report BLEU for each.

    PYTHONPATH=src python examples/train_and_quantize.py [--steps 300]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Calibrator, QuantMode, QuantPolicy, Taps, quantize_model
from repro.core.ptq import FP_CONTEXT
from repro.data import TranslationBatches, corpus_bleu, make_corpus
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.serving import ServingEngine, TokenSortedScheduler
from repro.train import make_train_step


def translate(model, params, qctx, requests):
    engine = ServingEngine(model, params, quant=qctx or FP_CONTEXT,
                           max_len=96)
    sched = TokenSortedScheduler(batch_size=16)
    hyps = {}
    for item in sched.plan(requests):
        res = engine.generate(item.batch, max_new_tokens=24)
        for local, gi in enumerate(item.indices):
            hyps[gi] = list(res.tokens[local])
    return [hyps[i] for i in range(len(requests))]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=900)
    args = ap.parse_args()

    from repro.optim.schedule import inverse_sqrt
    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=inverse_sqrt(cfg.d_model, warmup=200), b2=0.98)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = make_corpus(600, cfg.vocab, max_words=6, seed=0)
    data = TranslationBatches(corpus, 32, sort_mode="tokens", seed=0)

    print(f"training {args.steps} steps ...")
    for i in range(args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch())
        (params, opt_state), m = step(params, opt_state, batch)
        if (i + 1) % 100 == 0:
            print(f"  step {i + 1}: loss {float(m['loss']):.4f}")

    test_set = corpus[:96]
    refs = [list(s.tgt) for s in test_set]
    bleu_fp = corpus_bleu(translate(model, params, None, test_set), refs)
    print(f"\nFP32 BLEU: {bleu_fp:.2f}")

    cal = Calibrator()
    for s in corpus[200:260]:
        taps = Taps()
        model.forward(params, {
            "src_tokens": jnp.asarray(s.src[None, :]),
            "tgt_tokens": jnp.asarray(
                np.concatenate([[1], s.tgt, [2]])[None, :])}, taps=taps)
        cal.observe_taps(taps)

    print(f"{'mode':>12} {'BLEU':>7} {'drop':>7}    (paper Table 1)")
    for mode in ("naive", "symmetric", "independent", "conjugate"):
        recs = cal.compute(mode)
        qp, qctx = quantize_model(
            params, recs, QuantPolicy(mode=QuantMode(mode),
                                      act_quant="static"))
        bleu = corpus_bleu(translate(model, qp, qctx, test_set), refs)
        print(f"{mode:>12} {bleu:7.2f} {bleu_fp - bleu:+7.2f}")


if __name__ == "__main__":
    main()
