"""Batched-serving example (paper §5.4–5.6): token-sorted scheduling +
parallel streams + INT8 engine, with throughput comparison across configs.

    PYTHONPATH=src python examples/serve_translation.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.core.ptq import FP_CONTEXT
from repro.data import make_corpus
from repro.models import build_model
from repro.serving import (
    ParallelStreams,
    ServingEngine,
    TokenSortedScheduler,
    simulate_streams,
)


def main() -> None:
    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=96, n_layers=2, n_enc_layers=2, d_ff=192,
        n_heads=4, n_kv_heads=4, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    requests = make_corpus(96, cfg.vocab, seed=5)

    print("=== sorting (paper §5.4) ===")
    for mode in ("none", "words", "tokens"):
        sched = TokenSortedScheduler(batch_size=16, sort_mode=mode)
        print(f"  {mode:>7}: pad_waste="
              f"{sched.stats(requests)['pad_waste']:.3f}")

    sched = TokenSortedScheduler(batch_size=16, sort_mode="tokens")
    items = sched.plan(requests)

    print("\n=== engines (FP32 vs INT8 cache+weights) ===")
    results = {}
    for name, pp, qq in [("fp32", params, FP_CONTEXT),
                         ("int8", qparams, qctx)]:
        engine = ServingEngine(model, pp, quant=qq, max_len=96)
        t0 = time.perf_counter()
        n_tok = sum(engine.generate(i.batch, max_new_tokens=16).n_tokens
                    for i in items)
        dt = time.perf_counter() - t0
        results[name] = dt
        print(f"  {name}: {dt:.2f}s  ({n_tok / dt:.0f} tok/s)")

    print("\n=== parallel streams (paper §5.6, queue model) ===")
    engine = ServingEngine(model, qparams, quant=qctx, max_len=96)
    costs = []
    for item in items:
        t0 = time.perf_counter()
        engine.generate(item.batch, max_new_tokens=16)
        costs.append(time.perf_counter() - t0)
    for n in (1, 2, 4):
        sim = simulate_streams(costs, n)
        print(f"  {n} streams: speedup {sim['speedup_vs_serial']:.2f}x, "
              f"utilization {sim['utilization']:.2f}")


if __name__ == "__main__":
    main()
