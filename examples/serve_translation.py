"""Batched-serving example (paper §5.4–5.6): token-sorted scheduling +
parallel streams + INT8 engine, with throughput comparison across configs,
plus the continuous bin-packed engine that supersedes static batches and
an overload section (preempt-by-page-spill, deadline admission, chunked
prefill, chaos injection).

    PYTHONPATH=src python examples/serve_translation.py
    PYTHONPATH=src python examples/serve_translation.py \\
        --overcommit 1.5 --prefill-chunk 7 --deadline-ms 800 --chaos-seed 4
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.core.ptq import FP_CONTEXT
from repro.data import make_corpus, pack_batches_token_budget, padding_stats
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import (
    ParallelStreams,
    Request,
    ServingEngine,
    TokenSortedScheduler,
    make_chaos,
    simulate_continuous,
    simulate_streams,
)


def overload_demo(model, params, *, deadline_ms=None, overcommit=1.5,
                  prefill_chunk=7, chaos_seed=None) -> None:
    """Overload section: a paged engine on a deliberately starved page
    pool, served twice — uninterrupted baseline, then with overcommit /
    chunked prefill / deadlines / seeded chaos — with the full overload
    metrics block printed.  Token identity between the two serves is the
    whole point: preemption, spill/restore, and staged prefill must be
    invisible in the output."""
    print("\n=== overload: preempt-by-spill, deadlines, chunked prefill ===")
    cfg = model.cfg
    longs = make_corpus(4, cfg.vocab, seed=7, max_words=14)
    shorts = make_corpus(4, cfg.vocab, seed=11, max_words=6)
    mix = longs + shorts
    budgets = [14, 10, 12, 16, 6, 4, 6, 4]
    engine = ServingEngine(model, params, max_len=32, paged=True,
                           page_size=8, n_pages=8)
    def make_reqs(deadline_s):
        return [Request(req_id=i, src=np.asarray(s.src, np.int32),
                        max_new_tokens=budgets[i], deadline_s=deadline_s)
                for i, s in enumerate(mix)]

    kw = dict(n_slots=4, burst_len=4)
    # baseline carries no deadline: the first serve absorbs jit compile,
    # which would otherwise blow any realistic SLO before decoding starts
    base = engine.serve(make_reqs(None), **kw)
    chaos = (make_chaos(chaos_seed, n_rounds=64, preempt_every=2)
             if chaos_seed is not None else None)
    reqs = make_reqs(None if deadline_ms is None else deadline_ms / 1e3)
    res = engine.serve(reqs, overcommit=overcommit,
                       prefill_chunk=prefill_chunk, chaos=chaos, **kw)
    identical = all(np.array_equal(base.tokens_for(i), res.tokens_for(i))
                    for i in range(len(mix))
                    if res.requests[i].status == "finished"
                    and base.requests[i].status == "finished")
    met = res.metrics()
    print(f"  overcommit={overcommit} prefill_chunk={prefill_chunk} "
          f"deadline_ms={deadline_ms} chaos_seed={chaos_seed}")
    print(f"  peak_running {base.peak_running} -> {res.peak_running}, "
          f"preemptions {res.preemptions}, spills {res.spill_events}, "
          f"restores {res.restore_events}, "
          f"spilled {res.spilled_bytes / 1024:.1f} KiB")
    print(f"  chunked_admissions {res.chunked_admissions} "
          f"({res.chunk_rounds} staged encoder rounds), "
          f"rejected {res.rejected}, deadline_misses {res.deadline_misses}, "
          f"stragglers {res.straggler_rounds}")
    print(f"  pages_in_use {res.pages_in_use} (hwm {res.page_hwm}, "
          f"free_lwm {res.free_lwm}, fragmentation "
          f"{met['fragmentation']:.2f}), first-token p95 "
          f"{met['first_token_latency_p95_s']:.3f}s")
    print(f"  token identity vs uninterrupted serve: "
          f"{'ok' if identical else 'MISMATCH'}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO on the serve clock; requests "
                         "whose deadline is provably unmeetable are shed "
                         "(status 'rejected') instead of admitted")
    ap.add_argument("--overcommit", type=float, default=1.5,
                    help="KV page reservation cap as a multiple of the "
                         "physical pool (>1 admits past worst case; "
                         "preempt-by-spill covers the gap)")
    ap.add_argument("--prefill-chunk", type=int, default=7,
                    help="sources longer than this stage one encoder "
                         "layer per serving round instead of blocking "
                         "admission (0 disables)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a seeded forced-preemption schedule "
                         "(serving chaos harness); output tokens must "
                         "stay identical")
    args = ap.parse_args(argv)

    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=96, n_layers=2, n_enc_layers=2, d_ff=192,
        n_heads=4, n_kv_heads=4, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    requests = make_corpus(96, cfg.vocab, seed=5)

    print("=== sorting (paper §5.4) ===")
    for mode in ("none", "words", "tokens"):
        sched = TokenSortedScheduler(batch_size=16, sort_mode=mode)
        print(f"  {mode:>7}: pad_waste="
              f"{sched.stats(requests)['pad_waste']:.3f}")

    sched = TokenSortedScheduler(batch_size=16, sort_mode="tokens")
    items = sched.plan(requests)

    print("\n=== engines (FP32 vs INT8 cache+weights) ===")
    results = {}
    for name, pp, qq in [("fp32", params, FP_CONTEXT),
                         ("int8", qparams, qctx)]:
        engine = ServingEngine(model, pp, quant=qq, max_len=96)
        t0 = time.perf_counter()
        n_tok = sum(engine.generate(i.batch, max_new_tokens=16).n_tokens
                    for i in items)
        dt = time.perf_counter() - t0
        results[name] = dt
        print(f"  {name}: {dt:.2f}s  ({n_tok / dt:.0f} tok/s)")

    print("\n=== parallel streams (paper §5.6, queue model) ===")
    engine = ServingEngine(model, qparams, quant=qctx, max_len=96)
    costs = []
    for item in items:
        t0 = time.perf_counter()
        engine.generate(item.batch, max_new_tokens=16)
        costs.append(time.perf_counter() - t0)
    for n in (1, 2, 4):
        sim = simulate_streams(costs, n)
        print(f"  {n} streams: speedup {sim['speedup_vs_serial']:.2f}x, "
              f"utilization {sim['utilization']:.2f}")

    print("\n=== continuous bin-packed serving (beyond the paper) ===")
    bins = pack_batches_token_budget(requests, token_budget=256)
    print(f"  FFD bins: {len(bins)} (budget 256 padded tokens), pad_waste="
          f"{padding_stats(requests, bins)['pad_waste']:.3f}")
    # skewed generation lengths — the regime static batches handle poorly
    rng = np.random.default_rng(0)
    budgets = np.where(rng.random(len(requests)) < 0.75, 4, 16)
    order = [i for b in bins for i in b]
    res = engine.serve([requests[i] for i in order], n_slots=8,
                       max_new_tokens=[int(budgets[i]) for i in order])
    met = res.metrics()
    print(f"  continuous: {res.tokens_per_s:.0f} tok/s, slot utilization "
          f"{res.utilization:.2f}, first-token p95 "
          f"{met['first_token_latency_p95_s']:.3f}s")
    sim = simulate_continuous([int(b) for b in budgets], 8, static_batch=8)
    print(f"  queue model (8-row grids): static util "
          f"{sim['static_utilization']:.2f} vs continuous util "
          f"{sim['continuous_utilization']:.2f} "
          f"({sim['speedup_steps']:.2f}x fewer decode steps)")

    print("\n=== decode bursts (host syncs vs slot-refill latency) ===")
    for k in (1, 8):
        engine.serve([requests[i] for i in order], n_slots=8,  # warm jit
                     max_new_tokens=[int(budgets[i]) for i in order],
                     burst_len=k)
        t0 = time.perf_counter()
        res = engine.serve([requests[i] for i in order], n_slots=8,
                           max_new_tokens=[int(budgets[i]) for i in order],
                           burst_len=k)
        dt = time.perf_counter() - t0
        print(f"  burst_len={k}: {res.n_tokens / dt:.0f} tok/s, "
              f"{res.host_syncs} host syncs for {res.decode_steps} decode "
              f"steps, slot utilization {res.utilization:.2f}")

    print("\n=== fused admission (prefill rides the burst program) ===")
    for fused in (False, True):
        engine.serve([requests[i] for i in order], n_slots=8,  # warm jit
                     max_new_tokens=[int(budgets[i]) for i in order],
                     burst_len=8, fused_admission=fused)
        t0 = time.perf_counter()
        res = engine.serve([requests[i] for i in order], n_slots=8,
                           max_new_tokens=[int(budgets[i]) for i in order],
                           burst_len=8, fused_admission=fused)
        dt = time.perf_counter() - t0
        print(f"  {'fused  ' if fused else 'unfused'}: "
              f"{res.n_tokens / dt:.0f} tok/s, {res.host_syncs} host syncs, "
              f"{res.prefill_dispatches} prefill dispatches over "
              f"{res.prefill_rounds} admission rounds")

    print("\n=== continuous beam serving (beam groups in the decode grid) ===")
    beam = 2
    few = [requests[i] for i in order[:24]]
    caps = [int(budgets[i]) for i in order[:24]]
    # per-request baseline: one generate_beam call per request
    for _ in range(2):                                      # 2nd pass is warm
        t0 = time.perf_counter()
        n_tok = 0
        for s, cap in zip(few, caps):
            src, lens = pad_batch([s.src])
            n_tok += engine.generate_beam(
                {"src_tokens": src, "src_lengths": lens}, beam=beam,
                max_new_tokens=cap, burst_len=8).n_tokens
        per_req_s = time.perf_counter() - t0
    print(f"  per-request generate_beam: {n_tok / per_req_s:.0f} tok/s")
    for _ in range(2):
        t0 = time.perf_counter()
        res = engine.serve(few, n_slots=8, max_new_tokens=caps,
                           burst_len=8, beam=beam)
        cont_s = time.perf_counter() - t0
    print(f"  continuous beam groups:    {res.n_tokens / cont_s:.0f} tok/s "
          f"({res.n_groups} groups of {beam} rows, grid utilization "
          f"{res.utilization:.2f}, {res.prefill_rounds} refill rounds)")
    sim = simulate_continuous(caps, 8, static_batch=4, beam=beam)
    print(f"  queue model: static util {sim['static_utilization']:.2f} vs "
          f"continuous {sim['continuous_utilization']:.2f} with "
          f"{sim['n_groups']} group servers")

    overload_demo(model, params, deadline_ms=args.deadline_ms,
                  overcommit=args.overcommit,
                  prefill_chunk=args.prefill_chunk or None,
                  chaos_seed=args.chaos_seed)


if __name__ == "__main__":
    main()
