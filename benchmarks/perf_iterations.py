"""§Perf hillclimbing harness — hypothesis → change → re-lower → measure.

Each iteration re-lowers one of the three selected cells on the production
mesh with a candidate change and records the roofline terms before/after
into ``experiments/perf/<cell>__<iter>.json``.  The narrative lives in
EXPERIMENTS.md §Perf.

Cells (selection per the assignment):
  A. yi-9b × decode_32k      — most representative of the paper (INT8
                               serving decode); worst roofline fraction.
  B. internvl2-76b × train_4k — most collective-bound.
  C. qwen3-moe-30b-a3b × prefill_32k — MoE dispatch overhead (worst
                               useful-compute ratio among serve cells).

Run:  PYTHONPATH=src python -m benchmarks.perf_iterations <cell> <iter>
      (module must be launched fresh per iteration — device-count env).
"""

import json
import os
import subprocess
import sys

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
import jax
from jax.sharding import PartitionSpec as P
from repro.configs import SHAPES, get_config
from repro.core.policy import QuantPolicy
from repro.core.ptq import QuantContext, quantize_model
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.mesh import make_production_mesh, batch_axes
from repro.launch.dryrun import lower_cell
from repro.models.registry import build_model

cell, variant = sys.argv[1], sys.argv[2]
ARCH, SHAPE = {"A": ("yi-9b", "decode_32k"),
               "B": ("internvl2-76b", "train_4k"),
               "C": ("qwen3-moe-30b-a3b", "prefill_32k")}[cell]

def measure(**kw):
    rec = lower_cell(ARCH, SHAPE, multi_pod=False, **kw)
    return {"memory_gib": rec["memory"]["peak_per_device_gib"],
            "argument_bytes": rec["memory"]["argument_bytes"],
            "collective_bytes": rec["collectives"]["total_bytes"],
            "collectives_by_kind": rec["collectives"]["by_kind"]}

import repro.launch.specs as specs_mod
if variant == "baseline":
    out = measure()
elif variant == "static_scales":
    # patch the serving policy to calibrated-constant activation scales
    orig = specs_mod.serve_param_specs
    def patched(cfg, mesh):
        model, p_sds, qctx = orig(cfg, mesh)
        qctx = QuantContext(policy=QuantPolicy(
            mode=cfg.quant.mode, act_quant="static", default_amax=8.0,
            quantize_kv_cache=cfg.quant.quantize_kv_cache), impl="xla")
        return model, p_sds, qctx
    specs_mod.serve_param_specs = patched
    out = measure()
elif variant == "bf16_params":
    os.environ["REPRO_MIXED_PRECISION"] = "1"
    out = measure()
elif variant == "grad_rs_tag":
    from repro.distributed.context import block_grad_specs
    from repro.distributed.sharding import param_specs
    from repro.launch.mesh import fsdp_axes
    import repro.launch.dryrun as dr
    cfg = get_config(ARCH)
    mesh = make_production_mesh()
    model = build_model(cfg)
    p_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(p_abs, mesh, tensor="model", fsdp=fsdp_axes(mesh),
                        kv_heads=cfg.n_kv_heads)
    block_specs = jax.tree_util.tree_map(
        lambda s: P(*list(s)[1:]), specs["blocks"],
        is_leaf=lambda x: isinstance(x, P))
    with block_grad_specs(block_specs):
        out = measure()
else:
    raise SystemExit(f"unknown variant {variant}")
print("RESULT " + json.dumps(out))
'''


def run_variant(cell: str, variant: str) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, cell, variant],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"{cell}/{variant} failed:\n{proc.stderr[-2000:]}")


def main() -> None:
    cell, variant = sys.argv[1], sys.argv[2]
    os.makedirs("experiments/perf", exist_ok=True)
    out = run_variant(cell, variant)
    path = f"experiments/perf/{cell}__{variant}.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(path)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
