"""Continuous beam serving vs per-request beam search, FP and INT8 cache.

The paper's serving story is INT8 inference under batching with the
beam-search GatherNd quantized (§5.3); ``ServingEngine.serve(beam=B)``
closes the last decode mode the continuous engine didn't cover by running
beam groups — ``B`` contiguous rows per request — through the slot-refill
grid.  This sweep measures what that buys on the skewed-length workload
(75% short / 25% long budgets) where per-request beam search leaves the
machine idle on every short request's tail:

* ``beam_serve_{fp,int8}_b{B}``     — continuous beam groups: measured
  tokens/s, grid utilization, refill (prefill) rounds, and **token
  identity** against the per-request ``generate_beam`` reference (the
  winning hypothesis of every request must match exactly — FP and INT8
  engines each against their own reference).
* ``beam_per_request_{fp,int8}_b{B}`` — the baseline: one
  ``generate_beam`` call per request (batch of one group), same budgets.
* ``beam_fused_admission_{fp,int8}_b{B}`` — fused admission A/B: the same
  serve with ``fused_admission=False`` (PR 3 behaviour: separate prefill
  dispatch per admission round, source tiled ``B×`` through the encoder).
  Token identity, ``prefill_dispatches == 0`` on the fused path, and the
  ``B×`` encode-once reduction in ``encoder_tokens`` are **asserted** —
  the CI bench-smoke job fails on any regression.
* ``beam_serve_paged_{fp,int8}_b{B}`` — the **paged KV cache** (ISSUE 5):
  block tables end to end, so the per-step beam reorder is an int32 table
  permutation + one partial-page copy instead of the full slab gather.
  Asserted: token identity with the same per-request reference, ≥10×
  fewer reorder bytes than the unpaged serve, zero pages leaked, and
  tokens/s ≥ parity (with CI noise headroom) against the unpaged row.
* ``beam_serve_mixed_paged``        — mixed per-request beam widths in one
  grid (the fragmentation-free serving paging unlocks): every request is
  asserted token-identical to its own-width ``generate_beam``.
* ``paged_capacity``                — admitted-rows-at-fixed-HBM: how many
  concurrent requests the same cache HBM admits when reservations are
  per-request pages instead of contiguous ``S_max`` rows (asserted >).
* ``beam_serve_best``               — best configuration summary.
* ``compile_warmup``                — jit compile + warmup seconds,
  excluded from every measured row.

The INT8 rows quantize weights per-channel and the KV cache per-token
per-head (``core/ptq.quantize_model`` with dynamic activation
quantization), so the beam reorder moves int8 payloads — the paper's 4×
GatherNd traffic cut — while the sweep asserts the output stream is still
identical to that engine's own per-request beam decode.

``--smoke`` shrinks the sweep for CI.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import measure
from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ServingEngine

BEAMS = (2, 4)
N_REQUESTS = 32
N_SLOTS = 8                  # rows: beam groups per grid = N_SLOTS // beam
BURST_LEN = 8
MAX_LEN = 64
PAGE_SIZE = 8
SHORT_BUDGET, LONG_BUDGET = 4, 24
P_SHORT = 0.75
MEASURE_PASSES = 3
# CPU-noise headroom on the ≥-parity assertion (the paged path must not
# regress tokens/s; small shared-machine jitter must not flake CI)
PAGED_PARITY_FLOOR = 0.7


def _setup(n_requests: int):
    # test-scale model (dispatch-dominated on CPU): the regime where both
    # bursts and continuous refill pay — and where identity bugs surface
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=2, n_kv_heads=2, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    engines = {
        "fp": ServingEngine(model, params, max_len=MAX_LEN),
        "int8": ServingEngine(model, qparams, quant=qctx, max_len=MAX_LEN),
    }
    paged = {
        "fp": ServingEngine(model, params, max_len=MAX_LEN, paged=True,
                            page_size=PAGE_SIZE),
        "int8": ServingEngine(model, qparams, quant=qctx, max_len=MAX_LEN,
                              paged=True, page_size=PAGE_SIZE),
    }
    requests = make_corpus(n_requests, cfg.vocab, seed=9, max_words=8)
    rng = np.random.default_rng(0)
    budgets = [int(b) for b in np.where(rng.random(n_requests) < P_SHORT,
                                        SHORT_BUDGET, LONG_BUDGET)]
    return engines, paged, requests, budgets


def _per_request_beam(engine, requests, budgets, beam):
    """One generate_beam call per request — the baseline serving loop."""
    outs, n_tok = [], 0
    for s, cap in zip(requests, budgets):
        src, lens = pad_batch([s.src])
        res = engine.generate_beam(
            {"src_tokens": src, "src_lengths": lens}, beam=beam,
            max_new_tokens=cap, burst_len=BURST_LEN)
        outs.append(np.asarray(res.tokens[0])[:cap])
        n_tok += res.n_tokens
    return outs, n_tok


def run(smoke: bool = False) -> list:
    rows = []
    beams = (2,) if smoke else BEAMS
    n_requests = 12 if smoke else N_REQUESTS
    passes = 1 if smoke else MEASURE_PASSES
    engines, paged_engines, requests, budgets = _setup(n_requests)

    warm_total = 0.0
    best = (None, 0.0)
    for qname, engine in engines.items():
        for beam in beams:
            ref_fn = lambda: _per_request_beam(engine, requests, budgets,
                                               beam)
            (reference, ref_tok), times, warm_s = measure(
                ref_fn, warmup=1, passes=passes)
            warm_total += warm_s
            ref_tps = ref_tok / min(times)
            rows.append((f"beam_per_request_{qname}_b{beam}",
                         min(times) * 1e6 / n_requests,
                         f"tok_per_s={ref_tps:.1f}"))

            serve = lambda: engine.serve(requests, n_slots=N_SLOTS,
                                         max_new_tokens=budgets,
                                         burst_len=BURST_LEN, beam=beam)
            res, times, warm_s = measure(serve, warmup=1, passes=passes)
            warm_total += warm_s
            tps = res.n_tokens / min(times)
            mismatches = sum(
                not np.array_equal(res.tokens_for(i), reference[i])
                for i in range(n_requests))
            # identity is a hard invariant, not a report: fail the run (and
            # the CI bench-smoke step) if continuous beam ever diverges
            assert mismatches == 0, (
                f"{qname} beam={beam}: {mismatches}/{n_requests} requests "
                "diverged from per-request generate_beam")
            rows.append((f"beam_serve_{qname}_b{beam}",
                         min(times) * 1e6 / n_requests,
                         f"tok_per_s={tps:.1f} "
                         f"speedup_vs_per_request={tps / ref_tps:.2f}x "
                         f"groups={res.n_groups} "
                         f"grid_util={res.utilization:.3f} "
                         f"refill_rounds={res.prefill_rounds} "
                         f"prefill_dispatches={res.prefill_dispatches} "
                         f"encoder_tokens={res.encoder_tokens} "
                         f"identical_to_generate_beam={mismatches == 0}"))
            if tps / ref_tps > best[1]:
                best = (f"{qname}_b{beam}", tps / ref_tps)

            # fused-admission A/B: the unfused path re-dispatches prefill
            # every admission round and tiles each source `beam`× through
            # the encoder; identity + the dispatch/FLOP cuts are hard
            # invariants (CI bench-smoke fails on regression)
            unfused_fn = lambda: engine.serve(
                requests, n_slots=N_SLOTS, max_new_tokens=budgets,
                burst_len=BURST_LEN, beam=beam, fused_admission=False)
            unf, u_times, warm_s = measure(unfused_fn, warmup=1,
                                           passes=passes)
            warm_total += warm_s
            assert res.prefill_dispatches == 0 and res.fused_admission
            assert unf.prefill_dispatches > 0
            for i in range(n_requests):
                assert np.array_equal(res.tokens_for(i), unf.tokens_for(i)), (
                    f"{qname} beam={beam}: fused admission diverged from "
                    f"the unfused path on request {i}")
            # encode-once broadcast: the unfused path pays ≥ beam× the
            # encoder row-tokens for the same admissions
            assert unf.encoder_tokens >= beam * res.encoder_tokens > 0, (
                f"{qname} beam={beam}: expected ≥{beam}× encoder tokens "
                f"unfused, got {unf.encoder_tokens} vs {res.encoder_tokens}")
            assert res.host_syncs < unf.host_syncs
            rows.append((f"beam_fused_admission_{qname}_b{beam}",
                         min(u_times) * 1e6 / n_requests,
                         f"unfused_tok_per_s={unf.n_tokens / min(u_times):.1f} "
                         f"host_syncs={res.host_syncs}_vs_{unf.host_syncs} "
                         f"encoder_tokens={res.encoder_tokens}_vs_"
                         f"{unf.encoder_tokens} "
                         f"encode_once_cut="
                         f"{unf.encoder_tokens / max(res.encoder_tokens, 1):.2f}x"))

            # paged KV cache: same serve through block tables — zero-copy
            # beam reorder.  Identity, the ≥10× reorder-byte cut, zero
            # page leaks, and tokens/s parity are hard invariants (the CI
            # bench-smoke step fails on regression).
            paged_fn = lambda: paged_engines[qname].serve(
                requests, n_slots=N_SLOTS, max_new_tokens=budgets,
                burst_len=BURST_LEN, beam=beam)
            pres, p_times, warm_s = measure(paged_fn, warmup=1,
                                            passes=passes)
            warm_total += warm_s
            for i in range(n_requests):
                assert np.array_equal(pres.tokens_for(i), reference[i]), (
                    f"{qname} beam={beam}: paged serve diverged from "
                    f"per-request generate_beam on request {i}")
            assert pres.paged and pres.pages_in_use == 0
            assert pres.prefill_dispatches == 0
            assert res.reorder_bytes >= 10 * pres.reorder_bytes > 0, (
                f"{qname} beam={beam}: paged reorder must move ≥10× fewer "
                f"bytes: {res.reorder_bytes} vs {pres.reorder_bytes}")
            # tokens/s parity, measured as INTERLEAVED pairs (unpaged then
            # paged back-to-back each pass, median ratio) so shared-
            # machine load spikes hit both sides instead of whichever
            # block they landed on — the separate-block numbers above are
            # for the per-row report only
            ratios = []
            for _ in range(max(passes, 3)):
                u, ut, _ = measure(serve, warmup=0, passes=1)
                p, pt, _ = measure(paged_fn, warmup=0, passes=1)
                ratios.append((p.n_tokens / min(pt)) /
                              (u.n_tokens / min(ut)))
            rel = float(np.median(ratios))
            assert rel >= PAGED_PARITY_FLOOR, (
                f"{qname} beam={beam}: paged tokens/s regressed: "
                f"median paired ratio {rel:.2f}x vs unpaged")
            ptps = pres.n_tokens / min(p_times)
            rows.append((f"beam_serve_paged_{qname}_b{beam}",
                         min(p_times) * 1e6 / n_requests,
                         f"tok_per_s={ptps:.1f} "
                         f"vs_unpaged_paired={rel:.2f}x "
                         f"reorder_bytes_cut="
                         f"{res.reorder_bytes / max(pres.reorder_bytes, 1):.1f}x "
                         f"page_hwm={pres.page_hwm} "
                         f"page_size={pres.page_size}"))

    # mixed per-request beam widths through ONE paged grid (what paging's
    # fragmentation-free reservations unlock): every request must match
    # its own-width generate_beam stream exactly
    n_mixed = min(n_requests, 12)
    rng = np.random.default_rng(3)
    widths = [int(w) for w in rng.choice([1, 2, 4], size=n_mixed)]
    mixed_ref = []
    eng = engines["fp"]
    for s, cap, w in zip(requests[:n_mixed], budgets[:n_mixed], widths):
        src, lens = pad_batch([s.src])
        r = eng.generate_beam({"src_tokens": src, "src_lengths": lens},
                              beam=w, max_new_tokens=cap,
                              burst_len=BURST_LEN)
        mixed_ref.append(np.asarray(r.tokens[0])[:cap])
    mres = paged_engines["fp"].serve(
        requests[:n_mixed], n_slots=8, max_new_tokens=budgets[:n_mixed],
        burst_len=BURST_LEN, beam=widths)
    for i in range(n_mixed):
        assert np.array_equal(mres.tokens_for(i), mixed_ref[i]), (
            f"mixed-beam paged serve diverged on request {i} "
            f"(beam={widths[i]})")
    assert mres.pages_in_use == 0
    rows.append(("beam_serve_mixed_paged", 0.0,
                 f"widths={{1,2,4}} n={n_mixed} grid_beam={mres.beam} "
                 f"page_hwm={mres.page_hwm} identical_each_width=True"))

    # admitted-rows-at-fixed-HBM: contiguous rows reserve S_max tokens
    # each; pages reserve each request's own budget.  Same cache HBM ⇒
    # more concurrent rows for the skewed budget mix (asserted).
    maxP = MAX_LEN // PAGE_SIZE
    pool_pages = N_SLOTS * maxP               # = the unpaged grid's HBM
    mean_need = float(np.mean(
        [max((b + PAGE_SIZE - 1) // PAGE_SIZE, 1) for b in budgets]))
    paged_rows_fit = int(pool_pages / mean_need)
    assert paged_rows_fit > N_SLOTS, (paged_rows_fit, N_SLOTS)
    rows.append(("paged_capacity", 0.0,
                 f"rows_at_same_hbm={paged_rows_fit}_vs_{N_SLOTS} "
                 f"({paged_rows_fit / N_SLOTS:.1f}x; budget-mix mean "
                 f"{mean_need:.1f} pages/row vs {maxP} contiguous)"))

    rows.append(("beam_serve_best", 0.0,
                 f"best={best[0]} speedup_vs_per_request={best[1]:.2f}x"))
    rows.append(("compile_warmup", 0.0,
                 f"total_s={warm_total:.2f} (excluded from rows above)"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(x) for x in r))
