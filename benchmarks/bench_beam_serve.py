"""Continuous beam serving vs per-request beam search, FP and INT8 cache.

The paper's serving story is INT8 inference under batching with the
beam-search GatherNd quantized (§5.3); ``ServingEngine.serve(beam=B)``
closes the last decode mode the continuous engine didn't cover by running
beam groups — ``B`` contiguous rows per request — through the slot-refill
grid.  This sweep measures what that buys on the skewed-length workload
(75% short / 25% long budgets) where per-request beam search leaves the
machine idle on every short request's tail:

* ``beam_serve_{fp,int8}_b{B}``     — continuous beam groups: measured
  tokens/s, grid utilization, refill (prefill) rounds, and **token
  identity** against the per-request ``generate_beam`` reference (the
  winning hypothesis of every request must match exactly — FP and INT8
  engines each against their own reference).
* ``beam_per_request_{fp,int8}_b{B}`` — the baseline: one
  ``generate_beam`` call per request (batch of one group), same budgets.
* ``beam_fused_admission_{fp,int8}_b{B}`` — fused admission A/B: the same
  serve with ``fused_admission=False`` (PR 3 behaviour: separate prefill
  dispatch per admission round, source tiled ``B×`` through the encoder).
  Token identity, ``prefill_dispatches == 0`` on the fused path, and the
  ``B×`` encode-once reduction in ``encoder_tokens`` are **asserted** —
  the CI bench-smoke job fails on any regression.
* ``beam_serve_best``               — best configuration summary.
* ``compile_warmup``                — jit compile + warmup seconds,
  excluded from every measured row.

The INT8 rows quantize weights per-channel and the KV cache per-token
per-head (``core/ptq.quantize_model`` with dynamic activation
quantization), so the beam reorder moves int8 payloads — the paper's 4×
GatherNd traffic cut — while the sweep asserts the output stream is still
identical to that engine's own per-request beam decode.

``--smoke`` shrinks the sweep for CI.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import measure
from repro.configs import get_config
from repro.core import QuantPolicy, quantize_model
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ServingEngine

BEAMS = (2, 4)
N_REQUESTS = 32
N_SLOTS = 8                  # rows: beam groups per grid = N_SLOTS // beam
BURST_LEN = 8
SHORT_BUDGET, LONG_BUDGET = 4, 24
P_SHORT = 0.75
MEASURE_PASSES = 3


def _setup(n_requests: int):
    # test-scale model (dispatch-dominated on CPU): the regime where both
    # bursts and continuous refill pay — and where identity bugs surface
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=2, n_kv_heads=2, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, qctx = quantize_model(params, {},
                                   QuantPolicy(act_quant="dynamic"))
    engines = {
        "fp": ServingEngine(model, params, max_len=64),
        "int8": ServingEngine(model, qparams, quant=qctx, max_len=64),
    }
    requests = make_corpus(n_requests, cfg.vocab, seed=9, max_words=8)
    rng = np.random.default_rng(0)
    budgets = [int(b) for b in np.where(rng.random(n_requests) < P_SHORT,
                                        SHORT_BUDGET, LONG_BUDGET)]
    return engines, requests, budgets


def _per_request_beam(engine, requests, budgets, beam):
    """One generate_beam call per request — the baseline serving loop."""
    outs, n_tok = [], 0
    for s, cap in zip(requests, budgets):
        src, lens = pad_batch([s.src])
        res = engine.generate_beam(
            {"src_tokens": src, "src_lengths": lens}, beam=beam,
            max_new_tokens=cap, burst_len=BURST_LEN)
        outs.append(np.asarray(res.tokens[0])[:cap])
        n_tok += res.n_tokens
    return outs, n_tok


def run(smoke: bool = False) -> list:
    rows = []
    beams = (2,) if smoke else BEAMS
    n_requests = 12 if smoke else N_REQUESTS
    passes = 1 if smoke else MEASURE_PASSES
    engines, requests, budgets = _setup(n_requests)

    warm_total = 0.0
    best = (None, 0.0)
    for qname, engine in engines.items():
        for beam in beams:
            ref_fn = lambda: _per_request_beam(engine, requests, budgets,
                                               beam)
            (reference, ref_tok), times, warm_s = measure(
                ref_fn, warmup=1, passes=passes)
            warm_total += warm_s
            ref_tps = ref_tok / min(times)
            rows.append((f"beam_per_request_{qname}_b{beam}",
                         min(times) * 1e6 / n_requests,
                         f"tok_per_s={ref_tps:.1f}"))

            serve = lambda: engine.serve(requests, n_slots=N_SLOTS,
                                         max_new_tokens=budgets,
                                         burst_len=BURST_LEN, beam=beam)
            res, times, warm_s = measure(serve, warmup=1, passes=passes)
            warm_total += warm_s
            tps = res.n_tokens / min(times)
            mismatches = sum(
                not np.array_equal(res.tokens_for(i), reference[i])
                for i in range(n_requests))
            # identity is a hard invariant, not a report: fail the run (and
            # the CI bench-smoke step) if continuous beam ever diverges
            assert mismatches == 0, (
                f"{qname} beam={beam}: {mismatches}/{n_requests} requests "
                "diverged from per-request generate_beam")
            rows.append((f"beam_serve_{qname}_b{beam}",
                         min(times) * 1e6 / n_requests,
                         f"tok_per_s={tps:.1f} "
                         f"speedup_vs_per_request={tps / ref_tps:.2f}x "
                         f"groups={res.n_groups} "
                         f"grid_util={res.utilization:.3f} "
                         f"refill_rounds={res.prefill_rounds} "
                         f"prefill_dispatches={res.prefill_dispatches} "
                         f"encoder_tokens={res.encoder_tokens} "
                         f"identical_to_generate_beam={mismatches == 0}"))
            if tps / ref_tps > best[1]:
                best = (f"{qname}_b{beam}", tps / ref_tps)

            # fused-admission A/B: the unfused path re-dispatches prefill
            # every admission round and tiles each source `beam`× through
            # the encoder; identity + the dispatch/FLOP cuts are hard
            # invariants (CI bench-smoke fails on regression)
            unfused_fn = lambda: engine.serve(
                requests, n_slots=N_SLOTS, max_new_tokens=budgets,
                burst_len=BURST_LEN, beam=beam, fused_admission=False)
            unf, u_times, warm_s = measure(unfused_fn, warmup=1,
                                           passes=passes)
            warm_total += warm_s
            assert res.prefill_dispatches == 0 and res.fused_admission
            assert unf.prefill_dispatches > 0
            for i in range(n_requests):
                assert np.array_equal(res.tokens_for(i), unf.tokens_for(i)), (
                    f"{qname} beam={beam}: fused admission diverged from "
                    f"the unfused path on request {i}")
            # encode-once broadcast: the unfused path pays ≥ beam× the
            # encoder row-tokens for the same admissions
            assert unf.encoder_tokens >= beam * res.encoder_tokens > 0, (
                f"{qname} beam={beam}: expected ≥{beam}× encoder tokens "
                f"unfused, got {unf.encoder_tokens} vs {res.encoder_tokens}")
            assert res.host_syncs < unf.host_syncs
            rows.append((f"beam_fused_admission_{qname}_b{beam}",
                         min(u_times) * 1e6 / n_requests,
                         f"unfused_tok_per_s={unf.n_tokens / min(u_times):.1f} "
                         f"host_syncs={res.host_syncs}_vs_{unf.host_syncs} "
                         f"encoder_tokens={res.encoder_tokens}_vs_"
                         f"{unf.encoder_tokens} "
                         f"encode_once_cut="
                         f"{unf.encoder_tokens / max(res.encoder_tokens, 1):.2f}x"))

    rows.append(("beam_serve_best", 0.0,
                 f"best={best[0]} speedup_vs_per_request={best[1]:.2f}x"))
    rows.append(("compile_warmup", 0.0,
                 f"total_s={warm_total:.2f} (excluded from rows above)"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(x) for x in r))
