"""Paper Table 1 — effect of calibration mode on BLEU.

Trains the tiny synthetic-NMT transformer once, then PTQs it with each of
the paper's four modes and measures corpus BLEU on a held-out slice:

    Mode        BLEU    Drop          (paper: naive NA / sym 27.30, −0.38 /
                                       indep 27.33, −0.35 / conj 27.26, −0.42)

Expected reproduction shape: naive markedly worse (the paper's model emitted
no STOP token at all); the three calibrated modes within a small drop of
FP32.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_tiny_nmt, translate_all
from repro.core import Calibrator, QuantMode, QuantPolicy, Taps, quantize_model
from repro.data import corpus_bleu


def run() -> list:
    cfg, model, params, corpus, loss = trained_tiny_nmt()
    test_set = corpus[:96]
    refs = [list(s.tgt) for s in test_set]

    fp_hyps, fp_s = translate_all(model, params, None, test_set)
    bleu_fp = corpus_bleu(fp_hyps, refs)

    # calibration pass (held-out slice, the paper used 600/3003 sentences)
    cal = Calibrator()
    for s in corpus[200:260]:
        taps = Taps()
        batch = {"src_tokens": jnp.asarray(s.src[None, :]),
                 "tgt_tokens": jnp.asarray(
                     np.concatenate([[1], s.tgt, [2]])[None, :])}
        model.forward(params, batch, taps=taps)
        cal.observe_taps(taps)

    rows = [("table1_fp32_bleu", fp_s * 1e6 / max(len(test_set), 1),
             f"bleu={bleu_fp:.2f} train_loss={loss:.3f}")]
    for mode in ("naive", "symmetric", "independent", "conjugate"):
        recs = cal.compute(mode)
        qp, qctx = quantize_model(
            params, recs,
            QuantPolicy(mode=QuantMode(mode), act_quant="static"))
        hyps, q_s = translate_all(model, qp, qctx, test_set)
        bleu = corpus_bleu(hyps, refs)
        rows.append((f"table1_{mode}_bleu",
                     q_s * 1e6 / max(len(test_set), 1),
                     f"bleu={bleu:.2f} drop={bleu_fp - bleu:+.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
